"""AOT compile path: lower every L2 graph to HLO *text* artifacts.

Run once by `make artifacts`; rust/src/runtime/ loads the text with
`HloModuleProto::from_text_file` and compiles it on the PJRT CPU client.
HLO text (NOT `.serialize()`): jax >= 0.5 emits protos with 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each artifact gets a sidecar `<name>.meta.json` recording input shapes and
dtypes so the Rust runtime can validate feeds without parsing HLO.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model


def probe_input(spec) -> np.ndarray:
    """Deterministic probe tensor (matches the Rust integration test):
    element i = (i % 13) * 0.1, reshaped to the spec."""
    n = int(np.prod(spec.shape))
    return (np.arange(n) % 13).astype(np.float32).reshape(spec.shape) * 0.1


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer elides big weight
    # literals as '{...}', which the XLA 0.5.1 text parser silently reads
    # back as ZEROS — the baked model weights would vanish.
    return comp.as_hlo_text(True)


def _spec(x):
    return jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x))


def artifact_specs():
    """name -> (fn, example_args). Params are baked as constants via closure
    so the Rust side feeds only the activation tensor(s)."""
    vw = model.vgg_slice_params()
    rw = model.resnet_slice_params()
    qw = model.qnet_params()
    cw = model.classifier_params()
    return {
        "vgg_slice": (lambda x: model.vgg_slice(x, *vw), [jax.ShapeDtypeStruct(model.VGG_IN, jnp.float32)]),
        "resnet_slice": (lambda x: model.resnet_slice(x, *rw), [jax.ShapeDtypeStruct(model.RESNET_IN, jnp.float32)]),
        "qnet": (lambda s: model.qnet(s, *qw), [jax.ShapeDtypeStruct((8, model.STATE_DIM), jnp.float32)]),
        "classifier": (lambda x: model.classifier(x, *cw), [jax.ShapeDtypeStruct((8, model.CLS_IN), jnp.float32)]),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    names = args.only.split(",") if args.only else None

    for name, (fn, specs) in artifact_specs().items():
        if names and name not in names:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        hlo_path = out_dir / f"{name}.hlo.txt"
        hlo_path.write_text(text)
        # cross-language parity fixture: run the graph in jax on the
        # deterministic probe; rust/tests/integration_runtime.rs repeats
        # the execution through PJRT-from-Rust and must match.
        probe_out = jax.jit(fn)(*[jnp.asarray(probe_input(s)) for s in specs])
        checksums = [float(np.asarray(o, np.float64).sum()) for o in probe_out]
        meta = {
            "name": name,
            "inputs": [{"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs],
            "outputs": [
                {"shape": list(o.shape), "dtype": str(o.dtype)}
                for o in lowered.out_info
            ]
            if hasattr(lowered, "out_info")
            else [],
            "probe_checksums": checksums,
        }
        (out_dir / f"{name}.meta.json").write_text(json.dumps(meta, indent=2))
        print(f"wrote {hlo_path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
