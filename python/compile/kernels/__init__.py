"""L1: Pallas kernels for the DNN-slice compute hot-spot (+ jnp oracles)."""

from . import conv2d, matmul, ref  # noqa: F401
