"""L1 Pallas kernel: conv2d as im2col + the tiled Pallas matmul.

The paper's DNN slices are dominated by 3x3 convolutions (VGG19) and
1x1/3x3 bottleneck convolutions (ResNet101). On GPU these map to implicit-
GEMM threadblock tiles; the TPU re-think (DESIGN.md SSHardware-Adaptation)
is: materialize the im2col patch matrix once per block in HBM via an XLA
gather (free fusion), then run the MXU-shaped Pallas matmul over it, so
the HBM<->VMEM schedule is the matmul's BlockSpec schedule.

Layout is NHWC (TPU-native); weights are (kh, kw, cin, cout).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import matmul as mm


def _im2col(x: jax.Array, kh: int, kw: int, stride: int, padding: int):
    """(N, H, W, C) -> patch matrix (N*OH*OW, KH*KW*C) + output spatial dims."""
    n, h, w, c = x.shape
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (w + 2 * padding - kw) // stride + 1
    # Gather the kh*kw shifted views; XLA fuses the slices + stack.
    cols = []
    for di in range(kh):
        for dj in range(kw):
            cols.append(
                jax.lax.slice(
                    x,
                    (0, di, dj, 0),
                    (n, di + (oh - 1) * stride + 1, dj + (ow - 1) * stride + 1, c),
                    (1, stride, stride, 1),
                )
            )
    patches = jnp.stack(cols, axis=3)  # (N, OH, OW, KH*KW, C)
    return patches.reshape(n * oh * ow, kh * kw * c), oh, ow


def conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    padding: int = 1,
    bm: int = mm.DEFAULT_BM,
    bn: int = mm.DEFAULT_BN,
    bk: int = mm.DEFAULT_BK,
) -> jax.Array:
    """NHWC conv2d whose GEMM core is the Pallas matmul kernel.

    x: (N, H, W, Cin); w: (KH, KW, Cin, Cout) -> (N, OH, OW, Cout).
    """
    kh, kw, cin, cout = w.shape
    patches, oh, ow = _im2col(x, kh, kw, stride, padding)
    wmat = w.reshape(kh * kw * cin, cout)
    out = mm.matmul(patches, wmat, bm=bm, bn=bn, bk=bk)
    n = x.shape[0]
    return out.reshape(n, oh, ow, cout)


def conv2d_bias_relu(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    stride: int = 1,
    padding: int = 1,
    bm: int = mm.DEFAULT_BM,
    bn: int = mm.DEFAULT_BN,
    bk: int = mm.DEFAULT_BK,
) -> jax.Array:
    """Fused conv + bias + ReLU — the repeated unit of a VGG slice."""
    return jnp.maximum(
        conv2d(x, w, stride=stride, padding=padding, bm=bm, bn=bn, bk=bk) + b, 0.0
    )


def maxpool2(x: jax.Array) -> jax.Array:
    """2x2/2 max-pool, NHWC — closes each VGG conv stage."""
    n, h, w, c = x.shape
    return jnp.max(x.reshape(n, h // 2, 2, w // 2, 2, c), axis=(2, 4))
