"""L1 Pallas kernel: tiled matmul — the compute hot-spot of every DNN slice.

The paper's slice compute is CNN inference; its hot-spot (conv via im2col,
and the fully-connected layers) reduces to GEMM. On TPU the idiomatic
mapping is a grid over (M/bm, N/bn) output tiles with a K-loop revisiting
an f32 VMEM accumulator, tiles sized to feed the 128x128 MXU. We express
that schedule with BlockSpec; `interpret=True` is mandatory on this CPU
image (real-TPU lowering emits a Mosaic custom-call the CPU PJRT plugin
cannot execute) so correctness is validated here and MXU/VMEM figures are
*estimated* in DESIGN.md SSPerf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default block shapes: multiples of the MXU edge (128) where the operand
# permits. Chosen by the block-shape sweep recorded in EXPERIMENTS.md SSPerf.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    """One (bm, bn) output tile; grid dim 2 walks the K blocks.

    acc_ref is a VMEM scratch accumulator in f32: partial products are
    accumulated across the K grid dimension and written out once on the
    final K step (double-buffered pipelining of x/y tiles is implied by
    the BlockSpec index maps).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pick_block(dim: int, pref: int) -> int:
    """Largest divisor of `dim` that is <= pref (keeps the grid exact)."""
    b = min(dim, pref)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
) -> jax.Array:
    """Tiled Pallas matmul: (M, K) @ (K, N) -> (M, N).

    Block shapes are clamped to divisors of the problem shape so the grid
    is exact; odd shapes fall back to smaller tiles rather than padding.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {y.shape}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,  # CPU image: Mosaic custom-calls are not runnable
    )(x, y)


def vmem_bytes(bm: int, bn: int, bk: int, itemsize: int = 4) -> int:
    """Estimated VMEM working set for one grid step: x tile + y tile +
    accumulator + output tile (double-buffered inputs)."""
    return 2 * (bm * bk + bk * bn) * itemsize + 2 * (bm * bn) * 4


def mxu_utilization(bm: int, bn: int, bk: int) -> float:
    """Fraction of the 128x128x8 MXU issue shape covered by one tile —
    the structural efficiency estimate used in DESIGN.md SSPerf."""
    return min(bm / 128, 1.0) * min(bn / 128, 1.0) * min(bk / 128, 1.0)
