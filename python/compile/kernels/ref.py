"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Every kernel in this package must match these references under
`numpy.testing.assert_allclose` — pytest + hypothesis sweep shapes and
dtypes in python/tests/.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """(M, K) @ (K, N) with f32 accumulation — oracle for kernels.matmul."""
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def conv2d_ref(
    x: jax.Array, w: jax.Array, *, stride: int = 1, padding: int = 1
) -> jax.Array:
    """NHWC conv via lax.conv_general_dilated — oracle for kernels.conv2d."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def conv2d_bias_relu_ref(
    x: jax.Array, w: jax.Array, b: jax.Array, *, stride: int = 1, padding: int = 1
) -> jax.Array:
    return jnp.maximum(conv2d_ref(x, w, stride=stride, padding=padding) + b, 0.0)


def maxpool2_ref(x: jax.Array) -> jax.Array:
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 2, 2, 1),
        window_strides=(1, 2, 2, 1),
        padding="VALID",
    )
