"""Build-time compile path (L1 Pallas kernels + L2 JAX graphs + AOT lowering).

Never imported at runtime — the Rust binary consumes artifacts/*.hlo.txt.
"""
