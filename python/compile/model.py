"""L2: JAX compute graphs for the satellite-side DNN slice forwards.

Each satellite in the paper executes one *segment* of a partitioned DNN
(VGG19 or ResNet101). The Rust coordinator does not re-implement the
network: these build-time JAX functions (whose GEMM core is the L1 Pallas
kernel) are AOT-lowered by aot.py to HLO text, and rust/src/runtime/ runs
them through PJRT on the request path.

Exported graphs (fixed shapes chosen to be Pi-class-representative while
staying fast on the CPU PJRT backend):

  vgg_slice      — [conv3x3+bias+relu] x2 + maxpool on (1, 56, 56, 64)
                   (the repeated stage-unit of a VGG19 segment)
  resnet_slice   — 1x1 -> 3x3 -> 1x1 bottleneck with residual add on
                   (1, 56, 56, 256) (the repeated unit of ResNet101)
  qnet           — DQN Q-network MLP (STATE_DIM -> 64 -> 64 -> N_ACTIONS)
                   used by the DQN offloading baseline's serve path
  classifier     — FC head: flatten -> (D, CLASSES) matmul (final slice)

Weights are synthetic (seeded); splitting/offloading decisions depend on
layer *shapes* (workload, activation bytes), never on weight values — see
DESIGN.md SS4.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import conv2d as k_conv
from .kernels import matmul as k_mm

# ---------------------------------------------------------------- shapes
VGG_IN = (1, 56, 56, 64)        # N, H, W, C of the representative slice input
RESNET_IN = (1, 56, 56, 256)
STATE_DIM = 32                  # DQN observation: local loads + segment sizes
N_ACTIONS = 5                   # stay + 4 torus neighbours
CLASSES = 1000
CLS_IN = 512   # kept modest: weights are embedded in HLO text


def _key(i: int) -> jax.Array:
    return jax.random.PRNGKey(i)


# ------------------------------------------------------------- vgg slice
def vgg_slice_params():
    k1, k2 = jax.random.split(_key(0))
    c = VGG_IN[3]
    w1 = jax.random.normal(k1, (3, 3, c, c), jnp.float32) * (2.0 / (9 * c)) ** 0.5
    w2 = jax.random.normal(k2, (3, 3, c, c), jnp.float32) * (2.0 / (9 * c)) ** 0.5
    b1 = jnp.zeros((c,), jnp.float32)
    b2 = jnp.zeros((c,), jnp.float32)
    return w1, b1, w2, b2


# Block shapes for the slice GEMMs (M=3136, K=576, N=64 after im2col):
# one full-M/K/N tile = 15.6 MiB estimated VMEM (double-buffered inputs +
# f32 accumulator) — inside the 16 MiB budget with a single grid trip.
# Chosen by the sweep in EXPERIMENTS.md SSPerf (L1): grid trips dominate
# interpret-mode latency (1 step: 3.4 ms vs 168 steps: 254 ms), and on a
# real TPU fewer trips = fewer HBM round-trips for the same MXU work.
VGG_BLOCKS = dict(bm=3136, bn=64, bk=576)
RESNET_BLOCKS = dict(bm=3136, bn=256, bk=576)


def vgg_slice(x, w1, b1, w2, b2):
    """conv3x3-relu -> conv3x3-relu -> maxpool2: one VGG19 stage unit."""
    h = k_conv.conv2d_bias_relu(x, w1, b1, **VGG_BLOCKS)
    h = k_conv.conv2d_bias_relu(h, w2, b2, **VGG_BLOCKS)
    return (k_conv.maxpool2(h),)


# ---------------------------------------------------------- resnet slice
def resnet_slice_params():
    k1, k2, k3 = jax.random.split(_key(1), 3)
    c, mid = RESNET_IN[3], RESNET_IN[3] // 4
    w1 = jax.random.normal(k1, (1, 1, c, mid), jnp.float32) * (2.0 / c) ** 0.5
    w2 = jax.random.normal(k2, (3, 3, mid, mid), jnp.float32) * (2.0 / (9 * mid)) ** 0.5
    w3 = jax.random.normal(k3, (1, 1, mid, c), jnp.float32) * (2.0 / mid) ** 0.5
    return w1, w2, w3


def resnet_slice(x, w1, w2, w3):
    """1x1 reduce -> 3x3 -> 1x1 expand + residual: ResNet101 bottleneck."""
    h = jnp.maximum(k_conv.conv2d(x, w1, padding=0, **RESNET_BLOCKS), 0.0)
    h = jnp.maximum(k_conv.conv2d(h, w2, padding=1, **RESNET_BLOCKS), 0.0)
    h = k_conv.conv2d(h, w3, padding=0, **RESNET_BLOCKS)
    return (jnp.maximum(h + x, 0.0),)


# ------------------------------------------------------------------ qnet
def qnet_params():
    k1, k2, k3 = jax.random.split(_key(2), 3)
    w1 = jax.random.normal(k1, (STATE_DIM, 64), jnp.float32) * (2.0 / STATE_DIM) ** 0.5
    w2 = jax.random.normal(k2, (64, 64), jnp.float32) * (2.0 / 64) ** 0.5
    w3 = jax.random.normal(k3, (64, N_ACTIONS), jnp.float32) * (2.0 / 64) ** 0.5
    return w1, w2, w3


def qnet(s, w1, w2, w3):
    """DQN Q(s, .) forward over a batch of observations (B, STATE_DIM)."""
    h = jnp.maximum(k_mm.matmul(s, w1, bm=8, bk=32, bn=64), 0.0)
    h = jnp.maximum(k_mm.matmul(h, w2, bm=8, bk=64, bn=64), 0.0)
    return (k_mm.matmul(h, w3, bm=8, bk=64, bn=N_ACTIONS),)


# ------------------------------------------------------------ classifier
def classifier_params():
    k1 = _key(3)
    w = jax.random.normal(k1, (CLS_IN, CLASSES), jnp.float32) * (1.0 / CLS_IN) ** 0.5
    return (w,)


def classifier(x, w):
    """Final FC slice: (B, CLS_IN) -> logits (B, CLASSES)."""
    return (k_mm.matmul(x, w),)
