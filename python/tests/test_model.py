"""L2 shape/semantics tests for the slice graphs + AOT lowering checks."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_vgg_slice_shape():
    x = jnp.ones(model.VGG_IN, jnp.float32)
    (out,) = model.vgg_slice(x, *model.vgg_slice_params())
    n, h, w, c = model.VGG_IN
    assert out.shape == (n, h // 2, w // 2, c)


def test_resnet_slice_shape_and_residual():
    x = jnp.zeros(model.RESNET_IN, jnp.float32)
    (out,) = model.resnet_slice(x, *model.resnet_slice_params())
    assert out.shape == model.RESNET_IN
    # zero input -> residual contributes zero -> output is relu(conv path of 0) = 0
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)


def test_qnet_shape():
    s = jnp.ones((8, model.STATE_DIM), jnp.float32)
    (q,) = model.qnet(s, *model.qnet_params())
    assert q.shape == (8, model.N_ACTIONS)


def test_classifier_shape():
    x = jnp.ones((8, model.CLS_IN), jnp.float32)
    (logits,) = model.classifier(x, *model.classifier_params())
    assert logits.shape == (8, model.CLASSES)


def test_slices_deterministic():
    """Params are seeded: two calls produce identical weights (artifact
    reproducibility — rust loads a graph with baked constants)."""
    a = model.vgg_slice_params()
    b = model.vgg_slice_params()
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_artifact_specs_cover_all():
    specs = aot.artifact_specs()
    assert set(specs) == {"vgg_slice", "resnet_slice", "qnet", "classifier"}


def test_hlo_text_lowering_roundtrip():
    """Lowering must produce parseable HLO text with an entry computation."""
    specs = aot.artifact_specs()
    fn, args = specs["qnet"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_artifacts_match_meta(tmp_path=None):
    """If artifacts/ exists, sidecar metadata must match the model shapes."""
    art = pathlib.Path(__file__).resolve().parents[2] / "artifacts"
    meta_p = art / "vgg_slice.meta.json"
    if not meta_p.exists():
        return
    meta = json.loads(meta_p.read_text())
    assert meta["inputs"][0]["shape"] == list(model.VGG_IN)
    n, h, w, c = model.VGG_IN
    assert meta["outputs"][0]["shape"] == [n, h // 2, w // 2, c]
