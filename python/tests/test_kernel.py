"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py is THE
correctness signal for everything the Rust runtime will execute.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d as k_conv
from compile.kernels import matmul as k_mm
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


# ----------------------------------------------------------------- matmul
@settings(**SETTINGS)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
)
def test_matmul_matches_ref_shapes(m, k, n):
    x = _rand(m * 7 + 1, (m, k), jnp.float32)
    y = _rand(n * 13 + 2, (k, n), jnp.float32)
    np.testing.assert_allclose(
        k_mm.matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4
    )


@settings(**SETTINGS)
@given(
    bm=st.sampled_from([8, 16, 32, 64, 128]),
    bn=st.sampled_from([8, 16, 32, 64, 128]),
    bk=st.sampled_from([8, 16, 32, 64, 128]),
)
def test_matmul_block_shape_invariance(bm, bn, bk):
    """Result must not depend on the chosen tiling."""
    x = _rand(3, (64, 128), jnp.float32)
    y = _rand(4, (128, 32), jnp.float32)
    np.testing.assert_allclose(
        k_mm.matmul(x, y, bm=bm, bn=bn, bk=bk),
        ref.matmul_ref(x, y),
        rtol=1e-4,
        atol=1e-4,
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    x = _rand(5, (32, 64), dtype)
    y = _rand(6, (64, 16), dtype)
    got = k_mm.matmul(x, y)
    want = ref.matmul_ref(x, y)
    assert got.dtype == want.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
        atol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
    )


def test_matmul_identity():
    x = _rand(7, (16, 16), jnp.float32)
    np.testing.assert_allclose(
        k_mm.matmul(x, jnp.eye(16)), x, rtol=1e-5, atol=1e-5
    )


def test_pick_block_divides():
    for dim in [1, 7, 56, 128, 224, 1000]:
        for pref in [8, 128]:
            b = k_mm._pick_block(dim, pref)
            assert dim % b == 0 and 1 <= b <= min(dim, pref)


def test_vmem_estimate_monotone():
    assert k_mm.vmem_bytes(128, 128, 128) > k_mm.vmem_bytes(64, 64, 64)
    assert 0 < k_mm.mxu_utilization(64, 128, 128) < 1.0
    assert k_mm.mxu_utilization(128, 128, 128) == 1.0


# ----------------------------------------------------------------- conv2d
@settings(**SETTINGS)
@given(
    h=st.integers(4, 20),
    cin=st.sampled_from([3, 8, 16]),
    cout=st.sampled_from([4, 8, 32]),
    stride=st.sampled_from([1, 2]),
)
def test_conv2d_matches_ref(h, cin, cout, stride):
    x = _rand(h * 3, (1, h, h, cin), jnp.float32)
    w = _rand(cout, (3, 3, cin, cout), jnp.float32)
    np.testing.assert_allclose(
        k_conv.conv2d(x, w, stride=stride, padding=1),
        ref.conv2d_ref(x, w, stride=stride, padding=1),
        rtol=1e-4,
        atol=1e-4,
    )


@pytest.mark.parametrize("k,pad", [(1, 0), (3, 1), (5, 2), (7, 3)])
def test_conv2d_kernel_sizes(k, pad):
    x = _rand(11, (1, 14, 14, 8), jnp.float32)
    w = _rand(12, (k, k, 8, 16), jnp.float32)
    np.testing.assert_allclose(
        k_conv.conv2d(x, w, padding=pad),
        ref.conv2d_ref(x, w, padding=pad),
        rtol=1e-4,
        atol=1e-4,
    )


def test_conv2d_batched():
    x = _rand(13, (4, 8, 8, 4), jnp.float32)
    w = _rand(14, (3, 3, 4, 8), jnp.float32)
    np.testing.assert_allclose(
        k_conv.conv2d(x, w), ref.conv2d_ref(x, w), rtol=1e-4, atol=1e-4
    )


def test_conv_bias_relu_nonnegative():
    x = _rand(15, (1, 8, 8, 4), jnp.float32)
    w = _rand(16, (3, 3, 4, 8), jnp.float32)
    b = _rand(17, (8,), jnp.float32)
    got = k_conv.conv2d_bias_relu(x, w, b)
    assert (np.asarray(got) >= 0).all()
    np.testing.assert_allclose(
        got, ref.conv2d_bias_relu_ref(x, w, b), rtol=1e-4, atol=1e-4
    )


@settings(**SETTINGS)
@given(h=st.sampled_from([4, 8, 12, 16]), c=st.sampled_from([1, 4, 16]))
def test_maxpool_matches_ref(h, c):
    x = _rand(h + c, (2, h, h, c), jnp.float32)
    np.testing.assert_allclose(k_conv.maxpool2(x), ref.maxpool2_ref(x))
