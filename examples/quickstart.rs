//! Quickstart: build a Table-I default constellation, run the paper's SCC
//! scheme (Alg. 1 splitting + Alg. 2 GA offloading) on VGG19 tasks, and
//! print the three §V-B metrics.
//!
//! Run: `cargo run --release --example quickstart`

use satkit::config::SimConfig;
use satkit::offload::SchemeKind;
use satkit::sim::Simulation;

fn main() {
    let cfg = SimConfig::default(); // Table I defaults: N=10, lambda=25, VGG19
    println!("{}\n", cfg.table());

    let report = Simulation::new(&cfg, SchemeKind::Scc).run();

    println!("SCC on {} tasks over {} slots:", report.total_tasks, report.slots_run);
    println!("  task completion rate : {:.2}%", 100.0 * report.completion_rate());
    println!("  total average delay  : {:.1} ms  (comp {:.1} + tran {:.1})",
        report.avg_delay_ms, report.avg_comp_ms, report.avg_tran_ms);
    println!("  workload variance    : {:.3e} MFLOP^2 (cv {:.3})",
        report.workload_variance, report.workload_cv());
    println!("\nfull report: {}", report.to_json().to_string());
}
