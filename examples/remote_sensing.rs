//! Domain scenario from the paper's introduction: remote rural areas
//! without terrestrial infrastructure generate bursts of image-analysis
//! tasks (e.g. agricultural / disaster monitoring) that must be served by
//! the constellation alone.
//!
//! Three geographically dispersed "areas" (decision satellites) see a
//! diurnal burst pattern: λ ramps 10 → 60 → 10 across the run. We compare
//! all four offloading schemes on completion rate, delay, and balance.
//!
//! Run: `cargo run --release --example remote_sensing`

use satkit::config::SimConfig;
use satkit::dnn::DnnModel;
use satkit::metrics::Report;
use satkit::offload::SchemeKind;
use satkit::sim::Simulation;

/// Piecewise-burst arrival profile (tasks per slot per area).
fn burst_lambda(phase: usize) -> f64 {
    match phase {
        0 => 10.0, // quiet morning
        1 => 60.0, // burst (disaster event / satellite pass over farmland)
        _ => 10.0, // evening tail
    }
}

fn run_phase(scheme: SchemeKind, phase: usize, seed: u64) -> Report {
    let cfg = SimConfig {
        n: 10,
        slots: 8,
        lambda: burst_lambda(phase),
        model: DnnModel::Vgg19,
        decision_fraction: 0.03, // 3 areas on a 100-sat constellation
        seed: seed + phase as u64,
        ..SimConfig::default()
    };
    Simulation::new(&cfg, scheme).with_jitter(0.2).run()
}

fn main() {
    println!("remote-sensing burst scenario: 3 rural areas, VGG19 tasks, jittered sizes");
    println!(
        "{:<8} {:>7} {:>12} {:>12} {:>12} {:>14}",
        "scheme", "phase", "lambda", "complete", "delay[ms]", "variance"
    );
    for scheme in SchemeKind::all() {
        let mut total_tasks = 0u64;
        let mut total_done = 0u64;
        for phase in 0..3 {
            let r = run_phase(scheme, phase, 42);
            total_tasks += r.total_tasks;
            total_done += r.completed_tasks;
            println!(
                "{:<8} {:>7} {:>12.0} {:>11.2}% {:>12.1} {:>14.3e}",
                scheme.name(),
                phase,
                burst_lambda(phase),
                100.0 * r.completion_rate(),
                r.avg_delay_ms,
                r.workload_variance
            );
        }
        println!(
            "{:<8} overall completion {:.2}%\n",
            scheme.name(),
            100.0 * total_done as f64 / total_tasks.max(1) as f64
        );
    }
}
