//! End-to-end driver (DESIGN.md §3 "e2e"): proves all three layers
//! compose on a real workload.
//!
//! Loads the AOT slice artifacts (L2 JAX graphs whose GEMM core is the L1
//! Pallas kernel), starts the Rust coordinator with a PJRT execution pool,
//! and serves batched DNN inference requests end-to-end: Alg. 1 splits
//! each task, Alg. 2 (SCC) picks the satellite sequence, every surviving
//! segment runs *real* inference through PJRT, and latency/throughput are
//! reported. Results recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `make artifacts && cargo run --release --example e2e_inference`

use satkit::config::SimConfig;
use satkit::coordinator::{Coordinator, InferenceRequest};
use satkit::dnn::DnnModel;
use satkit::offload::SchemeKind;
use satkit::runtime::default_artifact_dir;
use satkit::tasks::decision_satellites;
use satkit::util::rng::Pcg64;
use satkit::util::stats;

fn main() -> anyhow::Result<()> {
    let n_req: usize = std::env::var("E2E_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(32);
    let workers = std::thread::available_parallelism()
        .map(|p| p.get().min(4))
        .unwrap_or(2);

    for model in [DnnModel::Vgg19, DnnModel::Resnet101] {
        let cfg = SimConfig {
            n: 8,
            model,
            seed: 7,
            ..SimConfig::default()
        };
        println!(
            "=== e2e: {} | {} satellites | L={} D_M={} | {} PJRT workers ===",
            model.name(),
            cfg.n * cfg.n,
            cfg.effective_l(),
            cfg.effective_d_max(),
            workers
        );
        let mut coord = Coordinator::new(&cfg, &default_artifact_dir(), workers, SchemeKind::Scc)?;
        println!("artifacts: {:?}", coord.artifact_names());

        let origins = decision_satellites(cfg.n * cfg.n, cfg.decision_fraction, cfg.seed);
        let mut rng = Pcg64::new(cfg.seed, 0xE2E);
        let reqs: Vec<InferenceRequest> = (0..n_req)
            .map(|i| InferenceRequest {
                id: i as u64,
                origin: *rng.choose(&origins),
                model,
            })
            .collect();

        let t0 = std::time::Instant::now();
        let mut walls = Vec::new();
        let mut modeled = Vec::new();
        let mut dropped = 0;
        let mut checksum_ok = 0;
        for (i, r) in reqs.iter().enumerate() {
            let resp = coord.serve(r)?;
            match resp.dropped_at {
                Some(_) => dropped += 1,
                None => {
                    walls.push(resp.wall_ms);
                    modeled.push(resp.modeled_ms);
                    // checksum != 0 ⇒ real numbers flowed through PJRT
                    if resp.output_checksum.abs() > 0.0 {
                        checksum_ok += 1;
                    }
                }
            }
            if (i + 1) % 8 == 0 {
                coord.tick(); // satellites drain one service slot
            }
        }
        let wall_s = t0.elapsed().as_secs_f64();

        println!(
            "served {}/{} ({} dropped) in {:.2}s -> {:.1} req/s",
            n_req - dropped,
            n_req,
            dropped,
            wall_s,
            n_req as f64 / wall_s
        );
        println!(
            "PJRT exec latency per task: p50={:.1}ms p95={:.1}ms mean={:.1}ms",
            stats::percentile(&walls, 50.0),
            stats::percentile(&walls, 95.0),
            stats::mean(&walls)
        );
        println!(
            "modeled (Eq.5+7) delay:     p50={:.1}ms p95={:.1}ms mean={:.1}ms",
            stats::percentile(&modeled, 50.0),
            stats::percentile(&modeled, 95.0),
            stats::mean(&modeled)
        );
        println!(
            "segments executed on PJRT: {}  | outputs with non-zero checksum: {}/{}\n",
            coord
                .stats
                .segments_executed
                .load(std::sync::atomic::Ordering::Relaxed),
            checksum_ok,
            n_req - dropped
        );
        assert!(checksum_ok == n_req - dropped, "some outputs were empty");
    }
    println!("e2e OK");
    Ok(())
}
