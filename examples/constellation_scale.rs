//! Network-scale study (§V-B, last experiment): completion rate for all
//! four schemes as the constellation grows from 4×4 to 32×32 satellites
//! (> 1000 sats) at fixed λ = 25.
//!
//! Run: `cargo run --release --example constellation_scale`
//! (set SCALE_QUICK=1 for a fast pass)

use satkit::experiments::{render_panels, scale, SweepOpts};

fn main() {
    let quick = std::env::var("SCALE_QUICK").map(|v| v == "1").unwrap_or(false);
    let opts = if quick { SweepOpts::quick() } else { SweepOpts::default() };
    let ns: Vec<usize> = if quick { vec![4, 8, 16] } else { vec![4, 8, 16, 24, 32] };
    let rows = scale(&ns, &opts);
    println!("{}", render_panels("network-scale study (lambda = 25, VGG19)", &rows, "N"));
    // the paper's claim: SCC keeps its completion-rate lead beyond 32x32
    for &n in &ns {
        let get = |s: satkit::offload::SchemeKind| {
            rows.iter()
                .find(|r| r.x == n as f64 && r.scheme == s)
                .unwrap()
                .report
                .completion_rate()
        };
        let scc = get(satkit::offload::SchemeKind::Scc);
        let best_other = [
            satkit::offload::SchemeKind::Random,
            satkit::offload::SchemeKind::Rrp,
            satkit::offload::SchemeKind::Dqn,
        ]
        .into_iter()
        .map(get)
        .fold(0.0f64, f64::max);
        println!(
            "N={n:>2}: SCC {:.3} vs best baseline {:.3} ({})",
            scc,
            best_other,
            if scc >= best_other - 0.01 { "SCC leads/ties" } else { "baseline leads" }
        );
    }
}
