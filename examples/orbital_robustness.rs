//! Robustness scenario: the constellation under *realistic dynamics* —
//! gateway handover as satellites drift overhead (§III-A) and transient
//! satellite outages (radiation upsets) — plus the paper's §VI future-work
//! extension, early exit, as the mitigation knob.
//!
//! Question answered: when satellites fail mid-run, how much completion
//! does each scheme lose, and can an accuracy-for-delay trade (early exit
//! at ≥ 90 % / ≥ 80 % relative accuracy) buy the headroom back?
//!
//! Run: `cargo run --release --example orbital_robustness`

use satkit::config::SimConfig;
use satkit::dnn::{DnnModel, EarlyExitProfile};
use satkit::offload::SchemeKind;
use satkit::sim::{dynamics::Handover, Simulation};

fn base_cfg() -> SimConfig {
    SimConfig {
        n: 10,
        slots: 16,
        lambda: 55.0,
        model: DnnModel::Vgg19,
        seed: 21,
        ..SimConfig::default()
    }
}

fn main() {
    println!("=== exit branches available (VGG19) ===");
    let ee = EarlyExitProfile::for_model(DnnModel::Vgg19);
    for (i, b) in ee.branches.iter().enumerate() {
        println!(
            "branch {i}: after layer {:>2} ({})  accuracy {:.2}  saves {:.1}% of FLOPs",
            b.layer_idx,
            ee.base.layers[b.layer_idx].name,
            b.accuracy,
            100.0 * ee.saving_for_exit(i)
        );
    }

    println!("\n=== dynamics: handover + 2% per-slot outage, lambda=55 ===");
    println!(
        "{:<8} {:>12} {:>12} {:>12} {:>12}",
        "scheme", "static", "handover", "faults", "both"
    );
    for scheme in SchemeKind::all() {
        let stat = Simulation::new(&base_cfg(), scheme).run();
        let hand = Simulation::new(&base_cfg(), scheme)
            .with_handover(Handover::default())
            .run();
        let faulty = Simulation::new(&base_cfg(), scheme)
            .with_faults(0.02, 0.3)
            .run();
        let both = Simulation::new(&base_cfg(), scheme)
            .with_handover(Handover::default())
            .with_faults(0.02, 0.3)
            .run();
        println!(
            "{:<8} {:>11.2}% {:>11.2}% {:>11.2}% {:>11.2}%",
            scheme.name(),
            100.0 * stat.completion_rate(),
            100.0 * hand.completion_rate(),
            100.0 * faulty.completion_rate(),
            100.0 * both.completion_rate(),
        );
    }

    println!("\n=== early exit as mitigation (SCC, faults on) ===");
    println!(
        "{:<22} {:>10} {:>12} {:>12}",
        "policy", "accuracy", "complete", "delay[ms]"
    );
    for (label, floor) in [
        ("full model", None),
        ("exit @ >=90% acc", Some(0.90)),
        ("exit @ >=80% acc", Some(0.80)),
    ] {
        let mut sim = Simulation::new(&base_cfg(), SchemeKind::Scc).with_faults(0.02, 0.3);
        let mut acc = 1.0;
        if let Some(f) = floor {
            sim = sim.with_early_exit(f);
            acc = sim.delivered_accuracy;
        }
        let r = sim.run();
        println!(
            "{:<22} {:>10.3} {:>11.2}% {:>12.1}",
            label,
            acc,
            100.0 * r.completion_rate(),
            r.avg_delay_ms
        );
    }
}
