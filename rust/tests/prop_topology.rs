//! Property tests for the pluggable constellation topology layer
//! (`satkit::topology`): an explicit `--topology torus:<n>` reproduces
//! the legacy default decisions bit-for-bit on both engines and all four
//! schemes, the hop distance is a metric on every topology kind,
//! `neighbors`/`hops` agree, and Walker-Delta with zero phasing is
//! hop-equivalent to the torus.

use satkit::config::{EngineKind, SimConfig};
use satkit::metrics::Report;
use satkit::offload::SchemeKind;
use satkit::topology::{Constellation, TopologyKind, Torus};
use satkit::util::quickcheck::{check_no_shrink, default_cases};
use satkit::util::rng::Pcg64;

/// Compare two reports field-by-field, bit-for-bit on floats.
fn assert_reports_identical(a: &Report, b: &Report) -> Result<(), String> {
    if a.total_tasks != b.total_tasks {
        return Err(format!(
            "task counts differ: {} vs {}",
            a.total_tasks, b.total_tasks
        ));
    }
    if a.completed_tasks != b.completed_tasks {
        return Err(format!(
            "completion counts differ: {} vs {}",
            a.completed_tasks, b.completed_tasks
        ));
    }
    for (name, x, y) in [
        ("avg_delay_ms", a.avg_delay_ms, b.avg_delay_ms),
        ("avg_comp_ms", a.avg_comp_ms, b.avg_comp_ms),
        ("avg_tran_ms", a.avg_tran_ms, b.avg_tran_ms),
        ("avg_uplink_ms", a.avg_uplink_ms, b.avg_uplink_ms),
        ("workload_variance", a.workload_variance, b.workload_variance),
        ("workload_mean", a.workload_mean, b.workload_mean),
        ("delay_p50_ms", a.delay_p50_ms, b.delay_p50_ms),
        ("delay_p95_ms", a.delay_p95_ms, b.delay_p95_ms),
    ] {
        if x.to_bits() != y.to_bits() {
            return Err(format!("{name} differs: {x} vs {y}"));
        }
    }
    Ok(())
}

/// The tentpole acceptance invariant, deterministically over every
/// (engine, scheme) cell: selecting `torus:<n>` explicitly reproduces the
/// legacy default run bit-for-bit — the `Constellation` abstraction is
/// transparent on the paper path.
#[test]
fn explicit_torus_matches_default_all_engines_and_schemes() {
    for engine in EngineKind::all() {
        for scheme in SchemeKind::all() {
            let mut cfg = SimConfig {
                n: 6,
                slots: 6,
                lambda: 8.0,
                seed: 11,
                engine,
                ..SimConfig::default()
            };
            let default = satkit::engine::run(&cfg, scheme);
            cfg.topology = Some(TopologyKind::Torus { n: 6 });
            let explicit = satkit::engine::run(&cfg, scheme);
            assert_reports_identical(&default, &explicit)
                .unwrap_or_else(|e| panic!("{engine:?}/{scheme:?}: {e}"));
        }
    }
}

/// The same invariant over random (n, λ, slots, engine, scheme, seed)
/// whole-run cases, in the style of `tests/prop_staleness.rs`.
#[test]
fn prop_explicit_torus_topology_is_bit_identical_to_default() {
    check_no_shrink(
        "torus-topology-default-identical",
        default_cases().min(16),
        |r| {
            let n = *r.choose(&[4usize, 6]);
            let lambda = r.f64_in(2.0, 10.0);
            let slots = r.usize_in(3, 7);
            let engine = *r.choose(&EngineKind::all());
            let scheme = *r.choose(&[SchemeKind::Random, SchemeKind::Rrp, SchemeKind::Scc]);
            let seed = r.next_u64() % 1000;
            (n, lambda, slots, engine, scheme, seed)
        },
        |&(n, lambda, slots, engine, scheme, seed)| {
            let mut cfg = SimConfig {
                n,
                lambda,
                slots,
                seed,
                engine,
                ..SimConfig::default()
            };
            let default = satkit::engine::run(&cfg, scheme);
            cfg.topology = Some(TopologyKind::Torus { n });
            let explicit = satkit::engine::run(&cfg, scheme);
            assert_reports_identical(&default, &explicit)
        },
    );
}

fn random_constellation(r: &mut Pcg64) -> (String, Constellation) {
    match r.usize_in(0, 3) {
        0 => {
            let n = r.usize_in(2, 9);
            (format!("torus:{n}"), Constellation::torus(n))
        }
        1 => {
            let p = r.usize_in(2, 7);
            let s = r.usize_in(2, 7);
            let f = r.usize_in(0, s);
            (
                format!("walker-delta:{p}x{s}:{f}"),
                Constellation::walker_delta(p, s, f),
            )
        }
        _ => {
            let p = r.usize_in(2, 7);
            let s = r.usize_in(2, 7);
            (format!("walker-star:{p}x{s}"), Constellation::walker_star(p, s))
        }
    }
}

/// Hop distance is a metric on every topology kind: symmetric, zero
/// exactly on the diagonal, and triangle-inequal.
#[test]
fn prop_hops_is_a_metric_on_all_topologies() {
    check_no_shrink(
        "hops-metric-all-kinds",
        default_cases(),
        |r| {
            let (label, c) = random_constellation(r);
            let a = r.usize_in(0, c.len());
            let b = r.usize_in(0, c.len());
            let m = r.usize_in(0, c.len());
            (label, c, a, b, m)
        },
        |(label, c, a, b, m)| {
            let (a, b, m) = (*a, *b, *m);
            if c.hops(a, b) != c.hops(b, a) {
                return Err(format!("{label}: asymmetric at ({a},{b})"));
            }
            if (c.hops(a, b) == 0) != (a == b) {
                return Err(format!("{label}: identity violated at ({a},{b})"));
            }
            if c.hops(a, m) > c.hops(a, b) + c.hops(b, m) {
                return Err(format!("{label}: triangle violated at ({a},{b},{m})"));
            }
            Ok(())
        },
    );
}

/// `neighbors` and `hops` agree on every topology kind: every neighbour
/// is at hop distance exactly 1, every satellite at hop distance 1 is a
/// neighbour, and `neighbors4` pads only with the satellite itself.
#[test]
fn prop_neighbors_and_hops_consistent() {
    check_no_shrink(
        "neighbors-hops-consistent",
        default_cases() / 2,
        |r| {
            let (label, c) = random_constellation(r);
            let s = r.usize_in(0, c.len());
            (label, c, s)
        },
        |(label, c, s)| {
            let s = *s;
            let nbs = c.neighbors(s);
            if nbs.is_empty() || nbs.len() > 4 {
                return Err(format!("{label}: degree {} at {s}", nbs.len()));
            }
            for &nb in &nbs {
                if nb == s {
                    return Err(format!("{label}: self-loop at {s}"));
                }
                if c.hops(s, nb) != 1 {
                    return Err(format!(
                        "{label}: neighbor {nb} of {s} at hop {}",
                        c.hops(s, nb)
                    ));
                }
                if !c.neighbors(nb).contains(&s) {
                    return Err(format!("{label}: asymmetric link {s}<->{nb}"));
                }
            }
            for t in 0..c.len() {
                if c.hops(s, t) == 1 && !nbs.contains(&t) {
                    return Err(format!("{label}: {t} at hop 1 of {s} but not a neighbor"));
                }
            }
            for x in c.neighbors4(s) {
                if x != s && !nbs.contains(&x) {
                    return Err(format!("{label}: neighbors4 invented {x} at {s}"));
                }
            }
            Ok(())
        },
    );
}

/// Walker-Delta with zero phasing is the torus: identical hop distances
/// and decision spaces for every origin and radius.
#[test]
fn prop_walker_delta_zero_phasing_equals_torus_hops() {
    check_no_shrink(
        "walker-delta-f0-equals-torus",
        default_cases() / 2,
        |r| {
            let n = r.usize_in(2, 7);
            let a = r.usize_in(0, n * n);
            let b = r.usize_in(0, n * n);
            let d = r.usize_in(0, 4);
            (n, a, b, d)
        },
        |&(n, a, b, d)| {
            let t = Torus::new(n);
            let w = Constellation::walker_delta(n, n, 0);
            if w.hops(a, b) != t.manhattan(a, b) {
                return Err(format!(
                    "n={n}: walker {} != torus {} at ({a},{b})",
                    w.hops(a, b),
                    t.manhattan(a, b)
                ));
            }
            if w.decision_space(a, d) != t.decision_space(a, d) {
                return Err(format!("n={n}: decision spaces differ at ({a},{d})"));
            }
            Ok(())
        },
    );
}

/// The decision space is sound and complete against `hops` on every
/// topology kind (the 11c ball, including the origin, sorted, deduped).
#[test]
fn prop_decision_space_sound_on_all_topologies() {
    check_no_shrink(
        "decision-space-all-kinds",
        default_cases() / 2,
        |r| {
            let (label, c) = random_constellation(r);
            let x = r.usize_in(0, c.len());
            let d = r.usize_in(0, 5);
            (label, c, x, d)
        },
        |(label, c, x, d)| {
            let (x, d) = (*x, *d);
            let ds = c.decision_space(x, d);
            if !ds.contains(&x) {
                return Err(format!("{label}: origin missing"));
            }
            if !ds.windows(2).all(|p| p[0] < p[1]) {
                return Err(format!("{label}: not sorted/deduped: {ds:?}"));
            }
            for s in 0..c.len() {
                if ds.contains(&s) != (c.hops(x, s) <= d) {
                    return Err(format!("{label}: ball membership wrong at {s}"));
                }
            }
            Ok(())
        },
    );
}

/// Walker-Star hop distances respect the seam: crossing from plane 0 to
/// plane P−1 must walk P−1 inter-plane links, never one.
#[test]
fn walker_star_seam_distance() {
    for (p, s) in [(3usize, 4usize), (5, 4), (6, 3)] {
        let star = Constellation::walker_star(p, s);
        assert_eq!(star.hops(0, (p - 1) * s), p - 1, "{p}x{s}");
    }
}
