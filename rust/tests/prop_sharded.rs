//! Whole-run properties for PR 7's two perf structures: the per-plane
//! sharded event queue must leave every report **byte-identical** to the
//! single-heap engine at any shard count (the merge discipline preserves
//! the `(time, seq)` total order, so sharding can only change heap
//! balance, never event order), and the SIMD `deficit_batch` lanes must
//! be bit-for-bit equal to the per-chromosome scalar oracle — including
//! ragged batch tails that exercise the scalar tail loop.

use satkit::config::{EngineKind, GaConfig, SimConfig};
use satkit::metrics::Report;
use satkit::offload::{BatchScratch, DecisionSpaceIndex, Gene, OffloadContext, SchemeKind};
use satkit::satellite::Satellite;
use satkit::state::StateView;
use satkit::topology::Constellation;
use satkit::util::quickcheck::{check_no_shrink, default_cases};
use satkit::util::rng::Pcg64;

/// Compare two reports field-by-field, bit-for-bit on floats.
fn assert_reports_identical(a: &Report, b: &Report) -> Result<(), String> {
    if a.total_tasks != b.total_tasks {
        return Err(format!(
            "task counts differ: {} vs {}",
            a.total_tasks, b.total_tasks
        ));
    }
    if a.completed_tasks != b.completed_tasks {
        return Err(format!(
            "completion counts differ: {} vs {}",
            a.completed_tasks, b.completed_tasks
        ));
    }
    for (name, x, y) in [
        ("avg_delay_ms", a.avg_delay_ms, b.avg_delay_ms),
        ("avg_comp_ms", a.avg_comp_ms, b.avg_comp_ms),
        ("avg_tran_ms", a.avg_tran_ms, b.avg_tran_ms),
        ("avg_uplink_ms", a.avg_uplink_ms, b.avg_uplink_ms),
        ("workload_variance", a.workload_variance, b.workload_variance),
        ("workload_mean", a.workload_mean, b.workload_mean),
        ("delay_p50_ms", a.delay_p50_ms, b.delay_p50_ms),
        ("delay_p95_ms", a.delay_p95_ms, b.delay_p95_ms),
    ] {
        if x.to_bits() != y.to_bits() {
            return Err(format!("{name} differs: {x} vs {y}"));
        }
    }
    Ok(())
}

/// The tentpole acceptance invariant, deterministically over every
/// (engine, scheme, shard count) cell: pinned shard counts and the
/// auto (one-per-plane) mode all reproduce the classic single-heap run
/// bit-for-bit. The slotted engine ignores the knob, which this also
/// pins down.
#[test]
fn sharded_engine_matches_single_heap_all_engines_and_schemes() {
    for engine in EngineKind::all() {
        for scheme in SchemeKind::all() {
            let mut cfg = SimConfig {
                n: 6,
                slots: 6,
                lambda: 8.0,
                seed: 11,
                engine,
                ..SimConfig::default()
            };
            cfg.shards = 1;
            let single = satkit::engine::run(&cfg, scheme);
            for shards in [2usize, 4, 7, 0] {
                cfg.shards = shards;
                let sharded = satkit::engine::run(&cfg, scheme);
                assert_reports_identical(&single, &sharded).unwrap_or_else(|e| {
                    panic!("{engine:?}/{scheme:?} shards={shards}: {e}")
                });
            }
        }
    }
}

/// The same invariant over random (n, λ, slots, engine, scheme, shards,
/// seed) whole-run cases, in the style of `tests/prop_topology.rs`.
#[test]
fn prop_sharded_runs_are_byte_identical_to_sequential() {
    check_no_shrink(
        "sharded-engine-byte-identical",
        default_cases().min(16),
        |r| {
            let n = *r.choose(&[4usize, 6]);
            let lambda = r.f64_in(2.0, 10.0);
            let slots = r.usize_in(3, 7);
            let engine = *r.choose(&EngineKind::all());
            let scheme = *r.choose(&SchemeKind::all());
            // 0 = auto (one shard per plane); otherwise a pinned count,
            // deliberately allowed to exceed the plane count
            let shards = r.usize_in(0, 9);
            let seed = r.next_u64() % 1000;
            (n, lambda, slots, engine, scheme, shards, seed)
        },
        |&(n, lambda, slots, engine, scheme, shards, seed)| {
            let mut cfg = SimConfig {
                n,
                lambda,
                slots,
                seed,
                engine,
                ..SimConfig::default()
            };
            cfg.shards = 1;
            let single = satkit::engine::run(&cfg, scheme);
            cfg.shards = shards;
            let sharded = satkit::engine::run(&cfg, scheme);
            assert_reports_identical(&single, &sharded)
        },
    );
}

/// Bitwise `deficit_batch` vs per-chromosome `deficit` over random gene
/// batches — random L, random batch sizes **including tails** where
/// `n % 4 != 0`, random loads. Built with `--features simd` on an AVX2 /
/// NEON machine this pins the vector lanes to the scalar oracle
/// bit-for-bit; built without it, it pins the batched scalar kernel the
/// same way (the oracle contract is identical either way).
#[test]
fn prop_deficit_batch_simd_matches_scalar() {
    check_no_shrink(
        "deficit-batch-simd-bitwise",
        default_cases().min(24),
        |r| {
            let l = r.usize_in(1, 7);
            // cover every lane-tail residue for both 4-wide and 2-wide
            let n = r.usize_in(1, 20);
            let load_seed = r.next_u64();
            let gene_seed = r.next_u64();
            (l, n, load_seed, gene_seed)
        },
        |&(l, n, load_seed, gene_seed)| {
            let topo = Constellation::torus(6);
            let mut sats: Vec<Satellite> =
                (0..36).map(|i| Satellite::new(i, 3000.0, 15000.0)).collect();
            let mut lr = Pcg64::seed_from_u64(load_seed);
            for s in sats.iter_mut() {
                s.try_load(lr.f64_in(0.0, 14_000.0));
            }
            let ga = GaConfig::default();
            let cands = topo.decision_space(14, 2);
            let segments: Vec<f64> = (0..l).map(|_| lr.f64_in(500.0, 5_000.0)).collect();
            let ctx = OffloadContext {
                topo: &topo,
                view: StateView::live(&sats),
                origin: 14,
                candidates: &cands,
                segments: &segments,
                kappa: 1e-4,
                ga: &ga,
                migration: None,
                outages: None,
            };
            let index = DecisionSpaceIndex::from_ctx(&ctx);
            let mut gr = Pcg64::seed_from_u64(gene_seed);
            let flat: Vec<Gene> = (0..n * l)
                .map(|_| gr.usize_in(0, cands.len()) as Gene)
                .collect();
            let mut scratch = BatchScratch::default();
            let mut outs: Vec<f64> = Vec::new();
            index.deficit_batch(&mut scratch, &flat, &mut outs);
            if outs.len() != n {
                return Err(format!("expected {n} deficits, got {}", outs.len()));
            }
            for (i, (c, &d)) in flat.chunks(l).zip(&outs).enumerate() {
                let want = index.deficit(c);
                if d.to_bits() != want.to_bits() {
                    return Err(format!(
                        "chromosome {i}/{n} (L={l}): batch={d} scalar={want}"
                    ));
                }
            }
            Ok(())
        },
    );
}
