//! Whole-run properties for PR 8's task-kind layer: `oneshot` (and an
//! unset `task_kind`, which defaults to it) must leave every report —
//! including its serialized JSON — **byte-identical** to the
//! pre-task-kind behaviour on both engines under all four schemes;
//! autoregressive runs must conserve decode rounds exactly
//! (`completed + dropped == decode_tasks × rounds`); and the sharded
//! event queue must reproduce the single-heap `experiment llm` sweep
//! bit-for-bit, down to the `BENCH_llm.json` string.

use satkit::config::{EngineKind, SimConfig};
use satkit::experiments as exp;
use satkit::metrics::Report;
use satkit::offload::SchemeKind;
use satkit::tasks::TaskKind;
use satkit::util::quickcheck::{check_no_shrink, default_cases};

/// Whole-report equality down to the serialized byte level: any new
/// field that leaks into the default path (e.g. an `llm` block on a
/// one-shot run) shows up here even if the headline numbers agree.
fn assert_json_identical(a: &Report, b: &Report) -> Result<(), String> {
    let (ja, jb) = (a.to_json().to_string(), b.to_json().to_string());
    if ja != jb {
        // find the first divergent region so failures are readable
        let split = ja
            .bytes()
            .zip(jb.bytes())
            .position(|(x, y)| x != y)
            .unwrap_or(ja.len().min(jb.len()));
        let lo = split.saturating_sub(40);
        return Err(format!(
            "report JSON diverges at byte {split}: ...{} vs ...{}",
            &ja[lo..(split + 40).min(ja.len())],
            &jb[lo..(split + 40).min(jb.len())]
        ));
    }
    Ok(())
}

/// The tentpole acceptance invariant, deterministically over every
/// (engine, scheme) cell: an explicit `--task-kind oneshot` and an
/// unset `task_kind` produce byte-identical reports, and neither carries
/// an `llm` block.
#[test]
fn oneshot_matches_unset_all_engines_and_schemes() {
    for engine in EngineKind::all() {
        for scheme in SchemeKind::all() {
            let mut cfg = SimConfig {
                n: 6,
                slots: 6,
                lambda: 8.0,
                seed: 11,
                engine,
                ..SimConfig::default()
            };
            cfg.task_kind = None;
            let unset = satkit::engine::run(&cfg, scheme);
            cfg.task_kind = Some(TaskKind::OneShot);
            let oneshot = satkit::engine::run(&cfg, scheme);
            assert!(unset.llm.is_none(), "{engine:?}/{scheme:?}: unset run grew an llm block");
            assert!(oneshot.llm.is_none(), "{engine:?}/{scheme:?}: oneshot run grew an llm block");
            assert_json_identical(&unset, &oneshot)
                .unwrap_or_else(|e| panic!("{engine:?}/{scheme:?}: {e}"));
        }
    }
}

/// The same invariant over random (n, λ, slots, engine, scheme, seed)
/// whole-run cases, in the style of `tests/prop_sharded.rs`.
#[test]
fn prop_oneshot_unset_byte_identical() {
    check_no_shrink(
        "taskkind-oneshot-unset-byte-identical",
        default_cases().min(12),
        |r| {
            let n = *r.choose(&[4usize, 6]);
            let lambda = r.f64_in(2.0, 10.0);
            let slots = r.usize_in(3, 7);
            let engine = *r.choose(&EngineKind::all());
            let scheme = *r.choose(&SchemeKind::all());
            let seed = r.next_u64() % 1000;
            (n, lambda, slots, engine, scheme, seed)
        },
        |&(n, lambda, slots, engine, scheme, seed)| {
            let mut cfg = SimConfig {
                n,
                lambda,
                slots,
                seed,
                engine,
                ..SimConfig::default()
            };
            cfg.task_kind = None;
            let unset = satkit::engine::run(&cfg, scheme);
            cfg.task_kind = Some(TaskKind::OneShot);
            let oneshot = satkit::engine::run(&cfg, scheme);
            if unset.llm.is_some() || oneshot.llm.is_some() {
                return Err("one-shot run produced an llm block".into());
            }
            assert_json_identical(&unset, &oneshot)
        },
    );
}

/// Round conservation over random autoregressive workloads on both
/// engines: every task that enters the decode phase accounts for exactly
/// `rounds` rounds between `rounds_completed` and `rounds_dropped`, and
/// a run that decodes at all carries the `llm` block. Running this under
/// `cargo test` (debug assertions on) also sweeps the event engine's
/// slab-arena hygiene check — the live-task arena must drain to empty
/// even when decode rounds outlive the arrival horizon.
#[test]
fn prop_autoregressive_rounds_conserve() {
    check_no_shrink(
        "taskkind-round-conservation",
        default_cases().min(12),
        |r| {
            let lambda = r.f64_in(2.0, 8.0);
            let slots = r.usize_in(3, 6);
            let engine = *r.choose(&EngineKind::all());
            let scheme = *r.choose(&SchemeKind::all());
            let rounds = r.usize_in(1, 9) as u32;
            // escalation on half the cases; threshold 0 escalates at
            // once, larger values may never trigger — both are legal
            let escalate = if r.next_u64() % 2 == 0 {
                Some(r.f64_in(0.0, 0.5))
            } else {
                None
            };
            let seed = r.next_u64() % 1000;
            (lambda, slots, engine, scheme, rounds, escalate, seed)
        },
        |&(lambda, slots, engine, scheme, rounds, escalate, seed)| {
            let mut cfg = SimConfig {
                n: 6,
                lambda,
                slots,
                seed,
                engine,
                ..SimConfig::default()
            };
            cfg.task_kind = Some(TaskKind::Autoregressive {
                rounds,
                decode_flops: cfg.llm.decode_flops,
                state_bytes: cfg.llm.state_bytes,
                escalate,
            });
            let report = satkit::engine::run(&cfg, scheme);
            let Some(l) = &report.llm else {
                // a run may legitimately decode nothing (every task
                // dropped in the chain phase) — then no block either
                return Ok(());
            };
            let expect = l.decode_tasks * rounds as u64;
            if l.rounds_completed + l.rounds_dropped != expect {
                return Err(format!(
                    "round leak: {} completed + {} dropped != {} tasks × {} rounds",
                    l.rounds_completed, l.rounds_dropped, l.decode_tasks, rounds
                ));
            }
            if l.decode_tasks > report.total_tasks {
                return Err(format!(
                    "{} decode tasks exceed {} generated",
                    l.decode_tasks, report.total_tasks
                ));
            }
            Ok(())
        },
    );
}

/// The full `experiment llm` sweep is byte-identical between the classic
/// single-heap event queue and the per-plane sharded queue — compared on
/// the serialized `BENCH_llm.json` payload, so the round-level metrics
/// (not just headline counts) are pinned.
#[test]
fn sharded_llm_sweep_matches_single_heap() {
    let kinds = exp::llm_kind_grid(&[3]);
    let mut opts = exp::SweepOpts::quick();
    opts.engine = EngineKind::Event;
    opts.threads = 1;
    opts.shards = 1;
    let single = exp::llm_sweep(satkit::dnn::DnnModel::Vgg19, 10.0, &kinds, &opts);
    let single_json =
        exp::llm_json(satkit::dnn::DnnModel::Vgg19, 10.0, EngineKind::Event, true, &single)
            .to_string();
    for shards in [4usize, 0] {
        opts.shards = shards;
        let sharded = exp::llm_sweep(satkit::dnn::DnnModel::Vgg19, 10.0, &kinds, &opts);
        let sharded_json =
            exp::llm_json(satkit::dnn::DnnModel::Vgg19, 10.0, EngineKind::Event, true, &sharded)
                .to_string();
        assert_eq!(
            single_json, sharded_json,
            "shards={shards}: BENCH_llm.json payload diverged from single-heap"
        );
    }
}
