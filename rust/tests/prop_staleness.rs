//! Property tests for the resource-state dissemination subsystem
//! (`satkit::state`): the defaults preserve each engine's pre-existing
//! behaviour bit-for-bit, the slotted `T_d = 1` slot special case equals
//! the legacy local-view snapshot, and staleness actually changes (and
//! never improves) what the schemes decide under load.

use satkit::config::{EngineKind, SimConfig};
use satkit::metrics::Report;
use satkit::offload::SchemeKind;
use satkit::satellite::Satellite;
use satkit::state::{DisseminationKind, ViewTracker};
use satkit::topology::Torus;
use satkit::util::quickcheck::{check_no_shrink, default_cases};
use satkit::util::rng::Pcg64;

/// Compare two reports field-by-field, bit-for-bit on floats.
fn assert_reports_identical(a: &Report, b: &Report) -> Result<(), String> {
    if a.total_tasks != b.total_tasks {
        return Err(format!("task counts differ: {} vs {}", a.total_tasks, b.total_tasks));
    }
    if a.completed_tasks != b.completed_tasks {
        return Err(format!(
            "completion counts differ: {} vs {}",
            a.completed_tasks, b.completed_tasks
        ));
    }
    for (name, x, y) in [
        ("avg_delay_ms", a.avg_delay_ms, b.avg_delay_ms),
        ("avg_comp_ms", a.avg_comp_ms, b.avg_comp_ms),
        ("avg_tran_ms", a.avg_tran_ms, b.avg_tran_ms),
        ("avg_uplink_ms", a.avg_uplink_ms, b.avg_uplink_ms),
        ("workload_variance", a.workload_variance, b.workload_variance),
        ("workload_mean", a.workload_mean, b.workload_mean),
        ("delay_p50_ms", a.delay_p50_ms, b.delay_p50_ms),
        ("delay_p95_ms", a.delay_p95_ms, b.delay_p95_ms),
    ] {
        if x.to_bits() != y.to_bits() {
            return Err(format!("{name} differs: {x} vs {y}"));
        }
    }
    Ok(())
}

fn random_case(r: &mut Pcg64) -> (usize, f64, usize, SchemeKind, u64) {
    let n = *r.choose(&[4usize, 6]);
    let lambda = r.f64_in(2.0, 12.0);
    let slots = r.usize_in(3, 9);
    let scheme = *r.choose(&[SchemeKind::Random, SchemeKind::Rrp, SchemeKind::Scc]);
    let seed = r.next_u64() % 1000;
    (n, lambda, slots, scheme, seed)
}

/// `--dissemination instant` reproduces the event engine's default (=
/// pre-dissemination) decisions bit-for-bit per seed: the view layer is
/// transparent when staleness is zero.
#[test]
fn prop_event_engine_instant_equals_default() {
    check_no_shrink(
        "event-instant-equals-default",
        default_cases().min(20),
        random_case,
        |&(n, lambda, slots, scheme, seed)| {
            let mut cfg = SimConfig {
                n,
                lambda,
                slots,
                seed,
                engine: EngineKind::Event,
                ..SimConfig::default()
            };
            let default = satkit::engine::run(&cfg, scheme);
            cfg.dissemination = Some(DisseminationKind::Instant);
            let instant = satkit::engine::run(&cfg, scheme);
            assert_reports_identical(&default, &instant)
        },
    );
}

/// `T_d = 1` slot in the slotted engine is behaviour-identical to the
/// legacy slot-start snapshot path (the engine's default).
#[test]
fn prop_slotted_engine_slot_period_equals_default() {
    check_no_shrink(
        "slotted-slot-period-equals-default",
        default_cases().min(20),
        random_case,
        |&(n, lambda, slots, scheme, seed)| {
            let mut cfg = SimConfig {
                n,
                lambda,
                slots,
                seed,
                engine: EngineKind::Slotted,
                ..SimConfig::default()
            };
            let default = satkit::engine::run(&cfg, scheme);
            cfg.dissemination = Some(DisseminationKind::Periodic { period_s: 1.0 });
            let explicit = satkit::engine::run(&cfg, scheme);
            assert_reports_identical(&default, &explicit)
        },
    );
}

/// The `ViewTracker` at `T_d = 1` slot reproduces the legacy slotted
/// local-view mechanism exactly: a per-batch `clone_from` of live state
/// plus the origin's own admission-gated placements. The shadow here IS
/// that legacy mechanism (a `Vec<Satellite>` driven by `try_load`), and
/// every observed load must match it bit-for-bit at every step.
#[test]
fn prop_tracker_slot_period_equals_legacy_local_view() {
    check_no_shrink(
        "tracker-equals-legacy-local-view",
        default_cases().min(60),
        |r| {
            let n = r.usize_in(3, 6);
            let slots = r.usize_in(1, 5);
            let seed = r.next_u64();
            (n, slots, seed)
        },
        |&(n, slots, seed)| {
            let torus = Torus::new(n);
            let n_sats = torus.len();
            let mut rng = Pcg64::seed_from_u64(seed);
            let mut live: Vec<Satellite> = (0..n_sats)
                .map(|i| Satellite::new(i, 3000.0, 15_000.0))
                .collect();
            let n_areas = rng.usize_in(1, 4);
            let mut tracker = ViewTracker::new(
                DisseminationKind::Periodic { period_s: 1.0 },
                n_sats,
                n_areas,
                2,
            );
            let mut shadow: Vec<Satellite> = live.clone();
            for slot in 0..slots {
                tracker.advance_to(slot as f64);
                for area in 0..n_areas {
                    tracker.sync_batch(area, &live);
                    shadow.clone_from(&live); // the legacy per-batch snapshot
                    let tasks = rng.usize_in(0, 4);
                    for _ in 0..tasks {
                        let l = rng.usize_in(1, 4);
                        let placements: Vec<(usize, f64)> = (0..l)
                            .map(|_| (rng.usize_in(0, n_sats), rng.f64_in(0.0, 9000.0)))
                            .collect();
                        for &(c, q) in &placements {
                            if q > 0.0 {
                                let _ = shadow[c].try_load(q);
                            }
                            tracker.record_local(area, c, q, slot as f64, &live);
                        }
                        let view = tracker.view(area, &live);
                        for (s, sat) in shadow.iter().enumerate() {
                            if view.loaded(s).to_bits() != sat.loaded().to_bits() {
                                return Err(format!(
                                    "slot {slot} area {area} sat {s}: view {} != legacy {}",
                                    view.loaded(s),
                                    sat.loaded()
                                ));
                            }
                        }
                        // ground truth moves on (execution), unseen by the
                        // frozen views until the next batch sync
                        for &(c, q) in &placements {
                            if q > 0.0 {
                                let _ = live[c].try_load(q);
                            }
                        }
                    }
                }
                for s in live.iter_mut() {
                    s.service_slot();
                }
            }
            Ok(())
        },
    );
}

/// Staleness must not change the arrival process (dissemination only
/// affects decisions), and under contention it must actually change the
/// event engine's behaviour.
#[test]
fn staleness_changes_decisions_but_not_arrivals() {
    let mut cfg = SimConfig {
        n: 6,
        slots: 12,
        lambda: 40.0,
        seed: 11,
        decision_fraction: 0.2,
        engine: EngineKind::Event,
        ..SimConfig::default()
    };
    cfg.satellite.max_workload_mflops = 60_000.0;
    cfg.dissemination = Some(DisseminationKind::Instant);
    let fresh = satkit::engine::run(&cfg, SchemeKind::Scc);
    cfg.dissemination = Some(DisseminationKind::Periodic { period_s: 2.0 });
    let stale = satkit::engine::run(&cfg, SchemeKind::Scc);
    assert!(fresh.total_tasks > 0);
    // identical arrival stream: thinning draws never depend on decisions
    assert_eq!(fresh.total_tasks, stale.total_tasks);
    // but the decisions (and with them completions or delays) moved
    assert!(
        fresh.completed_tasks != stale.completed_tasks
            || fresh.avg_delay_ms.to_bits() != stale.avg_delay_ms.to_bits(),
        "a 2s-stale view changed nothing at lambda=40"
    );
}

/// The §V-B herding direction: deciding on stale state must not *improve*
/// SCC's completion rate under contention — and each dissemination model
/// stays deterministic per seed.
#[test]
fn stale_state_does_not_improve_scc_and_stays_deterministic() {
    let mut cfg = SimConfig {
        n: 6,
        slots: 12,
        lambda: 40.0,
        seed: 7,
        decision_fraction: 0.2,
        engine: EngineKind::Event,
        ..SimConfig::default()
    };
    cfg.satellite.max_workload_mflops = 60_000.0;
    cfg.dissemination = Some(DisseminationKind::Instant);
    let fresh = satkit::engine::run(&cfg, SchemeKind::Scc);
    cfg.dissemination = Some(DisseminationKind::Periodic { period_s: 4.0 });
    let stale_a = satkit::engine::run(&cfg, SchemeKind::Scc);
    let stale_b = satkit::engine::run(&cfg, SchemeKind::Scc);
    assert_reports_identical(&stale_a, &stale_b).expect("stale run not deterministic");
    assert!(
        stale_a.completion_rate() <= fresh.completion_rate() + 0.05,
        "stale views should not beat fresh ones: stale {:.4} vs fresh {:.4}",
        stale_a.completion_rate(),
        fresh.completion_rate()
    );
}

/// Gossip dissemination runs clean on both engines and conserves tasks.
#[test]
fn gossip_runs_on_both_engines() {
    for engine in EngineKind::all() {
        let cfg = SimConfig {
            n: 6,
            slots: 8,
            lambda: 10.0,
            seed: 3,
            engine,
            dissemination: Some(DisseminationKind::Gossip { tick_s: 0.5 }),
            ..SimConfig::default()
        };
        let r = satkit::engine::run(&cfg, SchemeKind::Scc);
        assert!(r.total_tasks > 0, "{engine:?}");
        assert_eq!(r.total_tasks, r.completed_tasks + r.dropped_tasks, "{engine:?}");
    }
}
