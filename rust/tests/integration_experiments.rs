//! Integration over the experiment harness: every figure's generator
//! produces complete, structurally valid row sets (quick settings).

use satkit::dnn::DnnModel;
use satkit::experiments as exp;
use satkit::offload::SchemeKind;

fn quick() -> exp::SweepOpts {
    exp::SweepOpts {
        slots: 4,
        seed: 11,
        decision_fraction: 0.15,
        repeats: 1,
        ..exp::SweepOpts::default()
    }
}

#[test]
fn fig2_rows_complete_grid() {
    let rows = exp::lambda_sweep(DnnModel::Resnet101, &[4.0, 25.0], &quick());
    assert_eq!(rows.len(), 8);
    for s in SchemeKind::all() {
        assert_eq!(rows.iter().filter(|r| r.scheme == s).count(), 2);
    }
    for r in &rows {
        assert!(r.report.total_tasks > 0);
        assert!(r.report.completion_rate() <= 1.0);
    }
}

#[test]
fn fig3_rows_complete_grid() {
    let rows = exp::lambda_sweep(DnnModel::Vgg19, &[10.0], &quick());
    assert_eq!(rows.len(), 4);
}

#[test]
fn scale_rows_cover_all_ns() {
    let rows = exp::scale(&[4, 6], &quick());
    assert_eq!(rows.len(), 8);
    let xs: Vec<f64> = rows.iter().map(|r| r.x).collect();
    assert!(xs.contains(&4.0) && xs.contains(&6.0));
}

#[test]
fn render_and_json_roundtrip() {
    let rows = exp::lambda_sweep(DnnModel::Vgg19, &[10.0], &quick());
    let table = exp::render_panels("t", &rows, "lambda");
    assert!(table.contains("SCC") && table.contains("DQN"));
    let json = exp::rows_to_json(&rows).to_string();
    let parsed = satkit::util::json::Json::parse(&json).unwrap();
    let arr = parsed.as_arr().unwrap();
    assert_eq!(arr.len(), 4);
    for row in arr {
        assert!(row.get("scheme").is_some());
        assert!(row.get("completion_rate").unwrap().as_f64().unwrap() <= 1.0);
    }
}

#[test]
fn ablations_produce_rows() {
    let split = exp::ablation_split(DnnModel::Vgg19, &[15.0], &quick());
    assert_eq!(split.len(), 1);
    let ga = exp::ablation_ga(&[1, 5], &quick());
    assert_eq!(ga.len(), 2);
    // more GA iterations should not make the objective worse
    // (weak check, quick settings are noisy)
    assert!(ga[1].1.completion_rate() >= ga[0].1.completion_rate() - 0.15);
}
