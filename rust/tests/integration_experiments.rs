//! Integration over the experiment harness: every figure's generator
//! produces complete, structurally valid row sets (quick settings), and
//! the parallel sweep runner is byte-identical to a sequential run.

use satkit::config::EngineKind;
use satkit::dnn::DnnModel;
use satkit::experiments as exp;
use satkit::offload::SchemeKind;

fn quick() -> exp::SweepOpts {
    exp::SweepOpts {
        slots: 4,
        seed: 11,
        decision_fraction: 0.15,
        repeats: 1,
        ..exp::SweepOpts::default()
    }
}

#[test]
fn fig2_rows_complete_grid() {
    let rows = exp::lambda_sweep(DnnModel::Resnet101, &[4.0, 25.0], &quick());
    assert_eq!(rows.len(), 8);
    for s in SchemeKind::all() {
        assert_eq!(rows.iter().filter(|r| r.scheme == s).count(), 2);
    }
    for r in &rows {
        assert!(r.report.total_tasks > 0);
        assert!(r.report.completion_rate() <= 1.0);
    }
}

#[test]
fn fig3_rows_complete_grid() {
    let rows = exp::lambda_sweep(DnnModel::Vgg19, &[10.0], &quick());
    assert_eq!(rows.len(), 4);
}

#[test]
fn scale_rows_cover_all_ns() {
    let rows = exp::scale(&[4, 6], &quick());
    assert_eq!(rows.len(), 8);
    let xs: Vec<f64> = rows.iter().map(|r| r.x).collect();
    assert!(xs.contains(&4.0) && xs.contains(&6.0));
}

#[test]
fn render_and_json_roundtrip() {
    let rows = exp::lambda_sweep(DnnModel::Vgg19, &[10.0], &quick());
    let table = exp::render_panels("t", &rows, "lambda");
    assert!(table.contains("SCC") && table.contains("DQN"));
    let json = exp::rows_to_json(&rows).to_string();
    let parsed = satkit::util::json::Json::parse(&json).unwrap();
    let arr = parsed.as_arr().unwrap();
    assert_eq!(arr.len(), 4);
    for row in arr {
        assert!(row.get("scheme").is_some());
        assert!(row.get("completion_rate").unwrap().as_f64().unwrap() <= 1.0);
    }
}

#[test]
fn parallel_sweep_rows_match_sequential() {
    // the whole-run property of the parallel runner: fanning the cells
    // over worker threads must serialize to the SAME bytes as the forced
    // single-thread run — row order, every float bit, everything.
    let mut seq = quick();
    seq.engine = EngineKind::Event;
    seq.threads = 1;
    let mut par = seq.clone();
    par.threads = 4;
    let a = exp::eventsim_sweep(
        DnnModel::Vgg19,
        &[4.0, 25.0],
        satkit::config::ScenarioKind::Poisson,
        &seq,
    );
    let b = exp::eventsim_sweep(
        DnnModel::Vgg19,
        &[4.0, 25.0],
        satkit::config::ScenarioKind::Poisson,
        &par,
    );
    assert_eq!(
        exp::rows_to_json(&a).to_string(),
        exp::rows_to_json(&b).to_string(),
        "parallel eventsim sweep diverged from sequential"
    );

    // same property through the staleness sweep's JSON artifact path
    let rows_seq = exp::staleness_sweep(DnnModel::Vgg19, 10.0, &[1.0], &seq);
    let rows_par = exp::staleness_sweep(DnnModel::Vgg19, 10.0, &[1.0], &par);
    let ja = exp::staleness_json(DnnModel::Vgg19, 10.0, EngineKind::Event, true, &rows_seq);
    let jb = exp::staleness_json(DnnModel::Vgg19, 10.0, EngineKind::Event, true, &rows_par);
    assert_eq!(
        ja.to_string(),
        jb.to_string(),
        "parallel staleness sweep diverged from sequential"
    );
}

#[test]
fn per_repeat_dispatch_rows_match_sequential() {
    // PR 7 satellite: with repeats > 1 the sweep runner dispatches
    // individual (cell, repeat) pairs to the pool instead of whole
    // cells. The flattened fan-out must still serialize to the SAME
    // bytes as a forced single-thread run — the per-repeat seeds
    // (seed + r·1000) and the averaging order are position-derived, so
    // thread count can change nothing.
    let mut seq = quick();
    seq.engine = EngineKind::Event;
    seq.threads = 1;
    seq.repeats = 3;
    let mut par = seq.clone();
    par.threads = 4;
    let a = exp::eventsim_sweep(
        DnnModel::Vgg19,
        &[4.0, 25.0],
        satkit::config::ScenarioKind::Poisson,
        &seq,
    );
    let b = exp::eventsim_sweep(
        DnnModel::Vgg19,
        &[4.0, 25.0],
        satkit::config::ScenarioKind::Poisson,
        &par,
    );
    assert_eq!(
        exp::rows_to_json(&a).to_string(),
        exp::rows_to_json(&b).to_string(),
        "per-repeat dispatch diverged from sequential"
    );
    // and the repeat axis really was averaged in: a repeats=1 run of the
    // same grid must differ (distinct seeds feed the mean)
    let mut one = seq.clone();
    one.repeats = 1;
    let c = exp::eventsim_sweep(
        DnnModel::Vgg19,
        &[4.0, 25.0],
        satkit::config::ScenarioKind::Poisson,
        &one,
    );
    assert_ne!(
        exp::rows_to_json(&a).to_string(),
        exp::rows_to_json(&c).to_string(),
        "repeats=3 rows should not equal a single-repeat run"
    );
}

#[test]
fn run_cells_preserves_input_order_and_runs_every_cell() {
    // order is by input index, not completion time: staggered workloads
    // would reorder under a completion-order merge
    let items: Vec<usize> = (0..37).collect();
    let out = exp::run_cells(4, items.clone(), |i| {
        if i % 5 == 0 {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        i * 10
    });
    assert_eq!(out, items.iter().map(|i| i * 10).collect::<Vec<_>>());
    // degenerate worker counts
    assert_eq!(exp::run_cells(1, vec![3usize, 1, 2], |i| i + 1), vec![4, 2, 3]);
    assert_eq!(exp::run_cells(64, vec![7usize], |i| i), vec![7]);
    let empty: Vec<usize> = Vec::new();
    assert_eq!(exp::run_cells(0, empty, |i: usize| i), Vec::<usize>::new());
}

#[test]
fn ablations_produce_rows() {
    let split = exp::ablation_split(DnnModel::Vgg19, &[15.0], &quick());
    assert_eq!(split.len(), 1);
    let ga = exp::ablation_ga(&[1, 5], &quick());
    assert_eq!(ga.len(), 2);
    // more GA iterations should not make the objective worse
    // (weak check, quick settings are noisy)
    assert!(ga[1].1.completion_rate() >= ga[0].1.completion_rate() - 0.15);
}
