//! Whole-run properties for PR 10's resilience layer: `--recovery drop`
//! (and an unset `[resilience]` block, which defaults to it) must leave
//! every report — including its serialized JSON — **byte-identical** to
//! the pre-resilience behaviour on both engines under all four schemes;
//! a recovery-on run under satellite faults must complete strictly more
//! tasks than the legacy drop policy; and the Bernoulli fault schedule
//! both engines consume must be bit-for-bit reproducible from the seed,
//! with scripted trace windows layered on as a pure overlay.

use satkit::config::{EngineKind, SimConfig};
use satkit::metrics::Report;
use satkit::offload::SchemeKind;
use satkit::resilience::{FaultTrace, RecoveryPolicy};
use satkit::sim::dynamics::FaultInjector;
use satkit::util::quickcheck::{check_no_shrink, default_cases};

/// Whole-report equality down to the serialized byte level: any new
/// field that leaks into the default path (e.g. a `resilience` block on
/// a fault-free run) shows up here even if the headline numbers agree.
fn assert_json_identical(a: &Report, b: &Report) -> Result<(), String> {
    let (ja, jb) = (a.to_json().to_string(), b.to_json().to_string());
    if ja != jb {
        // find the first divergent region so failures are readable
        let split = ja
            .bytes()
            .zip(jb.bytes())
            .position(|(x, y)| x != y)
            .unwrap_or(ja.len().min(jb.len()));
        let lo = split.saturating_sub(40);
        return Err(format!(
            "report JSON diverges at byte {split}: ...{} vs ...{}",
            &ja[lo..(split + 40).min(ja.len())],
            &jb[lo..(split + 40).min(jb.len())]
        ));
    }
    Ok(())
}

/// The tentpole acceptance invariant, deterministically over every
/// (engine, scheme) cell: an explicit `--recovery drop` and a fully
/// unset `[resilience]` block produce byte-identical reports, and
/// neither grows a `resilience` block.
#[test]
fn drop_matches_unset_all_engines_and_schemes() {
    for engine in EngineKind::all() {
        for scheme in SchemeKind::all() {
            let mut cfg = SimConfig {
                n: 6,
                slots: 6,
                lambda: 8.0,
                seed: 11,
                engine,
                ..SimConfig::default()
            };
            let unset = satkit::engine::run(&cfg, scheme);
            cfg.resilience.recovery = RecoveryPolicy::Drop;
            let dropped = satkit::engine::run(&cfg, scheme);
            assert!(
                unset.resilience.is_none(),
                "{engine:?}/{scheme:?}: unset run grew a resilience block"
            );
            assert!(
                dropped.resilience.is_none(),
                "{engine:?}/{scheme:?}: drop run grew a resilience block"
            );
            assert_json_identical(&unset, &dropped)
                .unwrap_or_else(|e| panic!("{engine:?}/{scheme:?}: {e}"));
        }
    }
}

/// The same invariant over random (n, λ, slots, engine, scheme, seed)
/// whole-run cases, in the style of `tests/prop_taskkind.rs`.
#[test]
fn prop_drop_unset_byte_identical() {
    check_no_shrink(
        "resilience-drop-unset-byte-identical",
        default_cases().min(12),
        |r| {
            let n = *r.choose(&[4usize, 6]);
            let lambda = r.f64_in(2.0, 10.0);
            let slots = r.usize_in(3, 7);
            let engine = *r.choose(&EngineKind::all());
            let scheme = *r.choose(&SchemeKind::all());
            let seed = r.next_u64() % 1000;
            (n, lambda, slots, engine, scheme, seed)
        },
        |&(n, lambda, slots, engine, scheme, seed)| {
            let mut cfg = SimConfig {
                n,
                lambda,
                slots,
                seed,
                engine,
                ..SimConfig::default()
            };
            let unset = satkit::engine::run(&cfg, scheme);
            cfg.resilience.recovery = RecoveryPolicy::Drop;
            let dropped = satkit::engine::run(&cfg, scheme);
            if unset.resilience.is_some() || dropped.resilience.is_some() {
                return Err("fault-free run produced a resilience block".into());
            }
            assert_json_identical(&unset, &dropped)
        },
    );
}

/// The headline robustness claim (and the sweep gate's invariant): under
/// a heavy Bernoulli satellite-fault process on the event engine,
/// switching `--recovery` from `drop` to `reoffload` strictly increases
/// the number of completed tasks, summed across all four schemes, and
/// the recovery runs actually exercise the retry machinery. The event
/// engine is the acceptance target because a mid-chain fault interrupts
/// an in-flight task there — the slotted engine's recovery hook (Eq. 4
/// admission rejection) perturbs scheme-internal RNG draws, so its
/// per-seed ordering is asserted more weakly in `src/sim/mod.rs` tests.
#[test]
fn reoffload_beats_drop_under_faults_event() {
    let mut on_total = 0u64;
    let mut off_total = 0u64;
    let mut recovered = 0u64;
    for scheme in SchemeKind::all() {
        let mut cfg = SimConfig {
            n: 6,
            slots: 20,
            lambda: 10.0,
            seed: 7,
            engine: EngineKind::Event,
            ..SimConfig::default()
        };
        cfg.resilience.p_fail = 0.12;
        cfg.resilience.p_recover = 0.5;
        cfg.resilience.recovery = RecoveryPolicy::Drop;
        let off = satkit::engine::run(&cfg, scheme);
        cfg.resilience.recovery = RecoveryPolicy::Reoffload { max_retries: 2 };
        let on = satkit::engine::run(&cfg, scheme);
        assert_eq!(
            on.total_tasks, off.total_tasks,
            "{scheme:?}: recovery policy changed the arrival process"
        );
        on_total += on.completed_tasks;
        off_total += off.completed_tasks;
        recovered += on
            .resilience
            .as_ref()
            .map_or(0, |res| res.recovered_tasks);
    }
    assert!(
        on_total > off_total,
        "reoffload completed {on_total} <= drop's {off_total}"
    );
    assert!(recovered > 0, "no task ever recovered");
}

/// Cross-engine fault equivalence (the satellite task): the Bernoulli
/// schedule is a pure function of (n, p_fail, p_recover, seed), so two
/// injectors stepped independently — one via the slotted engine's
/// `step_at(t)` at integer ticks, one via the legacy `step()` — realize
/// bit-for-bit identical outage sets, and layering a scripted trace on
/// one of them is a pure overlay: `is_down == bernoulli || window`.
#[test]
fn prop_fault_schedule_engine_equivalent() {
    check_no_shrink(
        "resilience-fault-schedule-equivalence",
        default_cases().min(12),
        |r| {
            let n = r.usize_in(4, 12);
            let p_fail = r.f64_in(0.01, 0.3);
            let p_recover = r.f64_in(0.1, 0.8);
            let ticks = r.usize_in(5, 15);
            let seed = r.next_u64() % 10_000;
            (n, p_fail, p_recover, ticks, seed)
        },
        |&(n, p_fail, p_recover, ticks, seed)| {
            let trace = FaultTrace::parse_str("2 5 sat:1\n4 9 sat:3\n")
                .map_err(|e| format!("trace: {e}"))?;
            let mut slotted = FaultInjector::new(n, p_fail, p_recover, seed);
            let mut event = FaultInjector::new(n, p_fail, p_recover, seed);
            let mut traced = FaultInjector::new(n, p_fail, p_recover, seed);
            traced.set_trace(trace.clone());
            for tick in 0..ticks {
                let t = tick as f64;
                let a = slotted.step_at(t);
                let b = event.step();
                traced.step_at(t);
                if a != b {
                    return Err(format!(
                        "tick {tick}: step_at reported {a:?} newly failed, step reported {b:?}"
                    ));
                }
                for s in 0..n {
                    if slotted.is_down(s) != event.is_down(s) {
                        return Err(format!(
                            "tick {tick}: sat {s} down={} via step_at, {} via step",
                            slotted.is_down(s),
                            event.is_down(s)
                        ));
                    }
                    let want = slotted.is_down(s) || trace.sat_down_at(s, t);
                    if traced.is_down(s) != want {
                        return Err(format!(
                            "tick {tick}: sat {s} traced down={} but bernoulli||window={want}",
                            traced.is_down(s)
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// A scripted trace with both satellite and link windows drives full
/// runs on both engines without violating task conservation, and a
/// whole-run repeat is deterministic (same seed, same trace, same JSON).
#[test]
fn scripted_trace_runs_deterministic_on_both_engines() {
    let trace = FaultTrace::parse_str("1 4 sat:2\n2 6 link:0-1\n3 5 sat:0\n").unwrap();
    for engine in EngineKind::all() {
        let mut cfg = SimConfig {
            n: 6,
            slots: 10,
            lambda: 12.0,
            seed: 21,
            engine,
            ..SimConfig::default()
        };
        cfg.resilience.fault_trace = Some(trace.clone());
        cfg.resilience.recovery = RecoveryPolicy::Reoffload { max_retries: 2 };
        let a = satkit::engine::run(&cfg, SchemeKind::Scc);
        let b = satkit::engine::run(&cfg, SchemeKind::Scc);
        assert_eq!(
            a.completed_tasks + a.dropped_tasks,
            a.total_tasks,
            "{engine:?}: trace run lost tasks"
        );
        assert_json_identical(&a, &b)
            .unwrap_or_else(|e| panic!("{engine:?}: trace run not deterministic: {e}"));
    }
}
