//! Cross-module integration tests: simulator + splitting + all offloading
//! schemes, checking the *qualitative* claims of §V-B that the benches
//! quantify (SCC completion ≥ baselines under pressure, SCC variance ≈
//! Random ≪ RRP, delays ordered sensibly).

use satkit::config::SimConfig;
use satkit::dnn::DnnModel;
use satkit::metrics::Report;
use satkit::offload::SchemeKind;
use satkit::sim::{Simulation, SplitPolicy};

fn cfg(model: DnnModel, lambda: f64, seed: u64) -> SimConfig {
    SimConfig {
        n: 8,
        slots: 12,
        lambda,
        model,
        seed,
        ..SimConfig::default()
    }
}

fn run(model: DnnModel, lambda: f64, kind: SchemeKind, seed: u64) -> Report {
    Simulation::new(&cfg(model, lambda, seed), kind).run()
}

#[test]
fn conservation_total_equals_completed_plus_dropped() {
    for kind in SchemeKind::all() {
        let r = run(DnnModel::Vgg19, 15.0, kind, 1);
        assert_eq!(r.total_tasks, r.completed_tasks + r.dropped_tasks, "{kind:?}");
    }
}

#[test]
fn scc_completion_at_least_baselines_high_load() {
    // paper Fig 2(a)/3(a): SCC keeps the highest completion rate when the
    // incidence is high. Average over 3 seeds to kill flakiness.
    let mut rates = std::collections::HashMap::new();
    for kind in SchemeKind::all() {
        let mean: f64 = (0..3)
            .map(|s| run(DnnModel::Vgg19, 45.0, kind, 10 + s).completion_rate())
            .sum::<f64>()
            / 3.0;
        rates.insert(kind.name(), mean);
    }
    let scc = rates["SCC"];
    for (name, r) in &rates {
        assert!(
            scc >= r - 0.02,
            "SCC ({scc:.3}) should not trail {name} ({r:.3}) meaningfully: {rates:?}"
        );
    }
}

#[test]
fn scc_variance_not_worse_than_rrp() {
    // paper Fig 2(c)/3(c): RRP herds onto the fittest satellites; SCC with
    // balanced splitting stays near Random's (ideal) spread.
    let scc: f64 = (0..3)
        .map(|s| run(DnnModel::Vgg19, 30.0, SchemeKind::Scc, 20 + s).workload_variance)
        .sum::<f64>()
        / 3.0;
    let rrp: f64 = (0..3)
        .map(|s| run(DnnModel::Vgg19, 30.0, SchemeKind::Rrp, 20 + s).workload_variance)
        .sum::<f64>()
        / 3.0;
    assert!(
        scc <= rrp * 1.5,
        "SCC variance {scc:.3e} should not blow past RRP {rrp:.3e}"
    );
}

#[test]
fn delay_grows_with_incidence() {
    // Fig 2(b)/3(b): delay increases with lambda for every method
    for kind in [SchemeKind::Scc, SchemeKind::Rrp] {
        let lo = run(DnnModel::Resnet101, 5.0, kind, 30);
        let hi = run(DnnModel::Resnet101, 50.0, kind, 30);
        if lo.completed_tasks > 0 && hi.completed_tasks > 0 {
            assert!(
                hi.avg_delay_ms >= lo.avg_delay_ms * 0.8,
                "{kind:?}: delay at lambda=50 ({:.1}) should not collapse below lambda=5 ({:.1})",
                hi.avg_delay_ms,
                lo.avg_delay_ms
            );
        }
    }
}

#[test]
fn resnet_uses_l4_vgg_l3() {
    let r_v = run(DnnModel::Vgg19, 5.0, SchemeKind::Random, 2);
    let r_r = run(DnnModel::Resnet101, 5.0, SchemeKind::Random, 2);
    // encoded via drop_point domain: completed tasks have dp = L+1
    assert!(r_v.total_tasks > 0 && r_r.total_tasks > 0);
}

#[test]
fn balanced_split_improves_completion_over_naive() {
    // the Alg. 1 ablation, as an invariant: balanced splitting should not
    // lose to naive equal-layer cuts under pressure (VGG19's fc-heavy tail
    // makes naive splits badly unbalanced).
    let c = cfg(DnnModel::Vgg19, 35.0, 3);
    let bal = Simulation::new(&c, SchemeKind::Scc)
        .with_split_policy(SplitPolicy::Balanced)
        .run();
    let naive = Simulation::new(&c, SchemeKind::Scc)
        .with_split_policy(SplitPolicy::NaiveEqualLayers)
        .run();
    assert!(
        bal.completion_rate() >= naive.completion_rate() - 0.02,
        "balanced {:.3} vs naive {:.3}",
        bal.completion_rate(),
        naive.completion_rate()
    );
}

#[test]
fn dqn_improves_over_training() {
    // first-half vs second-half completion: the online learner should not
    // degrade (weak monotonicity, tolerant of noise)
    let mut c = cfg(DnnModel::Vgg19, 25.0, 4);
    c.slots = 6;
    let early = Simulation::new(&c, SchemeKind::Dqn).run();
    c.slots = 18;
    let late = Simulation::new(&c, SchemeKind::Dqn).run();
    assert!(late.completion_rate() >= early.completion_rate() - 0.10);
}

#[test]
fn zero_lambda_runs_clean() {
    let mut c = cfg(DnnModel::Vgg19, 0.0, 5);
    c.slots = 3;
    let r = Simulation::new(&c, SchemeKind::Scc).run();
    assert_eq!(r.total_tasks, 0);
    assert_eq!(r.completion_rate(), 1.0);
}

#[test]
fn tiny_constellation_n2() {
    let mut c = cfg(DnnModel::Vgg19, 3.0, 6);
    c.n = 2;
    for kind in SchemeKind::all() {
        let r = Simulation::new(&c, kind).run();
        assert!(r.total_tasks > 0, "{kind:?} on N=2");
    }
}

#[test]
fn capacity_starvation_drops_everything_eventually() {
    let mut c = cfg(DnnModel::Vgg19, 30.0, 7);
    // M_w below the largest segment: nothing can ever be admitted
    c.satellite.max_workload_mflops = 10.0;
    let r = Simulation::new(&c, SchemeKind::Random).run();
    assert_eq!(r.completed_tasks, 0);
    assert!(r.drop_rate() > 0.99);
}
