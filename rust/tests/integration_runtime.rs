//! Integration tests over the PJRT runtime: load every AOT artifact,
//! execute it from Rust, and verify numerics against the python-side
//! probe checksums recorded in the meta sidecars (cross-language parity:
//! the SAME graph, lowered once, must produce the same numbers through
//! jax and through PJRT-from-Rust).
//!
//! Requires `make artifacts`. Tests are skipped (with a notice) if the
//! artifact directory is missing so `cargo test` works pre-build.

use std::path::{Path, PathBuf};

use satkit::runtime::{default_artifact_dir, Engine, ExecPool};

/// Every artifact these tests load; a partial build must skip too, or
/// the `.unwrap()`s below turn an interrupted `make artifacts` into red.
const REQUIRED_ARTIFACTS: [&str; 4] = ["classifier", "qnet", "resnet_slice", "vgg_slice"];

/// True iff `dir` holds the complete compiled artifact set.
fn has_hlo_artifacts(dir: &Path) -> bool {
    REQUIRED_ARTIFACTS
        .iter()
        .all(|name| dir.join(format!("{name}.hlo.txt")).exists())
}

/// Gate for every PJRT/HLO-dependent test below: returns the artifact
/// directory, or `None` (after printing a clear skip notice) when the
/// `artifacts/*.hlo.txt` set is absent or incomplete — a bare checkout
/// keeps `cargo test -q` green without the Python AOT step.
fn artifact_dir() -> Option<PathBuf> {
    let dir = default_artifact_dir();
    if has_hlo_artifacts(&dir) {
        return Some(dir);
    }
    // tests run from the crate root; also probe ../artifacts
    let alt = Path::new("artifacts").to_path_buf();
    if has_hlo_artifacts(&alt) {
        return Some(alt);
    }
    eprintln!(
        "SKIP: artifacts/*.hlo.txt missing or incomplete (need {REQUIRED_ARTIFACTS:?}) — \
         run `make artifacts` to enable the PJRT runtime tests"
    );
    None
}

/// The deterministic probe of python/compile/aot.py: (i % 13) * 0.1.
fn probe(n: usize) -> Vec<f32> {
    (0..n).map(|i| (i % 13) as f32 * 0.1).collect()
}

#[test]
fn loads_all_four_artifacts() {
    let Some(dir) = artifact_dir() else { return };
    let mut e = Engine::cpu().unwrap();
    let names = e.load_dir(&dir).unwrap();
    assert_eq!(
        names,
        vec!["classifier", "qnet", "resnet_slice", "vgg_slice"]
    );
    assert_eq!(e.platform(), "cpu");
}

#[test]
fn probe_checksums_match_python() {
    let Some(dir) = artifact_dir() else { return };
    let mut e = Engine::cpu().unwrap();
    let names = e.load_dir(&dir).unwrap();
    for name in names {
        let art = e.get(&name).unwrap();
        // read the python-side fixture
        let meta_text =
            std::fs::read_to_string(dir.join(format!("{name}.meta.json"))).unwrap();
        let j = satkit::util::json::Json::parse(&meta_text).unwrap();
        let want: Vec<f64> = j
            .get("probe_checksums")
            .and_then(|c| c.as_arr())
            .expect("probe_checksums in meta (re-run make artifacts)")
            .iter()
            .map(|x| x.as_f64().unwrap())
            .collect();
        let inputs: Vec<Vec<f32>> = art
            .meta
            .inputs
            .iter()
            .map(|s| probe(s.num_elements()))
            .collect();
        let out = art.run_f32(&inputs).unwrap();
        assert_eq!(out.len(), want.len(), "{name}: output arity");
        for (o, w) in out.iter().zip(&want) {
            let got: f64 = o.iter().map(|x| *x as f64).sum();
            let tol = 1e-3 * w.abs().max(1.0);
            assert!(
                (got - w).abs() < tol,
                "{name}: rust checksum {got} != python {w}"
            );
        }
    }
}

#[test]
fn output_shapes_match_meta() {
    let Some(dir) = artifact_dir() else { return };
    let mut e = Engine::cpu().unwrap();
    for name in e.load_dir(&dir).unwrap() {
        let art = e.get(&name).unwrap();
        let inputs: Vec<Vec<f32>> = art
            .meta
            .inputs
            .iter()
            .map(|s| probe(s.num_elements()))
            .collect();
        let out = art.run_f32(&inputs).unwrap();
        for (o, spec) in out.iter().zip(&art.meta.outputs) {
            assert_eq!(o.len(), spec.num_elements(), "{name} output shape");
        }
    }
}

#[test]
fn rejects_wrong_input_shapes() {
    let Some(dir) = artifact_dir() else { return };
    let mut e = Engine::cpu().unwrap();
    e.load(&dir, "qnet").unwrap();
    // wrong element count
    assert!(e.run_f32("qnet", &[vec![0.0; 7]]).is_err());
    // wrong arity
    assert!(e.run_f32("qnet", &[vec![0.0; 256], vec![0.0; 3]]).is_err());
    // unknown artifact
    assert!(e.run_f32("nope", &[vec![]]).is_err());
}

#[test]
fn qnet_is_deterministic_across_engines() {
    let Some(dir) = artifact_dir() else { return };
    let run = |dir: &Path| {
        let mut e = Engine::cpu().unwrap();
        e.load(dir, "qnet").unwrap();
        e.run_f32("qnet", &[probe(256)]).unwrap()
    };
    assert_eq!(run(&dir), run(&dir));
}

#[test]
fn exec_pool_parallel_executions_agree() {
    let Some(dir) = artifact_dir() else { return };
    let pool = ExecPool::new(&dir, 3).unwrap();
    assert_eq!(pool.size(), 3);
    assert!(pool.artifact_names().contains(&"vgg_slice".to_string()));
    let input = probe(56 * 56 * 64);
    // fire 9 concurrent executions, all must agree
    let rxs: Vec<_> = (0..9)
        .map(|_| pool.submit("vgg_slice", vec![input.clone()]))
        .collect();
    let results: Vec<Vec<Vec<f32>>> =
        rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
    for r in &results[1..] {
        assert_eq!(r, &results[0]);
    }
}

#[test]
fn coordinator_end_to_end_smoke() {
    let Some(dir) = artifact_dir() else { return };
    use satkit::config::SimConfig;
    use satkit::coordinator::{Coordinator, InferenceRequest};
    use satkit::dnn::DnnModel;
    use satkit::offload::SchemeKind;

    let cfg = SimConfig {
        n: 4,
        ..SimConfig::default()
    };
    let mut coord = Coordinator::new(&cfg, &dir, 2, SchemeKind::Scc).unwrap();
    let resp = coord
        .serve(&InferenceRequest {
            id: 1,
            origin: 5,
            model: DnnModel::Vgg19,
        })
        .unwrap();
    assert!(resp.dropped_at.is_none());
    assert_eq!(resp.sequence.len(), cfg.effective_l());
    assert!(resp.output_checksum.abs() > 0.0, "real compute must flow");
    assert!(resp.wall_ms > 0.0);
    assert!(resp.modeled_ms > 0.0);
    coord.tick();
    assert_eq!(
        coord
            .stats
            .segments_executed
            .load(std::sync::atomic::Ordering::Relaxed),
        cfg.effective_l() as u64
    );
}
