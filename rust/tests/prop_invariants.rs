//! Property-based tests (via the util::quickcheck substrate) on the
//! invariants of the core algorithms: Alg. 1 splitting, Eq. 12 deficit,
//! the GA reproduction operator, topology metrics, and Eq. 4 admission.

use satkit::config::GaConfig;
use satkit::offload::{
    ga::GaScheme, make_scheme, BatchScratch, DecisionSpaceIndex, DeficitScratch, Gene,
    OffloadContext, OffloadScheme, SchemeKind,
};
use satkit::satellite::Satellite;
use satkit::splitting::{balanced_split, naive_equal_layers, split_with_limit};
use satkit::state::StateView;
use satkit::topology::{Constellation, Torus};
use satkit::util::quickcheck::{check, check_no_shrink, default_cases, shrink_f64_vec};
use satkit::util::rng::Pcg64;

fn gen_workloads(r: &mut Pcg64) -> Vec<f64> {
    let n = r.usize_in(1, 40);
    (0..n).map(|_| r.f64_in(0.0, 500.0)).collect()
}

// ---------------------------------------------------------- Algorithm 1

#[test]
fn prop_split_is_valid_partition() {
    check(
        "split-valid-partition",
        default_cases(),
        gen_workloads,
        |w| {
            let l = 1 + (w.len() - 1) % 7.min(w.len());
            let res = balanced_split(w, l, 0.5);
            if res.blocks.len() != l {
                return Err(format!("{} blocks != L={l}", res.blocks.len()));
            }
            let mut pos = 0usize;
            for b in &res.blocks {
                if !b.is_empty() {
                    if b.start != pos {
                        return Err(format!("gap at {pos}"));
                    }
                    pos = b.end;
                }
            }
            if pos != w.len() {
                return Err("layers not covered".into());
            }
            let total: f64 = w.iter().sum();
            let got: f64 = res.blocks.iter().map(|b| b.workload).sum();
            if (total - got).abs() > 1e-6 * total.max(1.0) {
                return Err(format!("workload leak: {total} vs {got}"));
            }
            Ok(())
        },
        shrink_f64_vec,
    );
}

#[test]
fn prop_split_minmax_never_worse_than_naive() {
    check(
        "split-beats-naive",
        default_cases(),
        gen_workloads,
        |w| {
            let l = 1 + (w.len() * 3) % 5.min(w.len());
            let bal = balanced_split(w, l, 1e-6).max_block_workload();
            let naive = naive_equal_layers(w, l).max_block_workload();
            if bal <= naive + 1e-6 {
                Ok(())
            } else {
                Err(format!("balanced {bal} > naive {naive} (L={l})"))
            }
        },
        shrink_f64_vec,
    );
}

#[test]
fn prop_split_lower_bound_max_layer() {
    // no partition can have max block < max layer
    check(
        "split-lower-bound",
        default_cases(),
        gen_workloads,
        |w| {
            let l = 1 + w.len() % 4.min(w.len());
            let res = balanced_split(w, l, 1e-6);
            let maxw = w.iter().cloned().fold(0.0, f64::max);
            if res.max_block_workload() >= maxw - 1e-9 {
                Ok(())
            } else {
                Err(format!(
                    "max block {} below max layer {maxw}",
                    res.max_block_workload()
                ))
            }
        },
        shrink_f64_vec,
    );
}

#[test]
fn prop_split_block_count_monotone_in_limit() {
    check_no_shrink(
        "split-monotone",
        default_cases() / 2,
        |r| {
            let w = gen_workloads(r);
            let a = r.f64_in(0.0, 1.0);
            let b = r.f64_in(0.0, 1.0);
            (w, a.min(b), a.max(b))
        },
        |(w, lo_frac, hi_frac)| {
            let total: f64 = w.iter().sum();
            let maxw = w.iter().cloned().fold(0.0, f64::max);
            let lim_lo = maxw + lo_frac * (total - maxw);
            let lim_hi = maxw + hi_frac * (total - maxw);
            let n_lo = split_with_limit(w, lim_lo).len();
            let n_hi = split_with_limit(w, lim_hi).len();
            if n_hi <= n_lo {
                Ok(())
            } else {
                Err(format!("limit {lim_lo}->{n_lo} blocks, {lim_hi}->{n_hi}"))
            }
        },
    );
}

// ------------------------------------------------------------- topology

#[test]
fn prop_manhattan_is_a_metric() {
    check_no_shrink(
        "manhattan-metric",
        default_cases(),
        |r| {
            let n = r.usize_in(2, 20);
            let t = Torus::new(n);
            let a = r.usize_in(0, t.len());
            let b = r.usize_in(0, t.len());
            let c = r.usize_in(0, t.len());
            (n, a, b, c)
        },
        |&(n, a, b, c)| {
            let t = Torus::new(n);
            if t.manhattan(a, b) != t.manhattan(b, a) {
                return Err("asymmetric".into());
            }
            if (t.manhattan(a, b) == 0) != (a == b) {
                return Err("identity violated".into());
            }
            if t.manhattan(a, c) > t.manhattan(a, b) + t.manhattan(b, c) {
                return Err("triangle violated".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_decision_space_sound_and_complete() {
    check_no_shrink(
        "decision-space",
        default_cases(),
        |r| {
            let n = r.usize_in(2, 16);
            let x = r.usize_in(0, n * n);
            let d = r.usize_in(0, 5);
            (n, x, d)
        },
        |&(n, x, d)| {
            let t = Torus::new(n);
            let ds = t.decision_space(x, d);
            if !ds.contains(&x) {
                return Err("origin missing".into());
            }
            for &s in &ds {
                if t.manhattan(x, s) > d {
                    return Err(format!("sat {s} outside ball"));
                }
            }
            for s in 0..t.len() {
                if t.manhattan(x, s) <= d && !ds.contains(&s) {
                    return Err(format!("sat {s} inside ball but missing"));
                }
            }
            let mut u = ds.clone();
            u.dedup();
            if u.len() != ds.len() {
                return Err("duplicates".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_shortest_path_realizes_manhattan() {
    check_no_shrink(
        "shortest-path",
        default_cases(),
        |r| {
            let n = r.usize_in(2, 16);
            let t = Torus::new(n);
            (n, r.usize_in(0, t.len()), r.usize_in(0, t.len()))
        },
        |&(n, a, b)| {
            let t = Torus::new(n);
            let p = t.shortest_path(a, b);
            if p.len() != t.manhattan(a, b) {
                return Err(format!("path len {} != MH {}", p.len(), t.manhattan(a, b)));
            }
            let mut prev = a;
            for &h in &p {
                if t.manhattan(prev, h) != 1 {
                    return Err("non-adjacent hop".into());
                }
                prev = h;
            }
            Ok(())
        },
    );
}

// ----------------------------------------------------- schemes & deficit

#[derive(Clone)]
struct Instance {
    n: usize,
    loads: Vec<f64>,
    segments: Vec<f64>,
    origin: usize,
    d_max: usize,
}

impl std::fmt::Debug for Instance {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Instance(n={}, origin={}, d_max={}, segs={:?})",
            self.n, self.origin, self.d_max, self.segments
        )
    }
}

fn gen_instance(r: &mut Pcg64) -> Instance {
    let n = r.usize_in(3, 10);
    let loads = (0..n * n).map(|_| r.f64_in(0.0, 14_000.0)).collect();
    let l = r.usize_in(1, 6);
    let segments = (0..l).map(|_| r.f64_in(0.0, 6_000.0)).collect();
    Instance {
        n,
        loads,
        segments,
        origin: r.usize_in(0, n * n),
        d_max: r.usize_in(1, 4),
    }
}

fn build_sats(inst: &Instance) -> Vec<Satellite> {
    inst.loads
        .iter()
        .enumerate()
        .map(|(i, &q)| {
            let mut s = Satellite::new(i, 3000.0, 15_000.0);
            if q > 0.0 {
                s.try_load(q.min(14_999.0));
            }
            s
        })
        .collect()
}

#[test]
fn prop_all_schemes_emit_valid_chromosomes() {
    check_no_shrink(
        "schemes-valid-chromosomes",
        default_cases() / 4,
        gen_instance,
        |inst| {
            let topo = Constellation::torus(inst.n);
            let sats = build_sats(inst);
            let cands = topo.decision_space(inst.origin, inst.d_max);
            let ga = GaConfig {
                n_iter: 3,
                ..GaConfig::default()
            };
            let ctx = OffloadContext {
                topo: &topo,
                view: StateView::live(&sats),
                origin: inst.origin,
                candidates: &cands,
                segments: &inst.segments,
                kappa: 1e-4,
                ga: &ga,
                migration: None,
                outages: None,
            };
            for kind in SchemeKind::all() {
                let mut s = make_scheme(kind, 99);
                let chrom = s.decide(&ctx);
                if chrom.len() != inst.segments.len() {
                    return Err(format!("{kind:?}: wrong length"));
                }
                if !chrom.iter().all(|c| cands.contains(c)) {
                    return Err(format!("{kind:?}: out-of-space sat in {chrom:?}"));
                }
                // constraint 11c explicitly
                for &c in &chrom {
                    if topo.hops(inst.origin, c) > inst.d_max {
                        return Err(format!("{kind:?}: 11c violated"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_deficit_nonnegative_and_theta_monotone() {
    check_no_shrink(
        "deficit-monotone",
        default_cases() / 2,
        gen_instance,
        |inst| {
            let topo = Constellation::torus(inst.n);
            let sats = build_sats(inst);
            let cands = topo.decision_space(inst.origin, inst.d_max);
            let mut rng = Pcg64::seed_from_u64(5);
            let chrom: Vec<usize> = (0..inst.segments.len())
                .map(|_| *rng.choose(&cands))
                .collect();
            let mk = |t1: f64, t2: f64, t3: f64| GaConfig {
                theta1: t1,
                theta2: t2,
                theta3: t3,
                ..GaConfig::default()
            };
            let d = |ga: &GaConfig| {
                let ctx = OffloadContext {
                    topo: &topo,
                    view: StateView::live(&sats),
                    origin: inst.origin,
                    candidates: &cands,
                    segments: &inst.segments,
                    kappa: 1e-4,
                    ga,
                    migration: None,
                    outages: None,
                };
                ctx.deficit(&chrom)
            };
            let base = d(&mk(1.0, 20.0, 1e6));
            if base < 0.0 {
                return Err("negative deficit".into());
            }
            // doubling any theta must not decrease the deficit
            for ga2 in [mk(2.0, 20.0, 1e6), mk(1.0, 40.0, 1e6), mk(1.0, 20.0, 2e6)] {
                if d(&ga2) + 1e-9 < base {
                    return Err("deficit decreased when a weight grew".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_indexed_deficit_matches_reference() {
    // the tentpole invariant: the indexed kernel (hop LUT + cached arrays,
    // plain and incremental paths) equals the reference Eq. 12 deficit to
    // 1e-12 on random topologies/loads/chromosomes — in fact bit-for-bit
    // between its own paths.
    check_no_shrink(
        "indexed-deficit-matches-reference",
        default_cases(),
        |r| {
            let inst = gen_instance(r);
            let raw: Vec<u64> = (0..16).map(|_| r.next_u64()).collect();
            (inst, raw)
        },
        |(inst, raw)| {
            let topo = Constellation::torus(inst.n);
            let sats = build_sats(inst);
            let cands = topo.decision_space(inst.origin, inst.d_max);
            let ga = GaConfig::default();
            let ctx = OffloadContext {
                topo: &topo,
                view: StateView::live(&sats),
                origin: inst.origin,
                candidates: &cands,
                segments: &inst.segments,
                kappa: 1e-4,
                ga: &ga,
                migration: None,
                outages: None,
            };
            let index = DecisionSpaceIndex::from_ctx(&ctx);
            let mut scratch = DeficitScratch::default();
            let l = inst.segments.len();
            let mut genes: Vec<Gene> = (0..l)
                .map(|k| (raw[k % raw.len()] as usize % cands.len()) as Gene)
                .collect();
            for step in 0..6 {
                let mut chrom = Vec::new();
                index.decode_into(&genes, &mut chrom);
                let want = ctx.deficit(&chrom);
                let got = index.deficit(&genes);
                if (got - want).abs() > 1e-12 * want.abs().max(1.0) {
                    return Err(format!(
                        "indexed {got} != reference {want} for {chrom:?}"
                    ));
                }
                let inc = index.deficit_with(&mut scratch, &genes);
                if inc.to_bits() != got.to_bits() {
                    return Err(format!(
                        "incremental {inc} != plain {got} at step {step}"
                    ));
                }
                // mutate one gene so later rounds exercise the delta path
                let pos = raw[(2 * step) % raw.len()] as usize % l;
                genes[pos] = (raw[(2 * step + 1) % raw.len()] as usize % cands.len()) as Gene;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_deficit_batch_matches_scalar() {
    // the batched whole-generation kernel must agree with the scalar
    // indexed kernel bit for bit, chromosome by chromosome, for any
    // generation size — including memo-free duplicates within a batch.
    check_no_shrink(
        "deficit-batch-bitwise",
        default_cases() / 2,
        |r| {
            let inst = gen_instance(r);
            let n = r.usize_in(1, 33);
            let raw: Vec<u64> = (0..n * inst.segments.len().max(1))
                .map(|_| r.next_u64())
                .collect();
            (inst, n, raw)
        },
        |(inst, n, raw)| {
            let topo = Constellation::torus(inst.n);
            let sats = build_sats(inst);
            let cands = topo.decision_space(inst.origin, inst.d_max);
            let ga = GaConfig::default();
            let ctx = OffloadContext {
                topo: &topo,
                view: StateView::live(&sats),
                origin: inst.origin,
                candidates: &cands,
                segments: &inst.segments,
                kappa: 1e-4,
                ga: &ga,
                migration: None,
                outages: None,
            };
            let index = DecisionSpaceIndex::from_ctx(&ctx);
            let l = inst.segments.len();
            let mut flat: Vec<Gene> = raw
                .iter()
                .map(|&x| (x as usize % cands.len()) as Gene)
                .collect();
            flat.truncate(n * l);
            // force a duplicated chromosome when the batch has >= 2 rows
            if *n >= 2 {
                let (head, tail) = flat.split_at_mut(l);
                tail[..l].copy_from_slice(head);
            }
            let mut scratch = BatchScratch::default();
            let mut out = Vec::new();
            index.deficit_batch(&mut scratch, &flat, &mut out);
            if out.len() != *n {
                return Err(format!("{} outputs for {n} chromosomes", out.len()));
            }
            for (chrom, &got) in flat.chunks(l).zip(&out) {
                let want = index.deficit(chrom);
                if got.to_bits() != want.to_bits() {
                    return Err(format!(
                        "batched {got} != scalar {want} for {chrom:?}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_index_cache_preserves_decisions() {
    // ROADMAP follow-up (PR 2): `build_cached` reuses the per-origin
    // index across consecutive decisions when origin, candidate set, and
    // observed view are unchanged — and the cached path must stay
    // bit-for-bit identical to a fresh build. A changed load must miss.
    check_no_shrink(
        "index-cache-bit-identical",
        default_cases() / 4,
        |r| {
            let inst = gen_instance(r);
            let raw: Vec<u64> = (0..8).map(|_| r.next_u64()).collect();
            (inst, raw)
        },
        |(inst, raw)| {
            let topo = Constellation::torus(inst.n);
            let mut sats = build_sats(inst);
            let cands = topo.decision_space(inst.origin, inst.d_max);
            let ga = GaConfig::default();
            let l = inst.segments.len();
            let genes: Vec<Gene> = (0..l)
                .map(|k| (raw[k % raw.len()] as usize % cands.len()) as Gene)
                .collect();
            let mut cached = DecisionSpaceIndex::new();
            let (fresh_deficit, cached_deficit) = {
                let ctx = OffloadContext {
                    topo: &topo,
                    view: StateView::live(&sats),
                    origin: inst.origin,
                    candidates: &cands,
                    segments: &inst.segments,
                    kappa: 1e-4,
                    ga: &ga,
                    migration: None,
                    outages: None,
                };
                if cached.build_cached(&ctx) {
                    return Err("first build reported a hit".into());
                }
                if !cached.build_cached(&ctx) {
                    return Err("identical rebuild missed the cache".into());
                }
                if (cached.cache_hits(), cached.cache_misses()) != (1, 1) {
                    return Err(format!(
                        "counters: {} hits / {} misses, want 1/1",
                        cached.cache_hits(),
                        cached.cache_misses()
                    ));
                }
                let fresh = DecisionSpaceIndex::from_ctx(&ctx);
                (fresh.deficit(&genes), cached.deficit(&genes))
            };
            if cached_deficit.to_bits() != fresh_deficit.to_bits() {
                return Err(format!(
                    "cached {cached_deficit} != fresh {fresh_deficit}"
                ));
            }
            // a load change on any candidate must invalidate the cache
            sats[cands[0]].try_load(1.0);
            let ctx2 = OffloadContext {
                topo: &topo,
                view: StateView::live(&sats),
                origin: inst.origin,
                candidates: &cands,
                segments: &inst.segments,
                kappa: 1e-4,
                ga: &ga,
                migration: None,
                outages: None,
            };
            if cached.build_cached(&ctx2) {
                return Err("stale cache hit after a load change".into());
            }
            let fresh2 = DecisionSpaceIndex::from_ctx(&ctx2);
            let (a, b) = (cached.deficit(&genes), fresh2.deficit(&genes));
            if a.to_bits() != b.to_bits() {
                return Err(format!("post-miss cached {a} != fresh {b}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ga_decide_identical_to_reference_per_seed() {
    // bit-for-bit decision preservation across the kernel swap: the
    // indexed GA and the retained paper-literal oracle must return the
    // identical chromosome for every seed, including across repeated
    // decisions that exercise buffer recycling and memo clearing.
    check_no_shrink(
        "ga-indexed-equals-reference",
        default_cases() / 8,
        |r| (gen_instance(r), r.next_u64() % 1_000_000),
        |(inst, seed)| {
            let topo = Constellation::torus(inst.n);
            let sats = build_sats(inst);
            let cands = topo.decision_space(inst.origin, inst.d_max);
            let ga = GaConfig {
                n_iter: 4,
                ..GaConfig::default()
            };
            let ctx = OffloadContext {
                topo: &topo,
                view: StateView::live(&sats),
                origin: inst.origin,
                candidates: &cands,
                segments: &inst.segments,
                kappa: 1e-4,
                ga: &ga,
                migration: None,
                outages: None,
            };
            let mut fast = GaScheme::new(*seed);
            let mut slow = GaScheme::new(*seed);
            for round in 0..2 {
                let a = fast.decide(&ctx);
                let b = slow.decide_reference(&ctx);
                if a != b {
                    return Err(format!(
                        "seed {seed} round {round}: indexed {a:?} != reference {b:?}"
                    ));
                }
            }
            // round 2 decided on an unchanged context: the per-origin
            // index cache must have served it without a rebuild — and the
            // loop above just proved the cached decision is bit-for-bit
            // the reference one.
            if fast.index_cache_stats() != (1, 1) {
                return Err(format!(
                    "index cache stats {:?}, want (1 hit, 1 miss)",
                    fast.index_cache_stats()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ga_close_to_random_best() {
    // sanity envelope: the GA result should never be grossly worse than
    // the best of an equal-budget random population
    check_no_shrink(
        "ga-vs-random-envelope",
        default_cases() / 16,
        gen_instance,
        |inst| {
            let topo = Constellation::torus(inst.n);
            let sats = build_sats(inst);
            let cands = topo.decision_space(inst.origin, inst.d_max);
            let ga = GaConfig::default();
            let ctx = OffloadContext {
                topo: &topo,
                view: StateView::live(&sats),
                origin: inst.origin,
                candidates: &cands,
                segments: &inst.segments,
                kappa: 1e-4,
                ga: &ga,
                migration: None,
                outages: None,
            };
            let mut g = GaScheme::new(7);
            let got = ctx.deficit(&g.decide(&ctx));
            let mut rng = Pcg64::seed_from_u64(8);
            let mut best = f64::INFINITY;
            for _ in 0..ga.n_ini {
                let chrom: Vec<usize> = (0..inst.segments.len())
                    .map(|_| *rng.choose(&cands))
                    .collect();
                best = best.min(ctx.deficit(&chrom));
            }
            if got <= best * 3.0 + 1e3 {
                Ok(())
            } else {
                Err(format!("GA deficit {got} far above random-best {best}"))
            }
        },
    );
}

// ------------------------------------------------------------ satellite

#[test]
fn prop_admission_monotone_in_load() {
    check_no_shrink(
        "admission-monotone",
        default_cases(),
        |r| (r.f64_in(0.0, 20_000.0), r.f64_in(0.0, 20_000.0)),
        |&(pre, m)| {
            let mut lo = Satellite::new(0, 3000.0, 15_000.0);
            let mut hi = Satellite::new(0, 3000.0, 15_000.0);
            if pre > 0.0 && pre < 15_000.0 {
                hi.try_load(pre);
            }
            // if the more-loaded satellite admits m, the empty one must too
            if hi.would_admit(m) && !lo.would_admit(m) {
                return Err("monotonicity violated".into());
            }
            let _ = (lo.try_load(m), hi.try_load(m));
            Ok(())
        },
    );
}

// -------------------------------------------------------- event kernel

#[test]
fn prop_event_queue_equal_times_pop_in_insertion_order() {
    use satkit::eventsim::queue::EventQueue;
    check_no_shrink(
        "event-queue-fifo-ties",
        default_cases(),
        |r| {
            // times drawn from a tiny bucket set to force many ties
            let n = r.usize_in(1, 60);
            (0..n)
                .map(|_| r.usize_in(0, 4) as f64 * 0.5)
                .collect::<Vec<f64>>()
        },
        |times| {
            let mut q = EventQueue::new();
            for (i, &t) in times.iter().enumerate() {
                q.push(t, i);
            }
            let mut popped: Vec<(f64, usize)> = Vec::with_capacity(times.len());
            while let Some(e) = q.pop() {
                popped.push(e);
            }
            if popped.len() != times.len() {
                return Err(format!("lost events: {} of {}", popped.len(), times.len()));
            }
            for w in popped.windows(2) {
                let ((t0, i0), (t1, i1)) = (w[0], w[1]);
                if t1 < t0 {
                    return Err(format!("time order violated: {t0} before {t1}"));
                }
                if t0 == t1 && i1 < i0 {
                    return Err(format!(
                        "tie at t={t0} popped out of insertion order: {i0} before {i1}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_eventsim_same_seed_identical_reports() {
    use satkit::config::{EngineKind, ScenarioKind, SimConfig};
    use satkit::offload::SchemeKind;

    // full engine runs are costly; a few dozen random cases still cover
    // the (scenario, scheme, size) space well
    let cases = default_cases().min(32);
    check_no_shrink(
        "eventsim-deterministic",
        cases,
        |r| {
            let n = *r.choose(&[4usize, 6]);
            let lambda = r.f64_in(1.0, 12.0);
            let slots = r.usize_in(3, 9);
            let scenario = *r.choose(&ScenarioKind::all());
            let scheme = *r.choose(&[SchemeKind::Random, SchemeKind::Rrp, SchemeKind::Scc]);
            let seed = r.next_u64() % 1000;
            (n, lambda, slots, scenario, scheme, seed)
        },
        |&(n, lambda, slots, scenario, scheme, seed)| {
            let cfg = SimConfig {
                n,
                lambda,
                slots,
                seed,
                scenario,
                engine: EngineKind::Event,
                ..SimConfig::default()
            };
            let a = satkit::engine::run(&cfg, scheme);
            let b = satkit::engine::run(&cfg, scheme);
            if a.total_tasks != b.total_tasks {
                return Err(format!("task counts differ: {} vs {}", a.total_tasks, b.total_tasks));
            }
            if a.completed_tasks != b.completed_tasks {
                return Err("completion counts differ".into());
            }
            for (name, x, y) in [
                ("avg_delay_ms", a.avg_delay_ms, b.avg_delay_ms),
                ("avg_comp_ms", a.avg_comp_ms, b.avg_comp_ms),
                ("avg_tran_ms", a.avg_tran_ms, b.avg_tran_ms),
                ("avg_uplink_ms", a.avg_uplink_ms, b.avg_uplink_ms),
                ("workload_variance", a.workload_variance, b.workload_variance),
                ("workload_mean", a.workload_mean, b.workload_mean),
                ("delay_p95_ms", a.delay_p95_ms, b.delay_p95_ms),
            ] {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("{name} differs: {x} vs {y}"));
                }
            }
            Ok(())
        },
    );
}
