//! Config-surface error paths and label/parse inverses. Malformed
//! `--task-kind` / `--topology` / `--dissemination` / `--recovery` /
//! fault-probability values must surface as `Err` from `SimConfig::load`
//! — never a panic — and each selector's canonical `label()` must
//! round-trip through its parser exactly (floats survive bit-for-bit:
//! Rust's `Display` is shortest-roundtrip).

use satkit::config::{LlmConfig, SimConfig};
use satkit::resilience::RecoveryPolicy;
use satkit::state::DisseminationKind;
use satkit::tasks::TaskKind;
use satkit::topology::TopologyKind;
use satkit::util::cli::Args;
use satkit::util::quickcheck::{check_no_shrink, default_cases};

fn load_with(key: &str, value: &str) -> Result<SimConfig, String> {
    let args = Args::parse(vec![format!("--{key}"), value.to_string()]);
    SimConfig::load(None, &args)
}

/// Every malformed selector value is rejected with an `Err` whose text
/// names the offending input — no panics, no silent defaults.
#[test]
fn malformed_selector_values_error_not_panic() {
    let cases: &[(&str, &str)] = &[
        // --task-kind: unknown head, bad numbers, arguments on oneshot
        ("task-kind", "bogus"),
        ("task-kind", "autoregressive:abc"),
        ("task-kind", "autoregressive:0"),
        ("task-kind", "autoregressive:4:-1"),
        ("task-kind", "autoregressive:4:nan"),
        ("task-kind", "autoregressive:4:100:-5"),
        ("task-kind", "autoregressive:4:100:1000:-0.5"),
        ("task-kind", "oneshot:3"),
        ("task-kind", ""),
        // --topology: unknown kind, missing size, malformed geometry
        ("topology", "bogus:4"),
        ("topology", "torus"),
        ("topology", "torus:one"),
        ("topology", "torus:1"),
        ("topology", "walker-delta:4"),
        ("topology", "walker-delta:4x"),
        ("topology", "walker-delta:4x4:9"),
        ("topology", "walker-star:1x4"),
        // --dissemination: unknown kind, bad interval, argument on instant
        ("dissemination", "bogus"),
        ("dissemination", "instant:1"),
        ("dissemination", "periodic:abc"),
        ("dissemination", "gossip:abc"),
        // --recovery: unknown policy, bad retry budget, argument on drop
        ("recovery", "bogus"),
        ("recovery", "reoffload:abc"),
        ("recovery", "reoffload:0"),
        ("recovery", "drop:1"),
        // fault probabilities must land in [0, 1] and be finite
        ("p-fail", "1.5"),
        ("p-fail", "-0.1"),
        ("p-recover", "nan"),
        ("link-p-fail", "2"),
        ("link-p-recover", "-1e-3"),
        // --fault-trace: missing file fails at the CLI boundary
        ("fault-trace", "/nonexistent/satkit-trace.txt"),
    ];
    for (key, value) in cases {
        match load_with(key, value) {
            Err(e) => assert!(
                !e.is_empty(),
                "--{key} {value}: error message should not be empty"
            ),
            Ok(_) => panic!("--{key} {value}: expected a parse error, got Ok"),
        }
    }
}

/// Well-formed selector values load, land in the config, and re-emerge
/// from the effective accessors.
#[test]
fn wellformed_selector_values_load() {
    let cfg = load_with("task-kind", "autoregressive:4").unwrap();
    assert!(matches!(
        cfg.task_kind,
        Some(TaskKind::Autoregressive { rounds: 4, .. })
    ));
    let cfg = load_with("task-kind", "oneshot").unwrap();
    assert_eq!(cfg.task_kind, Some(TaskKind::OneShot));
    let cfg = load_with("topology", "walker-delta:4x5:2").unwrap();
    assert_eq!(
        cfg.topology,
        Some(TopologyKind::WalkerDelta {
            planes: 4,
            sats_per_plane: 5,
            phasing: 2
        })
    );
    let cfg = load_with("dissemination", "periodic:2.5").unwrap();
    assert_eq!(
        cfg.dissemination,
        Some(DisseminationKind::Periodic { period_s: 2.5 })
    );
    let cfg = load_with("recovery", "reoffload:3").unwrap();
    assert_eq!(
        cfg.resilience.recovery,
        RecoveryPolicy::Reoffload { max_retries: 3 }
    );
    let cfg = load_with("recovery", "drop").unwrap();
    assert!(cfg.resilience.recovery.is_drop());
    let cfg = load_with("p-fail", "0.25").unwrap();
    assert_eq!(cfg.resilience.p_fail, 0.25);
    assert!(cfg.resilience.sat_faults_active());
}

/// `TaskKind::parse_with(kind.label(), defaults)` is the identity for
/// every valid kind when `defaults.escalate` is `None` (the stock
/// `[llm]` block) — numeric fields round-trip bit-for-bit.
#[test]
fn prop_task_kind_label_parse_inverse() {
    check_no_shrink(
        "task-kind-label-parse-inverse",
        default_cases(),
        |r| {
            if r.next_u64() % 8 == 0 {
                return TaskKind::OneShot;
            }
            TaskKind::Autoregressive {
                rounds: r.usize_in(1, 512) as u32,
                decode_flops: r.f64_in(0.1, 1e6),
                state_bytes: r.f64_in(0.0, 1e9),
                escalate: if r.next_u64() % 2 == 0 {
                    Some(r.f64_in(0.0, 100.0))
                } else {
                    None
                },
            }
        },
        |kind| {
            let label = kind.label();
            let parsed = TaskKind::parse_with(&label, &LlmConfig::default())
                .map_err(|e| format!("label '{label}' failed to parse: {e}"))?;
            if parsed != *kind {
                return Err(format!(
                    "label '{label}' parsed to {parsed:?}, expected {kind:?}"
                ));
            }
            Ok(())
        },
    );
}

/// `TopologyKind::parse(kind.label())` is the identity for every valid
/// geometry.
#[test]
fn prop_topology_label_parse_inverse() {
    check_no_shrink(
        "topology-label-parse-inverse",
        default_cases(),
        |r| match r.next_u64() % 3 {
            0 => TopologyKind::Torus {
                n: r.usize_in(2, 30),
            },
            1 => {
                let sats_per_plane = r.usize_in(2, 16);
                TopologyKind::WalkerDelta {
                    planes: r.usize_in(2, 16),
                    sats_per_plane,
                    phasing: r.usize_in(0, sats_per_plane),
                }
            }
            _ => TopologyKind::WalkerStar {
                planes: r.usize_in(2, 16),
                sats_per_plane: r.usize_in(2, 16),
            },
        },
        |kind| {
            let label = kind.label();
            let parsed = TopologyKind::parse(&label)
                .map_err(|e| format!("label '{label}' failed to parse: {e}"))?;
            if parsed != *kind {
                return Err(format!(
                    "label '{label}' parsed to {parsed:?}, expected {kind:?}"
                ));
            }
            Ok(())
        },
    );
}

/// `RecoveryPolicy::parse(policy.label())` is the identity for every
/// valid policy — `drop` and any positive retry budget round-trip.
#[test]
fn prop_recovery_label_parse_inverse() {
    check_no_shrink(
        "recovery-label-parse-inverse",
        default_cases(),
        |r| {
            if r.next_u64() % 4 == 0 {
                RecoveryPolicy::Drop
            } else {
                RecoveryPolicy::Reoffload {
                    max_retries: r.usize_in(1, 64) as u32,
                }
            }
        },
        |policy| {
            let label = policy.label();
            let parsed = RecoveryPolicy::parse(&label)
                .map_err(|e| format!("label '{label}' failed to parse: {e}"))?;
            if parsed != *policy {
                return Err(format!(
                    "label '{label}' parsed to {parsed:?}, expected {policy:?}"
                ));
            }
            Ok(())
        },
    );
}

/// `DisseminationKind::parse(kind.label())` is the identity — the label
/// always states the interval, so the bare-`gossip` default tick never
/// enters the round trip.
#[test]
fn prop_dissemination_label_parse_inverse() {
    check_no_shrink(
        "dissemination-label-parse-inverse",
        default_cases(),
        |r| match r.next_u64() % 3 {
            0 => DisseminationKind::Instant,
            1 => DisseminationKind::Periodic {
                period_s: r.f64_in(0.01, 30.0),
            },
            _ => DisseminationKind::Gossip {
                tick_s: r.f64_in(0.001, 5.0),
            },
        },
        |kind| {
            let label = kind.label();
            let parsed = DisseminationKind::parse(&label)
                .map_err(|e| format!("label '{label}' failed to parse: {e}"))?;
            if parsed != *kind {
                return Err(format!(
                    "label '{label}' parsed to {parsed:?}, expected {kind:?}"
                ));
            }
            Ok(())
        },
    );
}
