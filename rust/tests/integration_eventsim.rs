//! Integration tests over the event-driven engine: slotted/event
//! equivalence on the paper's homogeneous-Poisson setting, end-to-end
//! traffic scenarios through the same config path the CLI uses, and
//! cross-engine sanity of the shared report.

use satkit::config::{EngineKind, ScenarioKind, SimConfig};
use satkit::engine;
use satkit::eventsim::EventSim;
use satkit::metrics::Report;
use satkit::offload::SchemeKind;
use satkit::sim::Simulation;

/// The acceptance operating point: λ = 25, N = 8, same seed.
fn paper_point() -> SimConfig {
    SimConfig {
        n: 8,
        slots: 20,
        lambda: 25.0,
        seed: 42,
        ..SimConfig::default()
    }
}

#[test]
fn event_engine_matches_slotted_completion_rate() {
    // Same seed, same model, paper traffic: the two engines must agree on
    // completion rate within 5% absolute (the clocks differ, the
    // admission/offloading physics must not).
    let cfg = paper_point();
    let slotted = Simulation::new(&cfg, SchemeKind::Scc).run();
    let event = EventSim::new(&cfg, SchemeKind::Scc).run();
    assert!(slotted.total_tasks > 0 && event.total_tasks > 0);
    let diff = (slotted.completion_rate() - event.completion_rate()).abs();
    assert!(
        diff <= 0.05,
        "slotted {:.4} vs event {:.4} (|diff| = {diff:.4})",
        slotted.completion_rate(),
        event.completion_rate()
    );
    // arrival volumes must be statistically compatible too: both draw
    // Poisson(λ·horizon) network-wide (mean 500, sd ≈ 22)
    let (a, b) = (slotted.total_tasks as f64, event.total_tasks as f64);
    assert!((a - b).abs() < 6.0 * 500.0f64.sqrt(), "arrivals {a} vs {b}");
}

#[test]
fn event_engine_matches_slotted_for_baselines_too() {
    let cfg = paper_point();
    for kind in [SchemeKind::Random, SchemeKind::Rrp] {
        let slotted = Simulation::new(&cfg, kind).run();
        let event = EventSim::new(&cfg, kind).run();
        let diff = (slotted.completion_rate() - event.completion_rate()).abs();
        assert!(
            diff <= 0.05,
            "{kind:?}: slotted {:.4} vs event {:.4}",
            slotted.completion_rate(),
            event.completion_rate()
        );
    }
}

/// Run one scenario through the exact path the CLI takes: a `SimConfig`
/// with `engine`/`scenario` set (what `--engine event --scenario <s>`
/// produces) dispatched via `satkit::engine::run`.
fn run_scenario(s: ScenarioKind) -> Report {
    let cfg = SimConfig {
        n: 6,
        slots: 15,
        lambda: 25.0,
        seed: 7,
        decision_fraction: 0.15,
        engine: EngineKind::Event,
        scenario: s,
        ..SimConfig::default()
    };
    engine::run(&cfg, SchemeKind::Random)
}

#[test]
fn all_scenarios_run_end_to_end_with_distinct_load_profiles() {
    let reports: Vec<(ScenarioKind, Report)> = ScenarioKind::all()
        .into_iter()
        .map(|s| (s, run_scenario(s)))
        .collect();
    for (s, r) in &reports {
        assert!(r.total_tasks > 0, "{s:?} generated no tasks");
        assert_eq!(r.total_tasks, r.completed_tasks + r.dropped_tasks, "{s:?}");
    }
    // distinct load profiles: no two scenarios land on the same
    // per-satellite workload variance
    for i in 0..reports.len() {
        for j in (i + 1)..reports.len() {
            assert_ne!(
                reports[i].1.workload_variance.to_bits(),
                reports[j].1.workload_variance.to_bits(),
                "{:?} and {:?} produced identical load profiles",
                reports[i].0,
                reports[j].0
            );
        }
    }
    // the hotspot concentrates load on a moving subset of areas, so its
    // spatial imbalance must exceed the homogeneous baseline's
    let var_of = |k: ScenarioKind| {
        reports
            .iter()
            .find(|(s, _)| *s == k)
            .map(|(_, r)| r.workload_variance)
            .unwrap()
    };
    assert!(
        var_of(ScenarioKind::Hotspot) > var_of(ScenarioKind::Poisson),
        "hotspot variance {:.3e} should exceed poisson {:.3e}",
        var_of(ScenarioKind::Hotspot),
        var_of(ScenarioKind::Poisson)
    );
}

#[test]
fn engine_dispatch_honours_config() {
    let mut cfg = paper_point();
    cfg.lambda = 5.0;
    cfg.slots = 8;
    for kind in EngineKind::all() {
        cfg.engine = kind;
        let e = engine::build(&cfg, SchemeKind::Rrp);
        assert_eq!(e.label(), kind.name());
        let r = e.run_boxed();
        assert!(r.total_tasks > 0, "{kind:?}");
    }
}

#[test]
fn event_engine_delay_grows_with_incidence() {
    // queueing fidelity: continuous-time delays must still rise with λ
    let mut lo_cfg = paper_point();
    lo_cfg.lambda = 5.0;
    let mut hi_cfg = paper_point();
    hi_cfg.lambda = 50.0;
    let lo = EventSim::new(&lo_cfg, SchemeKind::Rrp).run();
    let hi = EventSim::new(&hi_cfg, SchemeKind::Rrp).run();
    if lo.completed_tasks > 0 && hi.completed_tasks > 0 {
        assert!(
            hi.avg_delay_ms >= lo.avg_delay_ms * 0.8,
            "delay at lambda=50 ({:.1}) collapsed below lambda=5 ({:.1})",
            hi.avg_delay_ms,
            lo.avg_delay_ms
        );
    }
}

#[test]
fn arena_slot_reuse_is_deterministic_under_fault_churn() {
    // fault ticks wipe queued work and abort in-flight tasks, so arena
    // slots churn hard (free → reuse → free); two runs with the same seed
    // must still agree on every headline statistic to the bit — slot
    // reuse, the per-satellite fault reverse index, and stale-event ABA
    // checks must all be invisible to the simulation's arithmetic.
    let cfg = SimConfig {
        n: 6,
        slots: 14,
        lambda: 20.0,
        seed: 13,
        ..SimConfig::default()
    };
    let run = || {
        EventSim::new(&cfg, SchemeKind::Scc)
            .with_faults(0.15, 0.5)
            .run()
    };
    let a = run();
    let b = run();
    assert!(a.total_tasks > 0);
    assert_eq!(a.total_tasks, a.completed_tasks + a.dropped_tasks);
    assert!(
        a.dropped_tasks > 0,
        "the churn point should abort some tasks"
    );
    assert_eq!(a.total_tasks, b.total_tasks);
    assert_eq!(a.completed_tasks, b.completed_tasks);
    for (name, x, y) in [
        ("avg_delay_ms", a.avg_delay_ms, b.avg_delay_ms),
        ("avg_comp_ms", a.avg_comp_ms, b.avg_comp_ms),
        ("avg_tran_ms", a.avg_tran_ms, b.avg_tran_ms),
        ("workload_variance", a.workload_variance, b.workload_variance),
        ("delay_p95_ms", a.delay_p95_ms, b.delay_p95_ms),
        ("last_finish_s", a.last_finish_s, b.last_finish_s),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{name} diverged: {x} vs {y}");
    }
}

#[test]
fn event_engine_dynamics_run_together() {
    // handover + faults + jitter all active on the event kernel
    let cfg = SimConfig {
        n: 6,
        slots: 12,
        lambda: 15.0,
        seed: 11,
        ..SimConfig::default()
    };
    let r = EventSim::new(&cfg, SchemeKind::Scc)
        .with_handover(satkit::sim::dynamics::Handover {
            dwell_slots: 3,
            direction: 1,
        })
        .with_faults(0.05, 0.4)
        .with_jitter(0.2)
        .run();
    assert!(r.total_tasks > 0);
    assert_eq!(r.total_tasks, r.completed_tasks + r.dropped_tasks);
}
