//! Whole-run properties for PR 9's two decision-layer structures.
//!
//! * The pooled GA generation evaluator (`--decide-threads`) must leave
//!   every report **byte-identical** to the sequential run at any lane
//!   count: all RNG stays on the coordinator thread and per-chromosome
//!   deficits are independent reductions, so fanning a generation over
//!   the `EvalPool` can only change wall-clock, never a single bit.
//! * The epoch-keyed decision cache (`--decision-cache`) is explicitly
//!   **not** byte-identical when on (hits skip the GA's RNG draws), so
//!   the guarantee the default path rides on is the inverse: with the
//!   flag off — the default — runs are bit-for-bit the legacy engine.
//!
//! Both invariants hold across both engines and all four schemes (the
//! heuristics ignore both knobs entirely, which this also pins down).

use satkit::config::{EngineKind, SimConfig};
use satkit::metrics::Report;
use satkit::offload::SchemeKind;
use satkit::util::quickcheck::{check_no_shrink, default_cases};

/// Compare two reports field-by-field, bit-for-bit on floats.
fn assert_reports_identical(a: &Report, b: &Report) -> Result<(), String> {
    if a.total_tasks != b.total_tasks {
        return Err(format!(
            "task counts differ: {} vs {}",
            a.total_tasks, b.total_tasks
        ));
    }
    if a.completed_tasks != b.completed_tasks {
        return Err(format!(
            "completion counts differ: {} vs {}",
            a.completed_tasks, b.completed_tasks
        ));
    }
    for (name, x, y) in [
        ("avg_delay_ms", a.avg_delay_ms, b.avg_delay_ms),
        ("avg_comp_ms", a.avg_comp_ms, b.avg_comp_ms),
        ("avg_tran_ms", a.avg_tran_ms, b.avg_tran_ms),
        ("avg_uplink_ms", a.avg_uplink_ms, b.avg_uplink_ms),
        ("workload_variance", a.workload_variance, b.workload_variance),
        ("workload_mean", a.workload_mean, b.workload_mean),
        ("delay_p50_ms", a.delay_p50_ms, b.delay_p50_ms),
        ("delay_p95_ms", a.delay_p95_ms, b.delay_p95_ms),
    ] {
        if x.to_bits() != y.to_bits() {
            return Err(format!("{name} differs: {x} vs {y}"));
        }
    }
    Ok(())
}

/// The tentpole acceptance invariant, deterministically over every
/// (engine, scheme, lane count) cell: pinned lane counts and the auto
/// (one-per-core) mode all reproduce the sequential run bit-for-bit.
#[test]
fn pooled_decide_matches_sequential_all_engines_and_schemes() {
    for engine in EngineKind::all() {
        for scheme in SchemeKind::all() {
            let mut cfg = SimConfig {
                n: 6,
                slots: 6,
                lambda: 8.0,
                seed: 11,
                engine,
                ..SimConfig::default()
            };
            cfg.decide_threads = 1;
            let sequential = satkit::engine::run(&cfg, scheme);
            for threads in [2usize, 4, 0] {
                cfg.decide_threads = threads;
                let pooled = satkit::engine::run(&cfg, scheme);
                assert_reports_identical(&sequential, &pooled).unwrap_or_else(|e| {
                    panic!("{engine:?}/{scheme:?} decide_threads={threads}: {e}")
                });
            }
        }
    }
}

/// `--decision-cache` defaults off, and off is the legacy path: a config
/// that spells `decision_cache = false` runs bit-for-bit like one that
/// never mentions the knob — across both engines, all schemes, and a
/// stale (periodic) dissemination where the cache would actually engage
/// if it were wrongly live.
#[test]
fn decision_cache_off_is_bit_identical_to_unset() {
    for engine in EngineKind::all() {
        for scheme in SchemeKind::all() {
            let base = SimConfig {
                n: 6,
                slots: 6,
                lambda: 8.0,
                seed: 11,
                engine,
                dissemination: Some(satkit::state::DisseminationKind::Periodic {
                    period_s: 2.0,
                }),
                ..SimConfig::default()
            };
            assert!(!base.decision_cache, "cache must default off");
            let unset = satkit::engine::run(&base, scheme);
            let mut explicit = base.clone();
            explicit.decision_cache = false;
            let off = satkit::engine::run(&explicit, scheme);
            assert_reports_identical(&unset, &off)
                .unwrap_or_else(|e| panic!("{engine:?}/{scheme:?}: {e}"));
        }
    }
}

/// Cache-on smoke: the run completes, produces tasks, and under a stale
/// periodic view the SCC scheme's cache actually records lookups (the
/// counters ride the telemetry block). Heuristic schemes never consult
/// it — their kernels have no cache — which the scheme-agnostic knob
/// plumbing (`make_scheme_with`) keeps true by construction.
#[test]
fn decision_cache_on_runs_and_counts_lookups() {
    let mut cfg = SimConfig {
        n: 6,
        slots: 6,
        lambda: 8.0,
        seed: 11,
        engine: EngineKind::Event,
        dissemination: Some(satkit::state::DisseminationKind::Periodic { period_s: 2.0 }),
        ..SimConfig::default()
    };
    cfg.decision_cache = true;
    cfg.obs.telemetry = true;
    let rep = satkit::engine::run(&cfg, SchemeKind::Scc);
    assert!(rep.total_tasks > 0);
    let scheme_block = rep
        .telemetry
        .as_ref()
        .and_then(|t| t.get("scheme"))
        .expect("SCC telemetry exposes the kernel block");
    let counter = |key: &str| -> f64 {
        scheme_block.get(key).and_then(|v| v.as_f64()).unwrap_or(-1.0)
    };
    let lookups = counter("decision_cache_lookups");
    let hits = counter("decision_cache_hits");
    let decides = counter("decides");
    assert!(decides > 0.0, "GA decided at least once");
    assert!(lookups > 0.0, "stale periodic views consult the cache");
    assert!(hits >= 0.0 && hits <= lookups, "hits within lookups");
}

/// The pooled-eval invariant over random (n, λ, slots, engine, scheme,
/// lanes, seed) whole-run cases, in the style of `tests/prop_sharded.rs`.
#[test]
fn prop_pooled_runs_are_byte_identical_to_sequential() {
    check_no_shrink(
        "pooled-decide-byte-identical",
        default_cases().min(16),
        |r| {
            let n = *r.choose(&[4usize, 6]);
            let lambda = r.f64_in(2.0, 10.0);
            let slots = r.usize_in(3, 7);
            let engine = *r.choose(&EngineKind::all());
            let scheme = *r.choose(&SchemeKind::all());
            // 0 = auto (one lane per core); otherwise a pinned count,
            // deliberately allowed to exceed the core count
            let threads = r.usize_in(0, 9);
            let seed = r.next_u64() % 1000;
            (n, lambda, slots, engine, scheme, threads, seed)
        },
        |&(n, lambda, slots, engine, scheme, threads, seed)| {
            let mut cfg = SimConfig {
                n,
                lambda,
                slots,
                seed,
                engine,
                ..SimConfig::default()
            };
            cfg.decide_threads = 1;
            let sequential = satkit::engine::run(&cfg, scheme);
            cfg.decide_threads = threads;
            let pooled = satkit::engine::run(&cfg, scheme);
            assert_reports_identical(&sequential, &pooled)
        },
    );
}
