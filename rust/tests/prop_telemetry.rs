//! Property tests for the telemetry layer (`satkit::obs`): observability
//! must be free when off and read-only when on.
//!
//! * With telemetry disabled (the default), both engines produce reports
//!   bit-for-bit identical to the pre-telemetry path — the hooks are one
//!   untaken branch, nothing else.
//! * Enabling `--telemetry` / `--trace` changes NO report field except
//!   adding the `telemetry` JSON block: the recorder observes the
//!   simulation, it never participates in it (no RNG draws, no float
//!   reordering).
//! * A recorded trace actually covers the task lifecycle: task / uplink /
//!   exec / ISL spans, broadcast instants, per-satellite counter samples.

use satkit::config::{EngineKind, SimConfig};
use satkit::metrics::Report;
use satkit::obs::TraceConfig;
use satkit::offload::SchemeKind;
use satkit::state::DisseminationKind;
use satkit::util::json::Json;
use satkit::util::quickcheck::{check_no_shrink, default_cases};
use satkit::util::rng::Pcg64;

/// Compare two reports field-by-field, bit-for-bit on floats (the
/// `telemetry` block is deliberately NOT compared — it is the one field
/// observability is allowed to add).
fn assert_reports_identical(a: &Report, b: &Report) -> Result<(), String> {
    if a.total_tasks != b.total_tasks {
        return Err(format!("task counts differ: {} vs {}", a.total_tasks, b.total_tasks));
    }
    if a.completed_tasks != b.completed_tasks {
        return Err(format!(
            "completion counts differ: {} vs {}",
            a.completed_tasks, b.completed_tasks
        ));
    }
    for (name, x, y) in [
        ("avg_delay_ms", a.avg_delay_ms, b.avg_delay_ms),
        ("avg_comp_ms", a.avg_comp_ms, b.avg_comp_ms),
        ("avg_tran_ms", a.avg_tran_ms, b.avg_tran_ms),
        ("avg_uplink_ms", a.avg_uplink_ms, b.avg_uplink_ms),
        ("workload_variance", a.workload_variance, b.workload_variance),
        ("workload_mean", a.workload_mean, b.workload_mean),
        ("delay_p50_ms", a.delay_p50_ms, b.delay_p50_ms),
        ("delay_p95_ms", a.delay_p95_ms, b.delay_p95_ms),
    ] {
        if x.to_bits() != y.to_bits() {
            return Err(format!("{name} differs: {x} vs {y}"));
        }
    }
    Ok(())
}

fn random_case(r: &mut Pcg64) -> (usize, f64, usize, SchemeKind, EngineKind, u64) {
    let n = *r.choose(&[4usize, 6]);
    let lambda = r.f64_in(2.0, 12.0);
    let slots = r.usize_in(3, 9);
    let scheme = *r.choose(&SchemeKind::all());
    let engine = *r.choose(&[EngineKind::Slotted, EngineKind::Event]);
    let seed = r.next_u64() % 1000;
    (n, lambda, slots, scheme, engine, seed)
}

/// Enabling the counter registry changes no report field except adding
/// the `telemetry` block, on either engine, for any scheme: stripping the
/// block yields byte-identical report JSON.
#[test]
fn prop_telemetry_counters_do_not_perturb_runs() {
    check_no_shrink(
        "telemetry-counters-do-not-perturb",
        default_cases().min(20),
        random_case,
        |&(n, lambda, slots, scheme, engine, seed)| {
            let cfg = SimConfig {
                n,
                lambda,
                slots,
                seed,
                engine,
                ..SimConfig::default()
            };
            let off = satkit::engine::run(&cfg, scheme);
            if off.telemetry.is_some() {
                return Err("telemetry block present on a default run".into());
            }
            let mut on_cfg = cfg.clone();
            on_cfg.obs.telemetry = true;
            let mut on = satkit::engine::run(&on_cfg, scheme);
            assert_reports_identical(&off, &on)?;
            if on.telemetry.is_none() {
                return Err("telemetry block missing on an enabled run".into());
            }
            // stripping the block must make the JSON byte-identical
            on.telemetry = None;
            let (a, b) = (off.to_json().to_string(), on.to_json().to_string());
            if a != b {
                return Err(format!("report JSON diverged beyond `telemetry`: {a} vs {b}"));
            }
            Ok(())
        },
    );
}

fn temp_trace_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("satkit_prop_trace_{tag}_{}.json", std::process::id()))
}

/// `--trace` (both engines × all four schemes) changes no report field
/// except the `telemetry` block, and the written file parses as a Chrome
/// trace with at least one event.
#[test]
fn trace_only_adds_telemetry_block_all_schemes_both_engines() {
    for engine in [EngineKind::Slotted, EngineKind::Event] {
        for scheme in SchemeKind::all() {
            let cfg = SimConfig {
                n: 4,
                lambda: 6.0,
                slots: 5,
                seed: 11,
                engine,
                ..SimConfig::default()
            };
            let off = satkit::engine::run(&cfg, scheme);
            let path = temp_trace_path(&format!("{}_{}", engine.name(), scheme.name()));
            let mut traced_cfg = cfg.clone();
            traced_cfg.obs.trace = Some(TraceConfig {
                path: path.to_string_lossy().into_owned(),
                max_events: 100_000,
            });
            let traced = satkit::engine::run(&traced_cfg, scheme);
            assert_reports_identical(&off, &traced)
                .unwrap_or_else(|e| panic!("{engine:?}/{scheme:?}: {e}"));
            assert!(
                traced.telemetry.is_some(),
                "{engine:?}/{scheme:?}: traced run must carry the telemetry block"
            );
            let text = std::fs::read_to_string(&path)
                .unwrap_or_else(|e| panic!("{engine:?}/{scheme:?}: reading trace: {e}"));
            let _ = std::fs::remove_file(&path);
            let json = Json::parse(&text)
                .unwrap_or_else(|e| panic!("{engine:?}/{scheme:?}: trace not JSON: {e}"));
            let events = json
                .get("traceEvents")
                .and_then(|e| e.as_arr())
                .unwrap_or_else(|| panic!("{engine:?}/{scheme:?}: no traceEvents array"));
            assert!(!events.is_empty(), "{engine:?}/{scheme:?}: empty trace");
        }
    }
}

/// A traced run on the event engine under periodic dissemination covers
/// the whole lifecycle: task/uplink/exec/ISL spans, broadcast instants,
/// and per-satellite + engine counter samples, all with sane timestamps.
#[test]
fn trace_covers_task_lifecycle() {
    let path = temp_trace_path("lifecycle");
    let mut cfg = SimConfig {
        n: 6,
        lambda: 10.0,
        slots: 8,
        seed: 3,
        engine: EngineKind::Event,
        ..SimConfig::default()
    };
    cfg.dissemination = Some(DisseminationKind::Periodic { period_s: 1.0 });
    cfg.obs.trace = Some(TraceConfig {
        path: path.to_string_lossy().into_owned(),
        max_events: 1_000_000,
    });
    let report = satkit::engine::run(&cfg, SchemeKind::Scc);
    assert!(report.completed_tasks > 0, "need completions to trace");

    let text = std::fs::read_to_string(&path).expect("trace written");
    let _ = std::fs::remove_file(&path);
    let json = Json::parse(&text).expect("trace parses");
    let events = json
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("traceEvents array");

    let mut names: Vec<&str> = Vec::new();
    for ev in events {
        let name = ev.get("name").and_then(|n| n.as_str()).expect("event name");
        let ts = ev.get("ts").and_then(|t| t.as_f64()).expect("event ts");
        assert!(ts >= 0.0 && ts.is_finite(), "bad ts {ts} on {name}");
        if let Some(dur) = ev.get("dur").and_then(|d| d.as_f64()) {
            assert!(dur >= 0.0, "negative dur on {name}");
        }
        if !names.contains(&name) {
            names.push(name);
        }
    }
    for expect in ["task", "uplink", "exec", "isl", "decide", "broadcast", "engine"] {
        assert!(names.contains(&expect), "trace lacks {expect:?} events: {names:?}");
    }
    assert!(
        names.iter().any(|n| n.starts_with("sat")),
        "trace lacks per-satellite counter samples: {names:?}"
    );

    // the telemetry block mirrors what the trace recorded
    let t = report.telemetry.expect("telemetry block");
    let spans = t.get("spans").expect("spans");
    assert!(spans.get("task").and_then(|v| v.as_f64()).unwrap_or(0.0) > 0.0);
    assert!(spans.get("exec").and_then(|v| v.as_f64()).unwrap_or(0.0) > 0.0);
    assert!(
        t.get("state_broadcasts").and_then(|v| v.as_f64()).unwrap_or(0.0) > 0.0,
        "periodic dissemination must count broadcasts"
    );
    let trace_meta = t.get("trace").expect("trace meta");
    assert_eq!(
        trace_meta.get("retained").and_then(|v| v.as_f64()),
        Some(events.len() as f64),
        "trace meta retained count must match the file"
    );
}

/// The ring cap truncates the trace to the newest events and reports the
/// drop count instead of growing without bound.
#[test]
fn trace_ring_cap_bounds_the_file() {
    let path = temp_trace_path("capped");
    let mut cfg = SimConfig {
        n: 4,
        lambda: 8.0,
        slots: 6,
        seed: 5,
        engine: EngineKind::Event,
        ..SimConfig::default()
    };
    cfg.obs.trace = Some(TraceConfig {
        path: path.to_string_lossy().into_owned(),
        max_events: 16,
    });
    let report = satkit::engine::run(&cfg, SchemeKind::Random);
    let text = std::fs::read_to_string(&path).expect("trace written");
    let _ = std::fs::remove_file(&path);
    let events = Json::parse(&text)
        .expect("trace parses")
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .map(|a| a.len())
        .unwrap_or(0);
    assert!(events <= 16, "ring cap exceeded: {events}");
    let t = report.telemetry.expect("telemetry block");
    let meta = t.get("trace").expect("trace meta");
    assert!(
        meta.get("dropped").and_then(|v| v.as_f64()).unwrap_or(0.0) > 0.0,
        "a capped busy run must report dropped events"
    );
}
