//! Ablation bench: GA solution quality and cost vs its hyper-parameters
//! (N_iter, population N_K) — the design-choice study DESIGN.md calls
//! abl-ga. Also isolates GA decide() latency per task.

use satkit::bench::{bench, quick_mode, section};
use satkit::config::GaConfig;
use satkit::experiments as exp;
use satkit::offload::{make_scheme, OffloadContext, SchemeKind};
use satkit::satellite::Satellite;
use satkit::state::StateView;
use satkit::topology::Constellation;
use satkit::util::rng::Pcg64;

fn main() {
    let quick = quick_mode();
    let opts = exp::SweepOpts {
        slots: if quick { 3 } else { 8 },
        ..exp::SweepOpts::default()
    };

    section("quality vs N_iter (VGG19, lambda=40, SCC)");
    let iters: Vec<usize> = if quick { vec![1, 10] } else { vec![1, 2, 5, 10, 20, 40] };
    println!("{:>8} {:>14} {:>14} {:>16}", "N_iter", "complete", "delay", "variance");
    for (it, r) in exp::ablation_ga(&iters, &opts) {
        println!(
            "{it:>8} {:>13.2}% {:>12.1}ms {:>16.3e}",
            100.0 * r.completion_rate(),
            r.avg_delay_ms,
            r.workload_variance
        );
    }

    section("GA decide() latency per task (Table-I params)");
    let topo = Constellation::torus(10);
    let mut sats: Vec<Satellite> =
        (0..100).map(|i| Satellite::new(i, 3000.0, 15000.0)).collect();
    let mut rng = Pcg64::seed_from_u64(1);
    for s in sats.iter_mut() {
        s.try_load(rng.f64_in(0.0, 12_000.0));
    }
    let cands = topo.decision_space(42, 3);
    let segments = vec![3800.0, 3900.0, 3700.0, 3800.0]; // ResNet101 L=4-ish
    for (nk, ni) in [(10usize, 5usize), (20, 10), (40, 20)] {
        let ga = GaConfig {
            n_k: nk,
            n_iter: ni,
            ..GaConfig::default()
        };
        let ctx = OffloadContext {
            topo: &topo,
            view: StateView::live(&sats),
            origin: 42,
            candidates: &cands,
            segments: &segments,
            kappa: 1e-4,
            ga: &ga,
            migration: None,
            outages: None,
        };
        let mut scheme = make_scheme(SchemeKind::Scc, 3);
        let r = bench(
            &format!("GA decide N_K={nk} N_iter={ni}"),
            3,
            if quick { 10 } else { 50 },
            || {
                std::hint::black_box(scheme.decide(&ctx));
            },
        );
        println!("{}", r.row());
    }
}
