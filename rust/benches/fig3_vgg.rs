//! Bench: regenerate Fig. 3 (VGG19, L=3, D_M=2) — the same three panels
//! as Fig. 2 for the second evaluated model.

use satkit::bench::{bench, quick_mode, section};
use satkit::dnn::DnnModel;
use satkit::experiments as exp;
use satkit::offload::SchemeKind;

fn main() {
    let quick = quick_mode();
    let opts = exp::SweepOpts {
        slots: if quick { 4 } else { 12 },
        ..exp::SweepOpts::default()
    };
    let lambdas: Vec<f64> = if quick {
        vec![4.0, 25.0]
    } else {
        exp::default_lambdas()
    };

    section("Fig 3 (VGG19): generation");
    let rows = exp::lambda_sweep(DnnModel::Vgg19, &lambdas, &opts);
    println!("{}", exp::render_panels("Fig 3 — VGG19", &rows, "lambda"));
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/fig3.json", exp::rows_to_json(&rows).to_string()).ok();
    println!("wrote results/fig3.json");

    section("Fig 3: per-cell decision cost");
    for scheme in SchemeKind::all() {
        let r = bench(
            &format!("vgg19 lambda=25 {}", scheme.name()),
            0,
            if quick { 1 } else { 3 },
            || {
                exp::run_point(DnnModel::Vgg19, 25.0, scheme, &exp::SweepOpts {
                    slots: 3,
                    ..opts.clone()
                });
            },
        );
        println!("{}", r.row());
    }
}
