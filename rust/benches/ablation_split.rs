//! Ablation bench: Alg. 1 workload-balanced splitting vs naive
//! equal-layer-count cuts, under the SCC offloader (DESIGN.md abl-split).
//! Shows why min-max balance (Eq. 3) matters: VGG19's fc-heavy tail makes
//! naive cuts badly unbalanced, inflating drops at high λ.

use satkit::bench::{bench, quick_mode, section};
use satkit::dnn::DnnModel;
use satkit::experiments as exp;
use satkit::splitting::{balanced_split, naive_equal_layers};

fn main() {
    let quick = quick_mode();
    let opts = exp::SweepOpts {
        slots: if quick { 3 } else { 10 },
        ..exp::SweepOpts::default()
    };
    let lambdas: Vec<f64> = if quick { vec![25.0] } else { vec![10.0, 25.0, 40.0, 55.0] };

    section("static split quality (max block / mean block)");
    for model in [DnnModel::Vgg19, DnnModel::Resnet101] {
        let w = model.profile().workloads();
        let (l, _) = model.table1_defaults();
        let bal = balanced_split(&w, l, 1.0);
        let naive = naive_equal_layers(&w, l);
        println!(
            "{:<10} L={l}  balanced max={:.0} (ratio {:.3})   naive max={:.0} (ratio {:.3})",
            model.name(),
            bal.max_block_workload(),
            bal.balance_ratio(),
            naive.max_block_workload(),
            naive.balance_ratio()
        );
    }

    section("end-to-end: completion & delay under SCC");
    for model in [DnnModel::Vgg19, DnnModel::Resnet101] {
        let rows = exp::ablation_split(model, &lambdas, &opts);
        println!("{}:", model.name());
        println!(
            "{:>8} {:>14} {:>14} {:>12} {:>12}",
            "lambda", "bal complete", "naive complete", "bal delay", "naive delay"
        );
        for (l, b, n) in &rows {
            println!(
                "{l:>8.0} {:>13.2}% {:>13.2}% {:>10.1}ms {:>10.1}ms",
                100.0 * b.completion_rate(),
                100.0 * n.completion_rate(),
                b.avg_delay_ms,
                n.avg_delay_ms
            );
        }
    }

    section("split cost");
    let w = DnnModel::Resnet101.profile().workloads();
    let r = bench("balanced_split resnet101 L=4", 10, 100, || {
        std::hint::black_box(balanced_split(&w, 4, 1.0));
    });
    println!("{}", r.row());
}
