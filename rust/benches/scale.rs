//! Bench: regenerate the §V-B network-scale study — completion rate vs
//! constellation size N ∈ {4..32} (up to 1024 satellites) at λ = 25 —
//! and time a full slot at each scale.

use satkit::bench::{bench, quick_mode, section};
use satkit::experiments as exp;

fn main() {
    let quick = quick_mode();
    let opts = exp::SweepOpts {
        slots: if quick { 3 } else { 8 },
        ..exp::SweepOpts::default()
    };
    let ns: Vec<usize> = if quick { vec![4, 8] } else { exp::default_ns() };

    section("network-scale study: generation");
    let rows = exp::scale(&ns, &opts);
    println!("{}", exp::render_panels("scale — completion vs N (lambda=25)", &rows, "N"));
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/scale.json", exp::rows_to_json(&rows).to_string()).ok();
    println!("wrote results/scale.json");

    section("scale: wall time per simulated slot (SCC)");
    for &n in &ns {
        let r = bench(&format!("N={n} ({} sats) one-slot sim", n * n), 0, 1, || {
            let mut cfg = satkit::config::SimConfig::default();
            cfg.n = n;
            cfg.lambda = 25.0;
            cfg.slots = 1;
            satkit::sim::Simulation::new(&cfg, satkit::offload::SchemeKind::Scc).run();
        });
        println!("{}", r.row());
    }
}
