//! Bench: event-kernel scaling. The slotted engine's cost grows with
//! wall-clock slots regardless of traffic; the event engine's grows with
//! events (≈ arrivals × L). This target times both engines over a λ ramp
//! and a horizon ramp so the crossover is visible, then sweeps the four
//! traffic scenarios at a fixed operating point.

use satkit::bench::{bench, quick_mode, section};
use satkit::config::{EngineKind, ScenarioKind, SimConfig};
use satkit::offload::SchemeKind;

fn cfg(engine: EngineKind, lambda: f64, slots: usize) -> SimConfig {
    SimConfig {
        n: 8,
        slots,
        lambda,
        seed: 42,
        engine,
        ..SimConfig::default()
    }
}

fn main() {
    let quick = quick_mode();
    let iters = if quick { 1 } else { 3 };

    section("engine wall time vs lambda (N=8, 20 s horizon, Random)");
    let lambdas: &[f64] = if quick { &[10.0, 40.0] } else { &[4.0, 10.0, 25.0, 40.0, 70.0] };
    for &lam in lambdas {
        for engine in EngineKind::all() {
            let c = cfg(engine, lam, if quick { 8 } else { 20 });
            let r = bench(
                &format!("{:<7} lambda={lam}", engine.name()),
                0,
                iters,
                || {
                    satkit::engine::run(&c, SchemeKind::Random);
                },
            );
            println!("{}", r.row());
        }
    }

    section("engine wall time vs horizon (N=8, lambda=10, Random)");
    let horizons: &[usize] = if quick { &[10, 40] } else { &[10, 40, 160, 640] };
    for &slots in horizons {
        for engine in EngineKind::all() {
            let c = cfg(engine, 10.0, slots);
            let r = bench(
                &format!("{:<7} horizon={slots}s", engine.name()),
                0,
                iters,
                || {
                    satkit::engine::run(&c, SchemeKind::Random);
                },
            );
            println!("{}", r.row());
        }
    }

    section("traffic scenarios on the event engine (lambda=25, SCC)");
    for scenario in ScenarioKind::all() {
        let mut c = cfg(EngineKind::Event, 25.0, if quick { 8 } else { 20 });
        c.scenario = scenario;
        let mut last_var = 0.0;
        let r = bench(&format!("scenario={}", scenario.name()), 0, iters, || {
            let rep = satkit::engine::run(&c, SchemeKind::Scc);
            last_var = rep.workload_variance;
        });
        println!("{}  workload_var={last_var:.3e}", r.row());
    }
}
