//! Bench: event-kernel scaling. The slotted engine's cost grows with
//! wall-clock slots regardless of traffic; the event engine's grows with
//! events (≈ arrivals × L). This target times both engines over a λ ramp
//! and a horizon ramp so the crossover is visible, sweeps the four
//! traffic scenarios at a fixed operating point, measures the live-task
//! bookkeeping structures head to head (the BTreeMap the kernel used
//! before the slab arena vs the arena itself), and finishes with the
//! ≥ 10⁶-task operating points: the admission-bound regime (streaming
//! metrics, memory flat in task count) and the execution-bound regime
//! (every segment through the queues — the live-task hot path).
//!
//! Emits `BENCH_eventsim.json` (override the path with
//! `SATKIT_EVENTSIM_JSON`): the timed rows under `results`, the
//! million-task operating points under `scale` with `tasks_per_s` — the
//! headline series of the event-kernel perf trajectory.

use std::collections::BTreeMap;

use satkit::bench::{bench, quick_mode, section, write_json, BenchResult};
use satkit::config::{EngineKind, ScenarioKind, SimConfig};
use satkit::eventsim::arena::Slab;
use satkit::offload::SchemeKind;
use satkit::util::json::Json;

/// Peak resident set (VmHWM) from procfs, for the memory-flat check.
fn peak_rss() -> String {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("VmHWM"))
                .map(|l| l.split_whitespace().skip(1).collect::<Vec<_>>().join(" "))
        })
        .map(|v| format!("peak_rss={v}"))
        .unwrap_or_else(|| "peak_rss=n/a".to_string())
}

fn cfg(engine: EngineKind, lambda: f64, slots: usize) -> SimConfig {
    SimConfig {
        n: 8,
        slots,
        lambda,
        seed: 42,
        engine,
        ..SimConfig::default()
    }
}

/// A live-task-sized payload (the arena's win is structural, not
/// payload-dependent; four words approximate `LiveTask`'s scalar part).
type Payload = [u64; 4];

/// Run one ≥ `floor`-task event-engine point, print its row, and return
/// the `scale` JSON record plus the report (so callers can compare runs
/// bit-for-bit without paying for a second run).
fn scale_point(name: &str, c: &SimConfig, floor: u64) -> (Json, satkit::metrics::Report) {
    let t0 = std::time::Instant::now();
    let rep = satkit::engine::run(c, SchemeKind::Random);
    let wall = t0.elapsed().as_secs_f64();
    let tasks_per_s = rep.total_tasks as f64 / wall.max(1e-9);
    println!(
        "{name}: tasks={} completed={} drop_rate={:.3} wall={:.2}s ({tasks_per_s:.0} tasks/s) {}",
        rep.total_tasks,
        rep.completed_tasks,
        rep.drop_rate(),
        wall,
        peak_rss()
    );
    assert!(
        rep.outcomes.is_none(),
        "streaming path must not buffer outcomes"
    );
    assert!(
        rep.total_tasks >= floor,
        "scale run produced {} tasks, expected >= {floor}",
        rep.total_tasks
    );
    let row = Json::obj(vec![
        ("point", Json::Str(name.to_string())),
        ("tasks", Json::Num(rep.total_tasks as f64)),
        ("completed", Json::Num(rep.completed_tasks as f64)),
        ("wall_s", Json::Num(wall)),
        ("tasks_per_s", Json::Num(tasks_per_s)),
    ]);
    (row, rep)
}

fn main() {
    let quick = quick_mode();
    let iters = if quick { 1 } else { 3 };
    let mut all: Vec<BenchResult> = Vec::new();
    let mut show = |r: BenchResult| {
        println!("{}", r.row());
        all.push(r);
    };

    section("engine wall time vs lambda (N=8, 20 s horizon, Random)");
    let lambdas: &[f64] = if quick { &[10.0, 40.0] } else { &[4.0, 10.0, 25.0, 40.0, 70.0] };
    for &lam in lambdas {
        for engine in EngineKind::all() {
            let c = cfg(engine, lam, if quick { 8 } else { 20 });
            show(bench(
                &format!("{:<7} lambda={lam}", engine.name()),
                0,
                iters,
                || {
                    satkit::engine::run(&c, SchemeKind::Random);
                },
            ));
        }
    }

    section("engine wall time vs horizon (N=8, lambda=10, Random)");
    let horizons: &[usize] = if quick { &[10, 40] } else { &[10, 40, 160, 640] };
    for &slots in horizons {
        for engine in EngineKind::all() {
            let c = cfg(engine, 10.0, slots);
            show(bench(
                &format!("{:<7} horizon={slots}s", engine.name()),
                0,
                iters,
                || {
                    satkit::engine::run(&c, SchemeKind::Random);
                },
            ));
        }
    }

    section("traffic scenarios on the event engine (lambda=25, SCC)");
    for scenario in ScenarioKind::all() {
        let mut c = cfg(EngineKind::Event, 25.0, if quick { 8 } else { 20 });
        c.scenario = scenario;
        let mut last_var = 0.0;
        let r = bench(&format!("scenario={}", scenario.name()), 0, iters, || {
            let rep = satkit::engine::run(&c, SchemeKind::Scc);
            last_var = rep.workload_variance;
        });
        show(r);
        println!("{:<44} workload_var={last_var:.3e}", "");
    }

    section("live-task bookkeeping: BTreeMap era vs slab arena");
    // The exact op mix a task with L=3 segments costs the live structure:
    // one insert, three lookups per segment (start/done/transfer), one
    // remove — against a steady concurrent population. The BTreeMap row
    // is what the kernel paid before the arena (PR ≤ 4); the arena row is
    // what it pays now.
    let churn_tasks: u64 = if quick { 100_000 } else { 1_000_000 };
    let resident: u64 = 4096;
    show(bench(
        &format!("live-map btreemap churn ({churn_tasks} tasks)"),
        0,
        iters,
        || {
            let mut map: BTreeMap<u64, Payload> = BTreeMap::new();
            for id in 0..resident {
                map.insert(id, [id; 4]);
            }
            let mut acc = 0u64;
            for id in resident..churn_tasks + resident {
                map.insert(id, [id; 4]);
                let dead = id - resident;
                for _ in 0..9 {
                    if let Some(p) = map.get(&id) {
                        acc = acc.wrapping_add(p[0]);
                    }
                }
                map.remove(&dead);
            }
            std::hint::black_box((acc, map.len()));
        },
    ));
    show(bench(
        &format!("live-map arena churn ({churn_tasks} tasks)"),
        0,
        iters,
        || {
            let mut slab: Slab<Payload> = Slab::new();
            let mut slots: Vec<u32> = Vec::new();
            for id in 0..resident {
                slots.push(slab.insert(id, [id; 4]));
            }
            let mut acc = 0u64;
            for id in resident..churn_tasks + resident {
                let slot = slab.insert(id, [id; 4]);
                slots.push(slot);
                let dead = id - resident;
                for _ in 0..9 {
                    if let Some(p) = slab.get(slot, id) {
                        acc = acc.wrapping_add(p[0]);
                    }
                }
                slab.remove(slots[dead as usize], dead);
            }
            std::hint::black_box((acc, slab.len()));
        },
    ));

    let mut scale_rows: Vec<Json> = Vec::new();

    section("million-task streaming metrics (event engine, Random)");
    // Heavy-overload operating point: the offered load far exceeds
    // capacity, so most tasks resolve at admission and the run's cost per
    // task is dominated by the decision + metrics path — exactly the
    // streaming-accumulator regime. Quick mode scales the arrival mass
    // down (~100k tasks) for CI; the full run crosses one million.
    let (lambda, slots, floor) = if quick {
        (5_000.0, 20, 50_000u64)
    } else {
        (25_000.0, 48, 1_000_000u64)
    };
    let c = cfg(EngineKind::Event, lambda, slots);
    scale_rows.push(scale_point("admission-bound", &c, floor).0);

    section("million-task live path (event engine, Random, capacity-matched)");
    // Execution-bound operating point: satellite capacity is raised so
    // the offered load is admissible and (nearly) every task walks the
    // full segment pipeline — arrival → FIFO → SegmentStart/Done →
    // IslTransfer — making the live-task arena and the pending-event heap
    // the hot structures. This is the row the slab arena exists for.
    let mut c = cfg(EngineKind::Event, lambda, slots);
    c.satellite.capacity_mflops = 5_000_000.0;
    c.satellite.max_workload_mflops = 50_000_000.0;
    let (row, exec_single) = scale_point("execution-bound", &c, floor);
    scale_rows.push(row);

    section("sharded pending-event queue (execution-bound, k=8 vs single heap)");
    // The per-plane sharded heap on the heap-heaviest operating point.
    // Same (time, seq) total order at any shard count, so the report must
    // be byte-identical to the single-heap run above — asserted here so a
    // bench run doubles as a whole-run regression check.
    c.shards = 8;
    let (row, exec_sharded) = scale_point("execution-bound sharded-queue k=8", &c, floor);
    scale_rows.push(row);
    assert_eq!(
        (exec_single.total_tasks, exec_single.completed_tasks),
        (exec_sharded.total_tasks, exec_sharded.completed_tasks),
        "sharded queue diverged from single heap"
    );
    assert_eq!(
        exec_single.avg_delay_ms.to_bits(),
        exec_sharded.avg_delay_ms.to_bits(),
        "sharded queue diverged from single heap (avg_delay bits)"
    );

    section("per-repeat sharded dispatch (million-task point, R repeats)");
    // The headline `sharded` row: R independent repeats of the
    // admission-bound operating point fanned over all cores through
    // `run_cells_repeated` vs forced-sequential. Per-repeat seeds are
    // position-derived, so the fan-out is byte-identical — only the wall
    // clock moves. Acceptance wants > 1.5x on a multi-core runner; the
    // CI gate warns instead of failing where cores are scarce.
    let repeats = if quick { 2usize } else { 4 };
    let rc = cfg(EngineKind::Event, lambda, slots);
    let run_rep = |threads: usize| -> (f64, Vec<satkit::metrics::Report>) {
        let t0 = std::time::Instant::now();
        let groups = satkit::experiments::run_cells_repeated(
            threads,
            repeats,
            vec![rc.clone()],
            |c, r| {
                let mut cc = c.clone();
                cc.seed = c.seed + r as u64 * 1000;
                satkit::engine::run(&cc, SchemeKind::Random)
            },
        );
        (t0.elapsed().as_secs_f64(), groups.into_iter().next().unwrap())
    };
    let (wall_seq, reps_seq) = run_rep(1);
    let (wall_par, reps_par) = run_rep(0);
    for (a, b) in reps_seq.iter().zip(&reps_par) {
        assert_eq!(
            (a.total_tasks, a.avg_delay_ms.to_bits()),
            (b.total_tasks, b.avg_delay_ms.to_bits()),
            "per-repeat fan-out diverged from sequential"
        );
    }
    let total_tasks: u64 = reps_par.iter().map(|r| r.total_tasks).sum();
    let seq_tps = total_tasks as f64 / wall_seq.max(1e-9);
    let par_tps = total_tasks as f64 / wall_par.max(1e-9);
    let speedup = wall_seq / wall_par.max(1e-9);
    println!(
        "sharded (R={repeats}): seq {wall_seq:.2}s ({seq_tps:.0} tasks/s) \
         -> fanned {wall_par:.2}s ({par_tps:.0} tasks/s), speedup {speedup:.2}x"
    );
    scale_rows.push(Json::obj(vec![
        ("point", Json::Str("sharded".to_string())),
        ("repeats", Json::Num(repeats as f64)),
        ("tasks", Json::Num(total_tasks as f64)),
        ("wall_s", Json::Num(wall_par)),
        ("tasks_per_s", Json::Num(par_tps)),
        ("single_shard_tasks_per_s", Json::Num(seq_tps)),
        ("speedup", Json::Num(speedup)),
    ]));

    section("pooled generation evaluation (SCC, decide-threads 1 vs 4)");
    // Intra-run decision parallelism: the same event-engine run with the
    // GA's generation evaluation fanned across the persistent EvalPool
    // (--decide-threads 4) vs the sequential oracle. RNG stays on the
    // coordinator, so the whole run is byte-identical at any lane count —
    // asserted here so the bench doubles as a regression check; only the
    // wall clock moves.
    let (pd_lambda, pd_slots) = if quick { (60.0, 8) } else { (120.0, 20) };
    let pd = cfg(EngineKind::Event, pd_lambda, pd_slots);
    let run_pd = |threads: usize, c: &SimConfig| -> (f64, satkit::metrics::Report) {
        let mut cc = c.clone();
        cc.decide_threads = threads;
        let t0 = std::time::Instant::now();
        let rep = satkit::engine::run(&cc, SchemeKind::Scc);
        (t0.elapsed().as_secs_f64(), rep)
    };
    // warm once so first-touch costs don't land on the timed sequential run
    let _ = run_pd(1, &pd);
    let (pd_wall_seq, pd_rep_seq) = run_pd(1, &pd);
    let (pd_wall_par, pd_rep_par) = run_pd(4, &pd);
    assert_eq!(
        (pd_rep_seq.total_tasks, pd_rep_seq.completed_tasks),
        (pd_rep_par.total_tasks, pd_rep_par.completed_tasks),
        "pooled decide diverged from sequential"
    );
    assert_eq!(
        pd_rep_seq.avg_delay_ms.to_bits(),
        pd_rep_par.avg_delay_ms.to_bits(),
        "pooled decide diverged from sequential (avg_delay bits)"
    );
    let pd_tasks = pd_rep_par.total_tasks;
    let pd_seq_tps = pd_tasks as f64 / pd_wall_seq.max(1e-9);
    let pd_par_tps = pd_tasks as f64 / pd_wall_par.max(1e-9);
    let pd_speedup = pd_wall_seq / pd_wall_par.max(1e-9);
    println!(
        "pooled-decide: seq {pd_wall_seq:.2}s ({pd_seq_tps:.0} tasks/s) \
         -> T=4 {pd_wall_par:.2}s ({pd_par_tps:.0} tasks/s), speedup {pd_speedup:.2}x"
    );
    scale_rows.push(Json::obj(vec![
        ("point", Json::Str("pooled-decide".to_string())),
        ("decide_threads", Json::Num(4.0)),
        ("tasks", Json::Num(pd_tasks as f64)),
        ("wall_s", Json::Num(pd_wall_par)),
        ("tasks_per_s", Json::Num(pd_par_tps)),
        ("sequential_tasks_per_s", Json::Num(pd_seq_tps)),
        ("speedup", Json::Num(pd_speedup)),
    ]));

    let path = satkit::bench::out_path("SATKIT_EVENTSIM_JSON", "BENCH_eventsim.json");
    let n_scale = scale_rows.len();
    let json = Json::obj(vec![
        ("bench", Json::Str("eventsim".into())),
        ("quick", Json::Bool(quick)),
        (
            "results",
            Json::Arr(all.iter().map(|r| r.to_json()).collect()),
        ),
        ("scale", Json::Arr(scale_rows)),
    ]);
    write_json(&path, &json).expect("writing bench json");
    println!(
        "\nwrote {path} ({} results, {n_scale} scale points)",
        all.len()
    );
}
