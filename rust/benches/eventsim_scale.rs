//! Bench: event-kernel scaling. The slotted engine's cost grows with
//! wall-clock slots regardless of traffic; the event engine's grows with
//! events (≈ arrivals × L). This target times both engines over a λ ramp
//! and a horizon ramp so the crossover is visible, sweeps the four
//! traffic scenarios at a fixed operating point, and finishes with the
//! million-task streaming-metrics demonstration: with the default
//! (non-retaining) metrics path, memory stays flat in task count.

use satkit::bench::{bench, quick_mode, section};
use satkit::config::{EngineKind, ScenarioKind, SimConfig};
use satkit::offload::SchemeKind;

/// Peak resident set (VmHWM) from procfs, for the memory-flat check.
fn peak_rss() -> String {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("VmHWM"))
                .map(|l| l.split_whitespace().skip(1).collect::<Vec<_>>().join(" "))
        })
        .map(|v| format!("peak_rss={v}"))
        .unwrap_or_else(|| "peak_rss=n/a".to_string())
}

fn cfg(engine: EngineKind, lambda: f64, slots: usize) -> SimConfig {
    SimConfig {
        n: 8,
        slots,
        lambda,
        seed: 42,
        engine,
        ..SimConfig::default()
    }
}

fn main() {
    let quick = quick_mode();
    let iters = if quick { 1 } else { 3 };

    section("engine wall time vs lambda (N=8, 20 s horizon, Random)");
    let lambdas: &[f64] = if quick { &[10.0, 40.0] } else { &[4.0, 10.0, 25.0, 40.0, 70.0] };
    for &lam in lambdas {
        for engine in EngineKind::all() {
            let c = cfg(engine, lam, if quick { 8 } else { 20 });
            let r = bench(
                &format!("{:<7} lambda={lam}", engine.name()),
                0,
                iters,
                || {
                    satkit::engine::run(&c, SchemeKind::Random);
                },
            );
            println!("{}", r.row());
        }
    }

    section("engine wall time vs horizon (N=8, lambda=10, Random)");
    let horizons: &[usize] = if quick { &[10, 40] } else { &[10, 40, 160, 640] };
    for &slots in horizons {
        for engine in EngineKind::all() {
            let c = cfg(engine, 10.0, slots);
            let r = bench(
                &format!("{:<7} horizon={slots}s", engine.name()),
                0,
                iters,
                || {
                    satkit::engine::run(&c, SchemeKind::Random);
                },
            );
            println!("{}", r.row());
        }
    }

    section("traffic scenarios on the event engine (lambda=25, SCC)");
    for scenario in ScenarioKind::all() {
        let mut c = cfg(EngineKind::Event, 25.0, if quick { 8 } else { 20 });
        c.scenario = scenario;
        let mut last_var = 0.0;
        let r = bench(&format!("scenario={}", scenario.name()), 0, iters, || {
            let rep = satkit::engine::run(&c, SchemeKind::Scc);
            last_var = rep.workload_variance;
        });
        println!("{}  workload_var={last_var:.3e}", r.row());
    }

    section("million-task streaming metrics (event engine, Random)");
    // Heavy-overload operating point: the offered load far exceeds
    // capacity, so most tasks resolve at admission and the run's cost per
    // task is dominated by the decision + metrics path — exactly the
    // streaming-accumulator regime. Quick mode scales the arrival mass
    // down (~100k tasks) for CI; the full run crosses one million.
    let (lambda, slots, floor) = if quick {
        (5_000.0, 20, 50_000u64)
    } else {
        (25_000.0, 48, 1_000_000u64)
    };
    let c = cfg(EngineKind::Event, lambda, slots);
    let t0 = std::time::Instant::now();
    let rep = satkit::engine::run(&c, SchemeKind::Random);
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "tasks={} completed={} drop_rate={:.3} wall={:.2}s ({:.0} tasks/s) {}",
        rep.total_tasks,
        rep.completed_tasks,
        rep.drop_rate(),
        wall,
        rep.total_tasks as f64 / wall.max(1e-9),
        peak_rss()
    );
    assert!(
        rep.outcomes.is_none(),
        "streaming path must not buffer outcomes"
    );
    assert!(
        rep.total_tasks >= floor,
        "scale run produced {} tasks, expected >= {floor}",
        rep.total_tasks
    );
}
