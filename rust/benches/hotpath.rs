//! Hot-path microbenches (§Perf): the pieces the profiler identified —
//! Eq. 12 deficit evaluation (reference and indexed kernels), GA
//! reproduction, Alg. 1 splitting, one simulator slot per scheme, and
//! (when artifacts exist) raw PJRT slice execution latency.
//!
//! Emits `BENCH_hotpath.json` (override the path with `SATKIT_BENCH_JSON`)
//! so the perf trajectory is machine-readable; quick mode is recorded in
//! the file since quick numbers are not comparable to full ones.

use satkit::bench::{
    bench, bench_per_item, out_path, quick_mode, section, write_suite_json, BenchResult,
};
use satkit::config::{GaConfig, SimConfig};
use satkit::dnn::DnnModel;
use satkit::offload::{
    make_scheme, BatchScratch, DecisionSpaceIndex, DeficitScratch, Gene, OffloadContext,
    SchemeKind,
};
use satkit::satellite::Satellite;
use satkit::sim::Simulation;
use satkit::splitting::balanced_split;
use satkit::state::StateView;
use satkit::topology::Constellation;
use satkit::util::rng::Pcg64;

fn main() {
    let quick = quick_mode();
    let iters = if quick { 20 } else { 200 };
    let mut all: Vec<BenchResult> = Vec::new();
    let mut show = |r: BenchResult| {
        println!("{}", r.row());
        all.push(r);
    };

    section("Eq.12 deficit evaluation");
    let topo = Constellation::torus(10);
    let mut sats: Vec<Satellite> =
        (0..100).map(|i| Satellite::new(i, 3000.0, 15000.0)).collect();
    let mut rng = Pcg64::seed_from_u64(1);
    for s in sats.iter_mut() {
        s.try_load(rng.f64_in(0.0, 12_000.0));
    }
    let ga = GaConfig::default();
    let cands = topo.decision_space(42, 3);
    let segments = vec![3800.0, 3900.0, 3700.0, 3800.0];
    let ctx = OffloadContext {
        topo: &topo,
        view: StateView::live(&sats),
        origin: 42,
        candidates: &cands,
        segments: &segments,
        kappa: 1e-4,
        ga: &ga,
        migration: None,
        outages: None,
    };
    let chrom: Vec<usize> = (0..4).map(|_| *rng.choose(&cands)).collect();
    show(bench("deficit(L=4, |A_x|=25) reference", 100, iters * 50, || {
        std::hint::black_box(ctx.deficit(&chrom));
    }));

    // the indexed kernel the GA actually runs on: gene chromosome over the
    // per-decision hop LUT + cached satellite arrays
    let index = DecisionSpaceIndex::from_ctx(&ctx);
    let genes: Vec<Gene> = chrom
        .iter()
        .map(|c| cands.iter().position(|x| x == c).unwrap() as Gene)
        .collect();
    show(bench("deficit(L=4, |A_x|=25) indexed", 100, iters * 50, || {
        std::hint::black_box(index.deficit(&genes));
    }));
    let mut scratch = DeficitScratch::default();
    let mut flip = genes.clone();
    let mut which = 0usize;
    show(bench(
        "deficit(L=4, |A_x|=25) incremental (1-gene delta)",
        100,
        iters * 50,
        || {
            // alternate one gene so every evaluation is a single-gene delta
            flip[0] = (which % 2) as Gene;
            which += 1;
            std::hint::black_box(index.deficit_with(&mut scratch, &flip));
        },
    ));

    // the whole-generation batched kernel vs a scalar loop over the same
    // GA-generation-sized chromosome matrix; both rows are normalized per
    // chromosome so they compare directly with the scalar/incremental
    // rows above (CI gates on batched <= scalar)
    let gen_size = 64usize;
    let mut brng = Pcg64::seed_from_u64(2);
    let flat: Vec<Gene> = (0..gen_size * segments.len())
        .map(|_| brng.usize_in(0, cands.len()) as Gene)
        .collect();
    let mut batch = BatchScratch::default();
    let mut outs: Vec<f64> = Vec::new();
    index.deficit_batch(&mut batch, &flat, &mut outs);
    for (c, &d) in flat.chunks(segments.len()).zip(&outs) {
        assert_eq!(
            d.to_bits(),
            index.deficit(c).to_bits(),
            "batched kernel diverged from the scalar oracle"
        );
    }
    show(bench_per_item(
        "deficit(L=4, |A_x|=25) scalar x64 (per-chrom)",
        gen_size,
        100,
        iters * 50,
        || {
            for c in flat.chunks(segments.len()) {
                std::hint::black_box(index.deficit(c));
            }
        },
    ));
    show(bench_per_item(
        "deficit_batch(L=4, |A_x|=25, B=64) per-chrom",
        gen_size,
        100,
        iters * 50,
        || {
            index.deficit_batch(&mut batch, &flat, &mut outs);
            std::hint::black_box(outs.last().copied());
        },
    ));

    // the same batch through the explicit SIMD lanes (`--features simd`):
    // when the feature is off or the CPU lacks the lanes this measures
    // the scalar dispatcher again, and the row label says so — the CI
    // perf gate only hard-asserts on the simd-active label. Bitwise
    // self-check against the scalar oracle before timing, like the
    // batched row above.
    let simd_on = satkit::offload::simd_active();
    let mut simd_outs: Vec<f64> = Vec::new();
    index.deficit_batch(&mut batch, &flat, &mut simd_outs);
    for (c, &d) in flat.chunks(segments.len()).zip(&simd_outs) {
        assert_eq!(
            d.to_bits(),
            index.deficit(c).to_bits(),
            "SIMD kernel diverged from the scalar oracle"
        );
    }
    show(bench_per_item(
        &format!(
            "simd deficit_batch(L=4, |A_x|=25, B=64) per-chrom [{}]",
            if simd_on { "simd-active" } else { "scalar-fallback" }
        ),
        gen_size,
        100,
        iters * 50,
        || {
            index.deficit_batch(&mut batch, &flat, &mut simd_outs);
            std::hint::black_box(simd_outs.last().copied());
        },
    ));

    // pooled generation evaluation (--decide-threads): the persistent
    // EvalPool splits a generation into contiguous chromosome chunks
    // evaluated concurrently into pre-sized slots. The pair below runs a
    // generation big enough to amortize the wake-up (B=4096; B=64 stays
    // on the inline path by design) sequentially and through a 4-lane
    // pool. Row names deliberately do NOT start with "deficit_batch" —
    // the CI gates key on that prefix for the B=64 rows above. Bitwise
    // self-check against the sequential kernel before timing.
    section("pooled generation evaluation (--decide-threads)");
    let pool_b = 4096usize;
    let flat_big: Vec<Gene> = (0..pool_b * segments.len())
        .map(|_| brng.usize_in(0, cands.len()) as Gene)
        .collect();
    let mut seq_outs: Vec<f64> = Vec::new();
    index.deficit_batch(&mut batch, &flat_big, &mut seq_outs);
    let pool = satkit::offload::pool::EvalPool::new(4);
    let mut pool_outs: Vec<f64> = Vec::new();
    pool.deficit_batch(&index, &mut batch, &flat_big, &mut pool_outs);
    assert_eq!(seq_outs.len(), pool_outs.len());
    for (i, (&s, &p)) in seq_outs.iter().zip(&pool_outs).enumerate() {
        assert_eq!(
            s.to_bits(),
            p.to_bits(),
            "pooled kernel diverged from the sequential oracle at chrom {i}"
        );
    }
    let pool_iters = if quick { 10 } else { 60 };
    show(bench_per_item(
        &format!("seq deficit_batch(L=4, |A_x|=25, B={pool_b}) per-chrom"),
        pool_b,
        5,
        pool_iters,
        || {
            index.deficit_batch(&mut batch, &flat_big, &mut seq_outs);
            std::hint::black_box(seq_outs.last().copied());
        },
    ));
    show(bench_per_item(
        &format!(
            "pooled deficit_batch(L=4, |A_x|=25, B={pool_b}, T={}) per-chrom",
            pool.lanes()
        ),
        pool_b,
        5,
        pool_iters,
        || {
            pool.deficit_batch(&index, &mut batch, &flat_big, &mut pool_outs);
            std::hint::black_box(pool_outs.last().copied());
        },
    ));

    section("scheme decide() per task");
    for kind in SchemeKind::all() {
        let mut scheme = make_scheme(kind, 7);
        show(bench(&format!("{} decide", kind.name()), 3, iters, || {
            std::hint::black_box(scheme.decide(&ctx));
        }));
    }

    section("Alg.1 balanced split");
    for model in [DnnModel::Vgg19, DnnModel::Resnet101] {
        let w = model.profile().workloads();
        let (l, _) = model.table1_defaults();
        show(bench(&format!("{} split L={l}", model.name()), 10, iters * 10, || {
            std::hint::black_box(balanced_split(&w, l, 1.0));
        }));
    }

    section("one simulated slot (N=10, lambda=25)");
    for kind in SchemeKind::all() {
        show(bench(&format!("{} slot", kind.name()), 0, if quick { 1 } else { 3 }, || {
            let cfg = SimConfig {
                slots: 1,
                ..SimConfig::default()
            };
            Simulation::new(&cfg, kind).run();
        }));
    }

    section("telemetry overhead (event engine, N=8, lambda=25)");
    // The off-row is what CI gates (<= 2% over the on-row would be
    // meaningless; the gate is off <= on * 1.02 — disabled hooks must not
    // cost more than the noise floor of the fully-instrumented run). The
    // hard guarantee that off-runs are BIT-IDENTICAL to the pre-telemetry
    // path is carried by tests/prop_telemetry.rs; this row tracks the
    // residual branch cost.
    let telem_cfg = SimConfig {
        n: 8,
        slots: if quick { 4 } else { 10 },
        lambda: 25.0,
        engine: satkit::config::EngineKind::Event,
        ..SimConfig::default()
    };
    let telem_iters = if quick { 5 } else { 20 };
    show(bench("telemetry-off", 1, telem_iters, || {
        std::hint::black_box(satkit::engine::run(&telem_cfg, SchemeKind::Scc));
    }));
    let mut telem_on = telem_cfg.clone();
    telem_on.obs.telemetry = true;
    show(bench("telemetry-on", 1, telem_iters, || {
        std::hint::black_box(satkit::engine::run(&telem_on, SchemeKind::Scc));
    }));

    section("PJRT slice execution (requires artifacts)");
    let dir = satkit::runtime::default_artifact_dir();
    if dir.join("vgg_slice.hlo.txt").exists() {
        let mut engine = satkit::runtime::Engine::cpu().unwrap();
        engine.load_dir(&dir).unwrap();
        for (name, n_in) in [("vgg_slice", 56 * 56 * 64), ("resnet_slice", 56 * 56 * 256), ("qnet", 256)] {
            let input: Vec<f32> = (0..n_in).map(|i| (i % 13) as f32 * 0.1).collect();
            show(bench(&format!("{name} execute"), 2, if quick { 5 } else { 20 }, || {
                std::hint::black_box(engine.run_f32(name, &[input.clone()]).unwrap());
            }));
        }
    } else {
        println!("skipped (run `make artifacts`)");
    }

    let path = out_path("SATKIT_BENCH_JSON", "BENCH_hotpath.json");
    write_suite_json(&path, "hotpath", quick, &all).expect("writing bench json");
    println!("\nwrote {path} ({} results)", all.len());
}
