//! Bench: regenerate Fig. 2 (ResNet101, L=4, D_M=3) — completion rate,
//! total average delay, and workload variance vs λ for all four schemes —
//! and time each (λ, scheme) cell.
//!
//! `SATKIT_BENCH_QUICK=1` shrinks the sweep for smoke runs.

use satkit::bench::{bench, quick_mode, section};
use satkit::dnn::DnnModel;
use satkit::experiments as exp;
use satkit::offload::SchemeKind;

fn main() {
    let quick = quick_mode();
    let opts = exp::SweepOpts {
        slots: if quick { 4 } else { 12 },
        ..exp::SweepOpts::default()
    };
    let lambdas: Vec<f64> = if quick {
        vec![4.0, 25.0]
    } else {
        exp::default_lambdas()
    };

    section("Fig 2 (ResNet101): generation");
    let rows = exp::lambda_sweep(DnnModel::Resnet101, &lambdas, &opts);
    println!("{}", exp::render_panels("Fig 2 — ResNet101", &rows, "lambda"));
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/fig2.json", exp::rows_to_json(&rows).to_string()).ok();
    println!("wrote results/fig2.json");

    section("Fig 2: per-cell decision cost");
    for scheme in SchemeKind::all() {
        let r = bench(
            &format!("resnet101 lambda=25 {}", scheme.name()),
            0,
            if quick { 1 } else { 3 },
            || {
                exp::run_point(DnnModel::Resnet101, 25.0, scheme, &exp::SweepOpts {
                    slots: 3,
                    ..opts.clone()
                });
            },
        );
        println!("{}", r.row());
    }
}
