//! Slab arena for live-task state: dense `u32` slot ids with free-list
//! reuse.
//!
//! The event kernel keeps one record per in-flight task. The original
//! implementation used a `BTreeMap<u64, LiveTask>` keyed on the task id —
//! every segment event paid an `O(log |live|)` pointer-chasing walk, and
//! insert/remove churned tree nodes at millions of tasks. The slab stores
//! records in a flat `Vec` instead: a slot id is a direct array index, a
//! freed slot goes on a free list and is reused by the next insert, and
//! the record's buffers (the `Vec`s inside the payload) stay allocated
//! across reuse.
//!
//! **ABA safety.** Events carry `(slot, id)` pairs: a slot id alone could
//! alias a *different* task after the slot was freed and reused, so every
//! access checks the occupant's id against the id the event carries. A
//! stale event therefore misses — exactly the semantics a `BTreeMap`
//! lookup of a removed key had.
//!
//! The structural win over the BTreeMap era is measured head-to-head by
//! `benches/eventsim_scale.rs` ("live-task bookkeeping" section) and the
//! engine-level determinism of slot reuse is enforced by
//! `tests/integration_eventsim.rs::arena_slot_reuse_is_deterministic_under_fault_churn`.

/// One slot of the arena: occupancy flag, occupant id, payload.
struct Slot<T> {
    occupied: bool,
    id: u64,
    value: T,
}

/// Free-list slab keyed by `(u32 slot, u64 id)` pairs.
pub struct Slab<T> {
    slots: Vec<Slot<T>>,
    free: Vec<u32>,
    live: usize,
}

impl<T: Default> Default for Slab<T> {
    fn default() -> Self {
        Slab::new()
    }
}

impl<T: Default> Slab<T> {
    pub fn new() -> Slab<T> {
        Slab::with_capacity(0)
    }

    pub fn with_capacity(cap: usize) -> Slab<T> {
        Slab {
            slots: Vec::with_capacity(cap),
            free: Vec::new(),
            live: 0,
        }
    }

    /// Insert a record under `id`, returning its slot. Reuses a freed slot
    /// when one exists (the common steady-state case), so the slot array
    /// stays as dense as the peak live population.
    pub fn insert(&mut self, id: u64, value: T) -> u32 {
        self.live += 1;
        if let Some(slot) = self.free.pop() {
            let s = &mut self.slots[slot as usize];
            debug_assert!(!s.occupied, "free-list slot still occupied");
            s.occupied = true;
            s.id = id;
            s.value = value;
            slot
        } else {
            let slot = u32::try_from(self.slots.len()).expect("slab slot space exhausted");
            self.slots.push(Slot {
                occupied: true,
                id,
                value,
            });
            slot
        }
    }

    /// The record in `slot` if it is still the one `id` names.
    pub fn get(&self, slot: u32, id: u64) -> Option<&T> {
        let s = &self.slots[slot as usize];
        if s.occupied && s.id == id {
            Some(&s.value)
        } else {
            None
        }
    }

    /// Mutable access with the same ABA check as [`Slab::get`].
    pub fn get_mut(&mut self, slot: u32, id: u64) -> Option<&mut T> {
        let s = &mut self.slots[slot as usize];
        if s.occupied && s.id == id {
            Some(&mut s.value)
        } else {
            None
        }
    }

    /// True when `slot` currently holds the record `id` names.
    pub fn contains(&self, slot: u32, id: u64) -> bool {
        let s = &self.slots[slot as usize];
        s.occupied && s.id == id
    }

    /// Remove and return the record in `slot` (checked against `id`).
    /// The payload is moved out and replaced with `T::default()`, so a
    /// payload whose buffers the caller recycles gives the slot fresh
    /// (empty) buffers for its next occupant.
    pub fn remove(&mut self, slot: u32, id: u64) -> Option<T> {
        let s = &mut self.slots[slot as usize];
        if !(s.occupied && s.id == id) {
            return None;
        }
        s.occupied = false;
        self.live -= 1;
        self.free.push(slot);
        Some(std::mem::take(&mut s.value))
    }

    /// Number of live records.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Number of slots ever allocated (the high-water mark of the live
    /// population — freed slots are retained for reuse).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut s: Slab<Vec<u64>> = Slab::new();
        let a = s.insert(10, vec![1, 2]);
        let b = s.insert(11, vec![3]);
        assert_ne!(a, b);
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(a, 10).unwrap(), &[1, 2]);
        assert_eq!(s.get_mut(b, 11).map(|v| v.pop()), Some(Some(3)));
        assert_eq!(s.remove(a, 10), Some(vec![1, 2]));
        assert_eq!(s.len(), 1);
        assert!(s.get(a, 10).is_none());
    }

    #[test]
    fn freed_slots_are_reused_densely() {
        let mut s: Slab<u64> = Slab::new();
        let a = s.insert(1, 100);
        let b = s.insert(2, 200);
        s.remove(a, 1);
        s.remove(b, 2);
        // LIFO reuse: the most recently freed slot comes back first
        assert_eq!(s.insert(3, 300), b);
        assert_eq!(s.insert(4, 400), a);
        assert_eq!(s.capacity(), 2, "no new slots were allocated");
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn stale_slot_id_cannot_alias_new_occupant() {
        let mut s: Slab<u64> = Slab::new();
        let a = s.insert(7, 700);
        s.remove(a, 7);
        let b = s.insert(8, 800);
        assert_eq!(a, b, "slot was reused");
        // an event still carrying (a, 7) must miss, not read task 8
        assert!(s.get(a, 7).is_none());
        assert!(!s.contains(a, 7));
        assert!(s.remove(a, 7).is_none());
        assert_eq!(s.get(b, 8), Some(&800));
    }

    #[test]
    fn remove_leaves_default_payload_in_slot() {
        let mut s: Slab<Vec<u8>> = Slab::new();
        let a = s.insert(1, vec![9; 16]);
        let taken = s.remove(a, 1).unwrap();
        assert_eq!(taken.len(), 16);
        let b = s.insert(2, Vec::new());
        assert_eq!(a, b);
        assert!(s.get(b, 2).unwrap().is_empty());
    }

    #[test]
    fn len_and_empty_track_live_records() {
        let mut s: Slab<u8> = Slab::with_capacity(8);
        assert!(s.is_empty());
        let a = s.insert(1, 0);
        assert_eq!(s.len(), 1);
        s.remove(a, 1);
        assert!(s.is_empty());
    }
}
