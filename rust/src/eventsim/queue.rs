//! Pending-event set of the discrete-event kernel: a binary heap keyed on
//! `(f64 time, u64 seq)`. The monotone sequence number breaks timestamp
//! ties in insertion order, which makes every run of the engine fully
//! deterministic — two events scheduled at the same instant always pop in
//! the order they were pushed, independent of heap internals.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Entry<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq && self.time.total_cmp(&other.time) == Ordering::Equal
    }
}

impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; reverse so the earliest (time, seq)
        // pops first. `total_cmp` gives f64 a total order (times are
        // asserted finite on push, so NaN never reaches the heap).
        self.time
            .total_cmp(&other.time)
            .then(self.seq.cmp(&other.seq))
            .reverse()
    }
}

/// Min-queue of timestamped events with deterministic FIFO tie-breaking.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    next_seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> EventQueue<E> {
        EventQueue::with_capacity(0)
    }

    /// Pre-sized queue: reserves heap storage for `cap` concurrently
    /// scheduled events up front, so a long run whose outstanding-event
    /// count is known (≈ one per live task plus one pending arrival per
    /// area) never pays mid-run heap regrowth.
    pub fn with_capacity(cap: usize) -> EventQueue<E> {
        EventQueue {
            heap: BinaryHeap::with_capacity(cap),
            next_seq: 0,
        }
    }

    /// Schedule `event` at absolute `time` [s]. Panics on non-finite time
    /// (a NaN key would corrupt the heap order silently).
    pub fn push(&mut self, time: f64, event: E) {
        assert!(time.is_finite(), "event time must be finite, got {time}");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Entry { time, seq, event });
    }

    /// Pop the earliest event; ties resolve in insertion order.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|e| (e.time, e.event))
    }

    /// Timestamp of the next event without removing it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.peek_time(), Some(1.0));
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn equal_times_pop_in_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..50u32 {
            q.push(1.5, i);
        }
        for i in 0..50u32 {
            assert_eq!(q.pop(), Some((1.5, i)));
        }
    }

    #[test]
    fn interleaved_ties_stay_fifo() {
        let mut q = EventQueue::new();
        q.push(2.0, "tie-1");
        q.push(1.0, "first");
        q.push(2.0, "tie-2");
        q.push(2.0, "tie-3");
        assert_eq!(q.pop().unwrap().1, "first");
        assert_eq!(q.pop().unwrap().1, "tie-1");
        assert_eq!(q.pop().unwrap().1, "tie-2");
        assert_eq!(q.pop().unwrap().1, "tie-3");
    }

    #[test]
    fn with_capacity_behaves_like_new() {
        let mut q = EventQueue::with_capacity(128);
        q.push(2.0, "b");
        q.push(1.0, "a");
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn len_and_empty_track_contents() {
        let mut q: EventQueue<u8> = EventQueue::new();
        assert!(q.is_empty());
        q.push(0.0, 1);
        q.push(0.0, 2);
        assert_eq!(q.len(), 2);
        q.pop();
        q.pop();
        assert!(q.is_empty());
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan_time() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }
}
