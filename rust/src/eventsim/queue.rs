//! Pending-event set of the discrete-event kernel: a binary heap keyed on
//! `(f64 time, u64 seq)`. The monotone sequence number breaks timestamp
//! ties in insertion order, which makes every run of the engine fully
//! deterministic — two events scheduled at the same instant always pop in
//! the order they were pushed, independent of heap internals.
//!
//! [`ShardedEventQueue`] partitions the same pending set across K
//! independent heaps (one per satellite plane in the engine's routing)
//! while preserving the exact global `(time, seq)` total order: sequence
//! numbers come from one shared counter, and `pop` merges by scanning the
//! K shard heads for the globally smallest key. Sharding therefore never
//! changes what pops when — only which heap each event waits in — so a
//! sharded run is byte-identical to the single-heap engine by
//! construction (and by `tests/prop_sharded.rs`). The win is structural:
//! each heap is K× smaller (shallower sift paths, hotter cache lines),
//! and the layout is the substrate the per-repeat sweep sharding in
//! `experiments::run_cells_repeated` scales across cores.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Entry<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq && self.time.total_cmp(&other.time) == Ordering::Equal
    }
}

impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; reverse so the earliest (time, seq)
        // pops first. `total_cmp` gives f64 a total order (times are
        // asserted finite on push, so NaN never reaches the heap).
        self.time
            .total_cmp(&other.time)
            .then(self.seq.cmp(&other.seq))
            .reverse()
    }
}

/// Min-queue of timestamped events with deterministic FIFO tie-breaking.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    next_seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> EventQueue<E> {
        EventQueue::with_capacity(0)
    }

    /// Pre-sized queue: reserves heap storage for `cap` concurrently
    /// scheduled events up front, so a long run whose outstanding-event
    /// count is known (≈ one per live task plus one pending arrival per
    /// area) never pays mid-run heap regrowth.
    pub fn with_capacity(cap: usize) -> EventQueue<E> {
        EventQueue {
            heap: BinaryHeap::with_capacity(cap),
            next_seq: 0,
        }
    }

    /// Schedule `event` at absolute `time` [s]. Panics on non-finite time
    /// (a NaN key would corrupt the heap order silently).
    pub fn push(&mut self, time: f64, event: E) {
        assert!(time.is_finite(), "event time must be finite, got {time}");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Entry { time, seq, event });
    }

    /// Pop the earliest event; ties resolve in insertion order.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|e| (e.time, e.event))
    }

    /// Timestamp of the next event without removing it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// The pending-event set split across K independent heaps with one shared
/// sequence counter. Push routes to a caller-chosen shard (the engine maps
/// satellites to orbital planes); pop scans the K shard heads and removes
/// the globally smallest `(time, seq)` key. Because `seq` assignment order
/// and the pop order are both identical to a single [`EventQueue`] fed the
/// same pushes, the shard routing affects only heap balance — never the
/// event order — so sharded runs stay bit-for-bit reproducible.
pub struct ShardedEventQueue<E> {
    shards: Vec<BinaryHeap<Entry<E>>>,
    next_seq: u64,
    len: usize,
}

impl<E> ShardedEventQueue<E> {
    /// `shards` heaps (clamped to >= 1), each pre-sized so the shards
    /// together hold `cap` concurrently scheduled events without regrowth
    /// — the sharded extension of [`EventQueue::with_capacity`].
    pub fn with_capacity(shards: usize, cap: usize) -> ShardedEventQueue<E> {
        let shards = shards.max(1);
        #[allow(clippy::manual_div_ceil)] // `div_ceil` needs a newer MSRV
        let per_shard = (cap + shards - 1) / shards;
        ShardedEventQueue {
            shards: (0..shards)
                .map(|_| BinaryHeap::with_capacity(per_shard))
                .collect(),
            next_seq: 0,
            len: 0,
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Schedule `event` at absolute `time` [s] on `shard` (taken modulo
    /// the shard count, so callers can route by plane id directly).
    /// Panics on non-finite time, like [`EventQueue::push`].
    pub fn push(&mut self, shard: usize, time: f64, event: E) {
        assert!(time.is_finite(), "event time must be finite, got {time}");
        let seq = self.next_seq;
        self.next_seq += 1;
        let k = shard % self.shards.len();
        self.shards[k].push(Entry { time, seq, event });
        self.len += 1;
    }

    /// Index of the shard holding the globally next `(time, seq)` key.
    fn min_shard(&self) -> Option<usize> {
        let mut best: Option<(f64, u64, usize)> = None;
        for (i, h) in self.shards.iter().enumerate() {
            if let Some(e) = h.peek() {
                let earlier = match best {
                    None => true,
                    Some((t, s, _)) => {
                        e.time.total_cmp(&t).then(e.seq.cmp(&s)) == Ordering::Less
                    }
                };
                if earlier {
                    best = Some((e.time, e.seq, i));
                }
            }
        }
        best.map(|(_, _, i)| i)
    }

    /// Pop the globally earliest event; ties resolve in push order across
    /// all shards — the same total order as a single [`EventQueue`].
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let i = self.min_shard()?;
        self.len -= 1;
        self.shards[i].pop().map(|e| (e.time, e.event))
    }

    /// Timestamp of the globally next event without removing it.
    pub fn peek_time(&self) -> Option<f64> {
        self.min_shard()
            .and_then(|i| self.shards[i].peek().map(|e| e.time))
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.peek_time(), Some(1.0));
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn equal_times_pop_in_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..50u32 {
            q.push(1.5, i);
        }
        for i in 0..50u32 {
            assert_eq!(q.pop(), Some((1.5, i)));
        }
    }

    #[test]
    fn interleaved_ties_stay_fifo() {
        let mut q = EventQueue::new();
        q.push(2.0, "tie-1");
        q.push(1.0, "first");
        q.push(2.0, "tie-2");
        q.push(2.0, "tie-3");
        assert_eq!(q.pop().unwrap().1, "first");
        assert_eq!(q.pop().unwrap().1, "tie-1");
        assert_eq!(q.pop().unwrap().1, "tie-2");
        assert_eq!(q.pop().unwrap().1, "tie-3");
    }

    #[test]
    fn with_capacity_behaves_like_new() {
        let mut q = EventQueue::with_capacity(128);
        q.push(2.0, "b");
        q.push(1.0, "a");
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn len_and_empty_track_contents() {
        let mut q: EventQueue<u8> = EventQueue::new();
        assert!(q.is_empty());
        q.push(0.0, 1);
        q.push(0.0, 2);
        assert_eq!(q.len(), 2);
        q.pop();
        q.pop();
        assert!(q.is_empty());
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan_time() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }

    #[test]
    fn sharded_pops_in_global_time_order() {
        let mut q = ShardedEventQueue::with_capacity(4, 16);
        q.push(0, 3.0, "c");
        q.push(1, 1.0, "a");
        q.push(2, 2.0, "b");
        assert_eq!(q.peek_time(), Some(1.0));
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn sharded_ties_stay_fifo_across_shards() {
        // equal timestamps scattered over different shards must still pop
        // in push order — the shared seq counter carries the total order
        let mut q = ShardedEventQueue::with_capacity(3, 0);
        for i in 0..60u32 {
            q.push((i % 3) as usize, 1.5, i);
        }
        for i in 0..60u32 {
            assert_eq!(q.pop(), Some((1.5, i)));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn sharded_matches_single_queue_oracle() {
        // random push sequence with random shard routing: the pop
        // sequence must equal a single EventQueue fed the same pushes
        let mut rng = crate::util::rng::Pcg64::seed_from_u64(7);
        for &shards in &[1usize, 2, 3, 8] {
            let mut sq = ShardedEventQueue::with_capacity(shards, 8);
            let mut oracle = EventQueue::new();
            let mut id = 0u64;
            for _ in 0..500 {
                if rng.f64() < 0.6 || oracle.is_empty() {
                    // coarse times force plenty of exact ties
                    let t = (rng.usize_in(0, 20) as f64) * 0.5;
                    sq.push(rng.usize_in(0, shards + 1), t, id);
                    oracle.push(t, id);
                    id += 1;
                } else {
                    assert_eq!(sq.peek_time(), oracle.peek_time());
                    assert_eq!(sq.pop(), oracle.pop());
                }
                assert_eq!(sq.len(), oracle.len());
            }
            while let Some(want) = oracle.pop() {
                assert_eq!(sq.pop(), Some(want));
            }
            assert!(sq.is_empty());
        }
    }

    #[test]
    fn sharded_clamps_zero_shards_and_wraps_routing() {
        let mut q = ShardedEventQueue::with_capacity(0, 0);
        assert_eq!(q.num_shards(), 1);
        q.push(99, 1.0, "wrapped");
        assert_eq!(q.pop(), Some((1.0, "wrapped")));
    }
}
