//! Satellite compute state (§III-C): per-satellite capacity `C_x`, loaded
//! workload `q`, the admission rule of Eq. 4 (`W = q + m_k < M_w`), and
//! per-slot service that drains the backlog at `C_x` MFLOP per slot.

use crate::topology::SatId;

/// Outcome of attempting to load a segment (Eq. 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Segment loaded; inference proceeds on this satellite.
    Accepted,
    /// `q + m_k >= M_w` — segment rejected, task dropped at this point.
    Rejected,
}

/// One satellite's mutable compute state.
#[derive(Clone, Debug)]
pub struct Satellite {
    pub id: SatId,
    /// C_x — computation capability [MFLOP/slot].
    pub capacity_mflops: f64,
    /// M_w — maximum total loaded workload [MFLOP].
    pub max_workload_mflops: f64,
    /// q — currently loaded (queued + executing) workload [MFLOP].
    loaded_mflops: f64,
    /// Total workload ever assigned (the Fig. 2(c)/3(c) variance metric).
    pub assigned_total_mflops: f64,
    /// Count of segments accepted / rejected (diagnostics).
    pub accepted: u64,
    pub rejected: u64,
}

impl Satellite {
    pub fn new(id: SatId, capacity_mflops: f64, max_workload_mflops: f64) -> Satellite {
        assert!(capacity_mflops > 0.0 && max_workload_mflops > 0.0);
        Satellite {
            id,
            capacity_mflops,
            max_workload_mflops,
            loaded_mflops: 0.0,
            assigned_total_mflops: 0.0,
            accepted: 0,
            rejected: 0,
        }
    }

    /// q — the workload already loaded [MFLOP].
    pub fn loaded(&self) -> f64 {
        self.loaded_mflops
    }

    /// Residual admissible workload `M_w − q` (the RRP scheme's ranking key).
    pub fn residual(&self) -> f64 {
        (self.max_workload_mflops - self.loaded_mflops).max(0.0)
    }

    /// Would a segment of `m_k` MFLOP be admitted right now? (Eq. 4,
    /// without mutating state — used by offloading schemes to plan.)
    pub fn would_admit(&self, m_k: f64) -> bool {
        self.loaded_mflops + m_k < self.max_workload_mflops
    }

    /// Eq. 4: try to load a segment. On success `q += m_k`.
    pub fn try_load(&mut self, m_k: f64) -> Admission {
        debug_assert!(m_k >= 0.0);
        if self.would_admit(m_k) {
            self.loaded_mflops += m_k;
            self.assigned_total_mflops += m_k;
            self.accepted += 1;
            Admission::Accepted
        } else {
            self.rejected += 1;
            Admission::Rejected
        }
    }

    /// Release `m_k` MFLOP of committed load once its service completes.
    /// The event-driven engine drains per segment at completion time; the
    /// slotted engine drains per slot via [`Satellite::service_slot`].
    /// Saturates at zero so a fault-time [`Satellite::reset`] followed by
    /// late completions of pre-fault work cannot drive `q` negative.
    pub fn complete(&mut self, m_k: f64) {
        debug_assert!(m_k >= 0.0);
        self.loaded_mflops = (self.loaded_mflops - m_k).max(0.0);
    }

    /// Advance one slot: the satellite executes up to `C_x` MFLOP of its
    /// backlog. Returns the amount actually processed.
    pub fn service_slot(&mut self) -> f64 {
        let done = self.loaded_mflops.min(self.capacity_mflops);
        self.loaded_mflops -= done;
        done
    }

    /// Computation seconds for `m_k` MFLOP on this satellite (Eq. 5 term).
    pub fn comp_secs(&self, m_k: f64) -> f64 {
        m_k / self.capacity_mflops
    }

    /// Queue-aware service seconds: the satellite drains its backlog FIFO
    /// at `C_x`, so a newly loaded segment waits `(q - m_k)/C_x` before
    /// its own `m_k/C_x` of service — i.e. `q/C_x` with `q` the post-load
    /// backlog. This is Eq. 5 extended with waiting time; it is what makes
    /// the paper's "fittest-satellite herding inflates delay" observation
    /// (§V-B) measurable.
    pub fn service_secs_with_queue(&self, m_k: f64) -> f64 {
        // called AFTER try_load succeeded: loaded() already includes m_k
        debug_assert!(self.loaded_mflops >= m_k);
        self.loaded_mflops / self.capacity_mflops
    }

    /// Utilization of the admission window, `q / M_w` in [0, 1].
    pub fn utilization(&self) -> f64 {
        (self.loaded_mflops / self.max_workload_mflops).clamp(0.0, 1.0)
    }

    /// Reset transient load (between independent experiment repetitions).
    pub fn reset(&mut self) {
        self.loaded_mflops = 0.0;
        self.assigned_total_mflops = 0.0;
        self.accepted = 0;
        self.rejected = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sat() -> Satellite {
        Satellite::new(0, 3000.0, 15000.0)
    }

    #[test]
    fn admission_rule_eq4_strict() {
        let mut s = sat();
        // fill to just under M_w
        assert_eq!(s.try_load(14999.0), Admission::Accepted);
        // q + m >= M_w rejected (strict <)
        assert_eq!(s.try_load(1.0), Admission::Rejected);
        assert_eq!(s.try_load(0.5), Admission::Accepted);
        assert_eq!(s.accepted, 2);
        assert_eq!(s.rejected, 1);
    }

    #[test]
    fn boundary_exact_mw_rejected() {
        let mut s = sat();
        assert_eq!(s.try_load(15000.0), Admission::Rejected); // W == M_w
        assert_eq!(s.try_load(14999.999), Admission::Accepted);
    }

    #[test]
    fn service_drains_at_capacity() {
        let mut s = sat();
        s.try_load(7000.0);
        assert_eq!(s.service_slot(), 3000.0);
        assert_eq!(s.loaded(), 4000.0);
        assert_eq!(s.service_slot(), 3000.0);
        assert_eq!(s.service_slot(), 1000.0);
        assert_eq!(s.service_slot(), 0.0);
    }

    #[test]
    fn residual_tracks_load() {
        let mut s = sat();
        assert_eq!(s.residual(), 15000.0);
        s.try_load(5000.0);
        assert_eq!(s.residual(), 10000.0);
    }

    #[test]
    fn comp_secs_eq5() {
        let s = sat();
        assert!((s.comp_secs(6000.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn utilization_bounded() {
        let mut s = sat();
        assert_eq!(s.utilization(), 0.0);
        s.try_load(7500.0);
        assert!((s.utilization() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn complete_releases_and_saturates() {
        let mut s = sat();
        s.try_load(5000.0);
        s.complete(2000.0);
        assert_eq!(s.loaded(), 3000.0);
        s.complete(9000.0); // more than loaded: clamps at 0
        assert_eq!(s.loaded(), 0.0);
        // assigned total is a lifetime counter, not released
        assert_eq!(s.assigned_total_mflops, 5000.0);
    }

    #[test]
    fn reset_clears_transient() {
        let mut s = sat();
        s.try_load(100.0);
        s.reset();
        assert_eq!(s.loaded(), 0.0);
        assert_eq!(s.assigned_total_mflops, 0.0);
    }
}
