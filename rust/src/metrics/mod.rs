//! Delay / drop accounting (§III-D, Eq. 5–9) and the three evaluation
//! metrics of §V-B: task completion rate, total average delay, and the
//! variance of total workload assigned to each satellite.
//!
//! Metrics **stream**: each [`TaskOutcome`] folds into constant-size
//! accumulators (Welford count/mean/M2 per delay component plus a
//! fixed-size log-spaced delay histogram for percentiles) the moment it is
//! recorded, so memory stays flat in task count and million-task runs
//! don't buffer millions of outcomes. Full outcomes are retained only
//! behind the [`MetricsCollector::retaining`] flag
//! (`SimConfig::retain_outcomes` / `--retain-outcomes`), for consumers
//! that need per-task data (plots, traces).

use crate::topology::SatId;
use crate::util::json::Json;
use crate::util::stats;
use crate::util::stats::Welford;

/// Outcome of one task after splitting + offloading + execution.
#[derive(Clone, Debug)]
pub struct TaskOutcome {
    pub task_id: u64,
    pub origin: SatId,
    /// Drop point dp ∈ {1..L} if dropped, or L+1 if completed (11d).
    pub drop_point: usize,
    /// L — segment count for this task.
    pub l: usize,
    /// Σ computation delay over its executed segments [s] (Eq. 5 terms).
    pub comp_delay_s: f64,
    /// Σ transmission delay over its executed hops [s] (Eq. 7 terms).
    pub tran_delay_s: f64,
    /// Gateway uplink delay [s] (Eq. 1; identical distribution across
    /// schemes, included for end-to-end realism).
    pub uplink_delay_s: f64,
    /// Continuous timestamp at which the outcome was decided [s]: last
    /// segment completion for completed tasks, rejection/abort instant for
    /// dropped ones. The slotted engine synthesizes it from the arrival
    /// slot plus the analytic delays; the event engine records the actual
    /// event-clock instant.
    pub finish_time_s: f64,
}

impl TaskOutcome {
    pub fn completed(&self) -> bool {
        self.drop_point == self.l + 1
    }

    /// Eq. 8 per-task total (comp + tran); uplink reported separately.
    pub fn total_delay_s(&self) -> f64 {
        self.comp_delay_s + self.tran_delay_s
    }
}

/// Per-satellite accumulators (Eq. 5/7 are per-satellite sums).
#[derive(Clone, Debug, Default)]
pub struct SatelliteTotals {
    pub comp_delay_s: f64,
    pub tran_delay_s: f64,
    pub assigned_mflops: f64,
    pub segments_executed: u64,
    pub segments_rejected: u64,
}

/// Fixed-size log-spaced histogram of per-task delays [ms] for streaming
/// percentile estimates: [`HIST_BINS`] bins over
/// `[HIST_MIN_MS, HIST_MAX_MS]` give ≈ ±1.1% relative resolution, with the
/// extreme bins absorbing under/overflow. Memory is constant in task
/// count — the piece that lets million-task runs keep percentiles without
/// buffering every outcome.
#[derive(Clone, Debug)]
pub struct DelayHistogram {
    counts: Vec<u64>,
    total: u64,
}

/// Bin count (8 KiB of u64 counters).
pub const HIST_BINS: usize = 1024;
/// Lower edge [ms]; smaller samples land in bin 0.
pub const HIST_MIN_MS: f64 = 1e-3;
/// Upper edge [ms]; larger samples land in the last bin.
pub const HIST_MAX_MS: f64 = 1e7;

impl Default for DelayHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl DelayHistogram {
    pub fn new() -> DelayHistogram {
        DelayHistogram {
            counts: vec![0; HIST_BINS],
            total: 0,
        }
    }

    fn bin_of(x_ms: f64) -> usize {
        if !(x_ms > HIST_MIN_MS) {
            return 0; // ≤ lower edge (and NaN) → first bin
        }
        if x_ms >= HIST_MAX_MS {
            return HIST_BINS - 1;
        }
        let f = (x_ms / HIST_MIN_MS).ln() / (HIST_MAX_MS / HIST_MIN_MS).ln();
        ((f * HIST_BINS as f64) as usize).min(HIST_BINS - 1)
    }

    pub fn record(&mut self, x_ms: f64) {
        self.counts[Self::bin_of(x_ms)] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Percentile `p ∈ [0, 100]`: the log-midpoint of the bin holding the
    /// rank-p sample (0.0 when empty). Resolution is one bin width,
    /// ≈ ±1.1% relative.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0).clamp(0.0, 1.0) * (self.total - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            seen += c;
            if seen > rank {
                let mid = (i as f64 + 0.5) / HIST_BINS as f64;
                return HIST_MIN_MS * ((HIST_MAX_MS / HIST_MIN_MS).ln() * mid).exp();
            }
        }
        HIST_MAX_MS
    }
}

/// Streaming accumulators for the autoregressive (LLM) workload class:
/// per-round delay components plus time-to-first/last-round, lazily
/// created the first time a decode hook fires — one-shot runs never
/// allocate it, so their reports (and JSON bytes) are untouched.
#[derive(Clone, Debug, Default)]
struct LlmStats {
    /// Tasks whose prefill chain completed and that entered decode.
    decode_tasks: u64,
    rounds_completed: u64,
    /// Rounds lost to a per-round deadline miss (the missed round plus
    /// every round the task never ran).
    rounds_dropped: u64,
    /// Welford over per-round ready→done delays [ms].
    round_delay_ms: Welford,
    /// Welford over arrival→first-round-done [ms] (completed tasks).
    ttfr_ms: Welford,
    /// Welford over arrival→last-round-done [ms] (completed tasks).
    ttlr_ms: Welford,
}

/// Streaming accumulators for the resilience layer: recovery retries,
/// reroutes, rework, and time-to-recover — lazily created the first time
/// a recovery hook fires, so fault-free (and recovery-off) runs never
/// allocate it and their JSON bytes are untouched.
#[derive(Clone, Debug, Default)]
struct ResilienceStats {
    /// Tasks that survived at least one fault and went on to complete.
    recovered_tasks: u64,
    /// Re-offload attempts (each fault-triggered re-decide).
    retries: u64,
    /// In-flight ISL transfers re-routed around a dead link.
    reroutes: u64,
    /// Faulted tasks abandoned after exhausting retries / deadline /
    /// link stalls.
    give_ups: u64,
    /// Segment work re-executed due to recovery [MFLOP].
    rework_mflops: f64,
    /// Welford over fault→resume latencies [ms].
    ttr_ms: Welford,
}

/// Collects everything a simulation run produces, streaming each outcome
/// into constant-size accumulators at record time.
#[derive(Clone, Debug)]
pub struct MetricsCollector {
    total_tasks: u64,
    completed_tasks: u64,
    /// Welford accumulators over COMPLETED tasks [ms].
    delay_ms: Welford,
    comp_ms: Welford,
    tran_ms: Welford,
    uplink_ms: Welford,
    delay_hist: DelayHistogram,
    last_finish_s: f64,
    /// Full outcome buffer, kept only when `retaining(true)` — the flag
    /// consumers (plots/traces) opt into; `None` keeps memory flat in
    /// task count.
    retained: Option<Vec<TaskOutcome>>,
    /// Autoregressive-round accumulators — `Some` only once a decode hook
    /// has fired, so one-shot runs stay byte-identical.
    llm: Option<Box<LlmStats>>,
    /// Recovery accumulators — `Some` only once a recovery hook has
    /// fired, so drop-policy runs stay byte-identical.
    resilience: Option<Box<ResilienceStats>>,
    pub per_sat: Vec<SatelliteTotals>,
    pub slots_run: usize,
}

impl MetricsCollector {
    pub fn new(n_sats: usize) -> MetricsCollector {
        MetricsCollector {
            total_tasks: 0,
            completed_tasks: 0,
            delay_ms: Welford::default(),
            comp_ms: Welford::default(),
            tran_ms: Welford::default(),
            uplink_ms: Welford::default(),
            delay_hist: DelayHistogram::new(),
            last_finish_s: 0.0,
            retained: None,
            llm: None,
            resilience: None,
            per_sat: vec![SatelliteTotals::default(); n_sats],
            slots_run: 0,
        }
    }

    fn llm_mut(&mut self) -> &mut LlmStats {
        self.llm.get_or_insert_with(Default::default)
    }

    /// A task's prefill chain completed and its decode phase began.
    pub fn decode_started(&mut self) {
        self.llm_mut().decode_tasks += 1;
    }

    /// One decode round completed within its deadline; `delay_s` is its
    /// ready→done delay (FIFO wait + service).
    pub fn round_done(&mut self, delay_s: f64) {
        let s = self.llm_mut();
        s.rounds_completed += 1;
        s.round_delay_ms.push(delay_s * 1e3);
    }

    /// A round missed its deadline: `n` rounds are lost (the missed one
    /// plus every round the task never ran).
    pub fn rounds_dropped(&mut self, n: u64) {
        self.llm_mut().rounds_dropped += n;
    }

    /// A decode task ran all its rounds: record time-to-first-round and
    /// time-to-last-round (both measured from arrival) [s].
    pub fn decode_finished(&mut self, ttfr_s: f64, ttlr_s: f64) {
        let s = self.llm_mut();
        s.ttfr_ms.push(ttfr_s * 1e3);
        s.ttlr_ms.push(ttlr_s * 1e3);
    }

    fn resilience_mut(&mut self) -> &mut ResilienceStats {
        self.resilience.get_or_insert_with(Default::default)
    }

    /// A faulted task was re-offloaded: `rework_mflops` of segment work
    /// re-executes and the task resumes `ttr_s` seconds after the fault.
    pub fn recovery_retry(&mut self, rework_mflops: f64, ttr_s: f64) {
        let s = self.resilience_mut();
        s.retries += 1;
        s.rework_mflops += rework_mflops;
        s.ttr_ms.push(ttr_s * 1e3);
    }

    /// An in-flight ISL transfer was re-routed around a dead link.
    pub fn reroute(&mut self) {
        self.resilience_mut().reroutes += 1;
    }

    /// A task that survived at least one fault completed.
    pub fn task_recovered(&mut self) {
        self.resilience_mut().recovered_tasks += 1;
    }

    /// A faulted/stalled task was abandoned (retry budget, deadline, or
    /// link stall limit exhausted).
    pub fn recovery_giveup(&mut self) {
        self.resilience_mut().give_ups += 1;
    }

    /// Builder: keep the full `TaskOutcome` buffer (memory grows with task
    /// count — only for consumers that need per-task data).
    pub fn retaining(mut self, retain: bool) -> MetricsCollector {
        self.retained = if retain { Some(Vec::new()) } else { None };
        self
    }

    pub fn record(&mut self, o: TaskOutcome) {
        self.total_tasks += 1;
        if o.finish_time_s > self.last_finish_s {
            self.last_finish_s = o.finish_time_s;
        }
        if o.completed() {
            self.completed_tasks += 1;
            let d_ms = o.total_delay_s() * 1e3;
            self.delay_ms.push(d_ms);
            self.delay_hist.record(d_ms);
            self.comp_ms.push(o.comp_delay_s * 1e3);
            self.tran_ms.push(o.tran_delay_s * 1e3);
            self.uplink_ms.push(o.uplink_delay_s * 1e3);
        }
        if let Some(buf) = &mut self.retained {
            buf.push(o);
        }
    }

    /// Outcomes recorded so far — `Some` only under `retaining(true)`.
    pub fn outcomes(&self) -> Option<&[TaskOutcome]> {
        self.retained.as_deref()
    }

    /// Tasks recorded so far (streaming counter).
    pub fn total_recorded(&self) -> u64 {
        self.total_tasks
    }

    pub fn sat(&mut self, id: SatId) -> &mut SatelliteTotals {
        &mut self.per_sat[id]
    }

    /// Finalize a slotted run of `slots_run` slots (1 slot = 1 s).
    pub fn finish(self, slots_run: usize) -> Report {
        Report {
            slots_run,
            horizon_s: slots_run as f64,
            ..Report::from_collector(self)
        }
    }

    /// Finalize a continuous-time run over a `horizon_s`-second arrival
    /// window (the event engine drains in-flight work past the horizon,
    /// but rates are normalized to the arrival window).
    pub fn finish_continuous(self, horizon_s: f64) -> Report {
        Report {
            slots_run: horizon_s.ceil() as usize,
            horizon_s,
            ..Report::from_collector(self)
        }
    }
}

/// Round-level block of the report for autoregressive (LLM) runs —
/// present only when the run generated decode rounds, so one-shot
/// reports (and their JSON bytes) are unchanged.
#[derive(Clone, Debug, PartialEq)]
pub struct LlmReport {
    /// Tasks whose prefill chain completed and that entered decode.
    pub decode_tasks: u64,
    pub rounds_completed: u64,
    pub rounds_dropped: u64,
    /// Mean per-round ready→done delay [ms].
    pub avg_round_delay_ms: f64,
    /// Mean arrival→first-round-done [ms] over fully-decoded tasks.
    pub time_to_first_round_ms: f64,
    /// Mean arrival→last-round-done [ms] over fully-decoded tasks.
    pub time_to_last_round_ms: f64,
}

impl LlmReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("decode_tasks", Json::Num(self.decode_tasks as f64)),
            ("rounds_completed", Json::Num(self.rounds_completed as f64)),
            ("rounds_dropped", Json::Num(self.rounds_dropped as f64)),
            ("avg_round_delay_ms", Json::Num(self.avg_round_delay_ms)),
            (
                "time_to_first_round_ms",
                Json::Num(self.time_to_first_round_ms),
            ),
            (
                "time_to_last_round_ms",
                Json::Num(self.time_to_last_round_ms),
            ),
        ])
    }
}

/// Recovery block of the report for fault-injected runs with the
/// resilience layer active — present only when a recovery/reroute hook
/// fired, so drop-policy reports (and their JSON bytes) are unchanged.
#[derive(Clone, Debug, PartialEq)]
pub struct ResilienceReport {
    /// Tasks that survived at least one fault and completed.
    pub recovered_tasks: u64,
    /// Re-offload attempts across all faulted tasks.
    pub retries: u64,
    /// ISL transfers re-routed around dead links.
    pub reroutes: u64,
    /// Faulted tasks abandoned after exhausting the recovery budget.
    pub give_ups: u64,
    /// Segment work re-executed due to recovery [MFLOP].
    pub rework_mflops: f64,
    /// Mean fault→resume latency [ms].
    pub mean_time_to_recover_ms: f64,
}

impl ResilienceReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("recovered_tasks", Json::Num(self.recovered_tasks as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("reroutes", Json::Num(self.reroutes as f64)),
            ("give_ups", Json::Num(self.give_ups as f64)),
            ("rework_mflops", Json::Num(self.rework_mflops)),
            (
                "mean_time_to_recover_ms",
                Json::Num(self.mean_time_to_recover_ms),
            ),
        ])
    }
}

/// Final experiment report — the quantities plotted in Figs. 2 & 3.
#[derive(Clone, Debug)]
pub struct Report {
    pub total_tasks: u64,
    pub completed_tasks: u64,
    pub dropped_tasks: u64,
    /// Mean per-task total delay over COMPLETED tasks [ms] (Fig 2b/3b).
    pub avg_delay_ms: f64,
    /// Mean computation / transmission components [ms].
    pub avg_comp_ms: f64,
    pub avg_tran_ms: f64,
    pub avg_uplink_ms: f64,
    /// Variance of per-satellite assigned workload [MFLOP²] (Fig 2c/3c).
    pub workload_variance: f64,
    /// Mean per-satellite assigned workload [MFLOP].
    pub workload_mean: f64,
    /// p50 / p95 per-task delay [ms].
    pub delay_p50_ms: f64,
    pub delay_p95_ms: f64,
    pub slots_run: usize,
    /// Arrival-window length [s] (= `slots_run` for the slotted engine;
    /// the exact continuous horizon for the event engine).
    pub horizon_s: f64,
    /// Latest outcome timestamp [s] (max `TaskOutcome::finish_time_s`);
    /// with the event engine this shows how far past the horizon the
    /// in-flight drain ran.
    pub last_finish_s: f64,
    /// Full per-task outcomes — `Some` only when the run was collected
    /// with `SimConfig::retain_outcomes` (plots/traces); `None` on the
    /// default streaming path.
    pub outcomes: Option<Vec<TaskOutcome>>,
    /// Runtime-counter block from the observability layer — `Some` only
    /// when telemetry was enabled (`--telemetry` / `--trace`); `None`
    /// keeps the default JSON output byte-identical to pre-telemetry
    /// builds. See `crate::obs`.
    pub telemetry: Option<Json>,
    /// Round-level stats — `Some` only when the run executed decode
    /// rounds (`task-kind=autoregressive`); `None` keeps one-shot JSON
    /// byte-identical to pre-LLM builds.
    pub llm: Option<LlmReport>,
    /// Recovery stats — `Some` only when the resilience layer recovered,
    /// rerouted, or gave up on at least one task; `None` keeps
    /// drop-policy JSON byte-identical to pre-resilience builds.
    pub resilience: Option<ResilienceReport>,
}

impl Report {
    fn from_collector(c: MetricsCollector) -> Report {
        let assigned: Vec<f64> = c.per_sat.iter().map(|s| s.assigned_mflops).collect();
        Report {
            total_tasks: c.total_tasks,
            completed_tasks: c.completed_tasks,
            dropped_tasks: c.total_tasks - c.completed_tasks,
            avg_delay_ms: c.delay_ms.mean(),
            avg_comp_ms: c.comp_ms.mean(),
            avg_tran_ms: c.tran_ms.mean(),
            avg_uplink_ms: c.uplink_ms.mean(),
            workload_variance: stats::variance(&assigned),
            workload_mean: stats::mean(&assigned),
            delay_p50_ms: c.delay_hist.percentile(50.0),
            delay_p95_ms: c.delay_hist.percentile(95.0),
            slots_run: 0,
            horizon_s: 0.0,
            last_finish_s: c.last_finish_s,
            outcomes: c.retained,
            telemetry: None,
            llm: c.llm.map(|s| LlmReport {
                decode_tasks: s.decode_tasks,
                rounds_completed: s.rounds_completed,
                rounds_dropped: s.rounds_dropped,
                avg_round_delay_ms: s.round_delay_ms.mean(),
                time_to_first_round_ms: s.ttfr_ms.mean(),
                time_to_last_round_ms: s.ttlr_ms.mean(),
            }),
            resilience: c.resilience.map(|s| ResilienceReport {
                recovered_tasks: s.recovered_tasks,
                retries: s.retries,
                reroutes: s.reroutes,
                give_ups: s.give_ups,
                rework_mflops: s.rework_mflops,
                mean_time_to_recover_ms: s.ttr_ms.mean(),
            }),
        }
    }

    /// Seconds the run drained in-flight work past the arrival window.
    pub fn drain_secs(&self) -> f64 {
        (self.last_finish_s - self.horizon_s).max(0.0)
    }

    /// Completed tasks per second of arrival window (0 if no horizon).
    pub fn throughput_per_s(&self) -> f64 {
        if self.horizon_s > 0.0 {
            self.completed_tasks as f64 / self.horizon_s
        } else {
            0.0
        }
    }

    /// Task completion rate (Fig 2a/3a) = 1 − r_D (Eq. 9).
    pub fn completion_rate(&self) -> f64 {
        if self.total_tasks == 0 {
            return 1.0;
        }
        self.completed_tasks as f64 / self.total_tasks as f64
    }

    /// Drop rate r_D (Eq. 9).
    pub fn drop_rate(&self) -> f64 {
        1.0 - self.completion_rate()
    }

    /// The scalar objective of Eq. 10 with weights (α, β); delay in seconds.
    pub fn objective(&self, alpha: f64, beta: f64) -> f64 {
        alpha * self.drop_rate() + beta * self.avg_delay_ms / 1e3
    }

    /// Coefficient of variation of satellite workload (scale-free balance).
    pub fn workload_cv(&self) -> f64 {
        if self.workload_mean == 0.0 {
            0.0
        } else {
            self.workload_variance.sqrt() / self.workload_mean
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("total_tasks", Json::Num(self.total_tasks as f64)),
            ("completed_tasks", Json::Num(self.completed_tasks as f64)),
            ("completion_rate", Json::Num(self.completion_rate())),
            ("avg_delay_ms", Json::Num(self.avg_delay_ms)),
            ("avg_comp_ms", Json::Num(self.avg_comp_ms)),
            ("avg_tran_ms", Json::Num(self.avg_tran_ms)),
            ("avg_uplink_ms", Json::Num(self.avg_uplink_ms)),
            ("delay_p50_ms", Json::Num(self.delay_p50_ms)),
            ("delay_p95_ms", Json::Num(self.delay_p95_ms)),
            ("workload_variance", Json::Num(self.workload_variance)),
            ("workload_mean", Json::Num(self.workload_mean)),
            ("workload_cv", Json::Num(self.workload_cv())),
            ("slots_run", Json::Num(self.slots_run as f64)),
            ("horizon_s", Json::Num(self.horizon_s)),
            ("throughput_per_s", Json::Num(self.throughput_per_s())),
            ("drain_secs", Json::Num(self.drain_secs())),
        ];
        if let Some(l) = &self.llm {
            pairs.push(("llm", l.to_json()));
        }
        if let Some(r) = &self.resilience {
            pairs.push(("resilience", r.to_json()));
        }
        if let Some(t) = &self.telemetry {
            pairs.push(("telemetry", t.clone()));
        }
        Json::obj(pairs)
    }

    /// One figure-style table row.
    pub fn row(&self, label: &str) -> String {
        format!(
            "{label:<10} tasks={:<6} complete={:>6.2}% delay={:>9.1}ms (comp {:>8.1} + tran {:>7.1}) var={:>12.3e} cv={:.3}",
            self.total_tasks,
            100.0 * self.completion_rate(),
            self.avg_delay_ms,
            self.avg_comp_ms,
            self.avg_tran_ms,
            self.workload_variance,
            self.workload_cv(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(id: u64, dp: usize, l: usize, comp: f64, tran: f64) -> TaskOutcome {
        TaskOutcome {
            task_id: id,
            origin: 0,
            drop_point: dp,
            l,
            comp_delay_s: comp,
            tran_delay_s: tran,
            uplink_delay_s: 0.05,
            finish_time_s: comp + tran,
        }
    }

    #[test]
    fn completion_and_drop_rate_eq9() {
        let mut c = MetricsCollector::new(4);
        c.record(outcome(0, 4, 3, 1.0, 0.2)); // completed (dp = L+1)
        c.record(outcome(1, 2, 3, 0.5, 0.1)); // dropped at segment 2
        c.record(outcome(2, 4, 3, 2.0, 0.4)); // completed
        let r = c.finish(10);
        assert_eq!(r.total_tasks, 3);
        assert_eq!(r.completed_tasks, 2);
        assert!((r.completion_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert!((r.drop_rate() - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(r.slots_run, 10);
    }

    #[test]
    fn delay_only_over_completed() {
        let mut c = MetricsCollector::new(1);
        c.record(outcome(0, 3, 2, 1.0, 0.0)); // completed: 1000 ms
        c.record(outcome(1, 1, 2, 99.0, 0.0)); // dropped: excluded
        let r = c.finish(1);
        assert!((r.avg_delay_ms - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn workload_variance_matches_stats() {
        let mut c = MetricsCollector::new(3);
        c.sat(0).assigned_mflops = 100.0;
        c.sat(1).assigned_mflops = 200.0;
        c.sat(2).assigned_mflops = 300.0;
        let r = c.finish(1);
        assert!((r.workload_mean - 200.0).abs() < 1e-12);
        assert!((r.workload_variance - stats::variance(&[100.0, 200.0, 300.0])).abs() < 1e-9);
    }

    #[test]
    fn empty_run_is_sane() {
        let r = MetricsCollector::new(2).finish(0);
        assert_eq!(r.completion_rate(), 1.0);
        assert_eq!(r.avg_delay_ms, 0.0);
    }

    #[test]
    fn objective_eq10_weights() {
        let mut c = MetricsCollector::new(1);
        c.record(outcome(0, 1, 2, 0.0, 0.0)); // dropped
        c.record(outcome(1, 3, 2, 2.0, 0.0)); // completed, 2 s
        let r = c.finish(1);
        // r_D = 0.5, mean delay = 2 s
        assert!((r.objective(1.0, 1.0) - 2.5).abs() < 1e-12);
        assert!((r.objective(2.0, 0.5) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn continuous_finish_keeps_exact_horizon() {
        let mut c = MetricsCollector::new(2);
        c.record(outcome(0, 3, 2, 1.0, 0.2));
        c.record(outcome(1, 3, 2, 2.0, 0.1));
        let r = c.finish_continuous(12.5);
        assert!((r.horizon_s - 12.5).abs() < 1e-12);
        assert_eq!(r.slots_run, 13);
        assert!((r.throughput_per_s() - 2.0 / 12.5).abs() < 1e-12);
        // outcome() stamps finish_time_s = comp + tran: latest is 2.1 s,
        // inside the horizon, so nothing drained past the window
        assert!((r.last_finish_s - 2.1).abs() < 1e-12);
        assert_eq!(r.drain_secs(), 0.0);
    }

    #[test]
    fn drain_secs_measures_overrun_past_horizon() {
        let mut c = MetricsCollector::new(1);
        c.record(outcome(0, 3, 2, 4.0, 1.0)); // finishes at t = 5.0
        let r = c.finish_continuous(3.0);
        assert!((r.last_finish_s - 5.0).abs() < 1e-12);
        assert!((r.drain_secs() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn streaming_default_retains_nothing() {
        let mut c = MetricsCollector::new(1);
        for i in 0..1000 {
            c.record(outcome(i, 3, 2, 1.0 + i as f64 * 1e-3, 0.1));
        }
        assert!(c.outcomes().is_none());
        assert_eq!(c.total_recorded(), 1000);
        let r = c.finish(10);
        assert_eq!(r.total_tasks, 1000);
        assert!(r.outcomes.is_none());
    }

    #[test]
    fn retaining_keeps_full_outcomes() {
        let mut c = MetricsCollector::new(1).retaining(true);
        c.record(outcome(0, 3, 2, 1.0, 0.2));
        c.record(outcome(1, 1, 2, 9.0, 0.0));
        assert_eq!(c.outcomes().unwrap().len(), 2);
        let r = c.finish(1);
        let outs = r.outcomes.as_ref().unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[1].drop_point, 1);
    }

    #[test]
    fn streaming_means_match_batch() {
        let mut c = MetricsCollector::new(1);
        let mut delays = Vec::new();
        for i in 0..5000u64 {
            let comp = 0.5 + (i as f64).sin().abs();
            let tran = 0.1 * ((i % 7) as f64);
            delays.push((comp + tran) * 1e3);
            c.record(outcome(i, 3, 2, comp, tran));
        }
        let r = c.finish(1);
        let batch = stats::mean(&delays);
        assert!(
            (r.avg_delay_ms - batch).abs() < 1e-9 * batch,
            "streaming {} vs batch {batch}",
            r.avg_delay_ms
        );
    }

    #[test]
    fn llm_block_absent_unless_rounds_ran() {
        let mut c = MetricsCollector::new(1);
        c.record(outcome(0, 3, 2, 1.0, 0.2));
        let r = c.finish(1);
        assert!(r.llm.is_none());
        // JSON for a one-shot run must not mention the llm block at all
        assert!(!r.to_json().to_string().contains("\"llm\""));
    }

    #[test]
    fn resilience_block_absent_unless_recovery_ran() {
        let mut c = MetricsCollector::new(1);
        c.record(outcome(0, 3, 2, 1.0, 0.2));
        let r = c.finish(1);
        assert!(r.resilience.is_none());
        // JSON for a drop-policy run must not mention the block at all
        assert!(!r.to_json().to_string().contains("\"resilience\""));
    }

    #[test]
    fn resilience_accumulators_roll_up() {
        let mut c = MetricsCollector::new(1);
        c.recovery_retry(120.0, 0.5);
        c.recovery_retry(80.0, 1.5);
        c.reroute();
        c.task_recovered();
        c.recovery_giveup();
        let r = c.finish(1);
        let s = r.resilience.as_ref().unwrap();
        assert_eq!(s.retries, 2);
        assert_eq!(s.reroutes, 1);
        assert_eq!(s.recovered_tasks, 1);
        assert_eq!(s.give_ups, 1);
        assert!((s.rework_mflops - 200.0).abs() < 1e-9);
        assert!((s.mean_time_to_recover_ms - 1000.0).abs() < 1e-9);
        let js = r.to_json().to_string();
        assert!(js.contains("\"resilience\""));
        assert!(js.contains("\"rework_mflops\""));
    }

    #[test]
    fn llm_accumulators_roll_up() {
        let mut c = MetricsCollector::new(1);
        c.decode_started();
        c.round_done(0.1);
        c.round_done(0.3);
        c.decode_finished(0.5, 1.5);
        c.decode_started();
        c.round_done(0.2);
        c.rounds_dropped(3);
        let r = c.finish(1);
        let l = r.llm.as_ref().unwrap();
        assert_eq!(l.decode_tasks, 2);
        assert_eq!(l.rounds_completed, 3);
        assert_eq!(l.rounds_dropped, 3);
        assert!((l.avg_round_delay_ms - 200.0).abs() < 1e-9);
        assert!((l.time_to_first_round_ms - 500.0).abs() < 1e-9);
        assert!((l.time_to_last_round_ms - 1500.0).abs() < 1e-9);
        let js = r.to_json().to_string();
        assert!(js.contains("\"llm\""));
        assert!(js.contains("\"rounds_dropped\""));
    }

    #[test]
    fn histogram_percentiles_approximate_exact() {
        let mut h = DelayHistogram::new();
        let mut xs = Vec::new();
        // log-spread sample over 4 decades
        for i in 0..10_000 {
            let x = 10f64.powf(0.5 + 3.5 * (i as f64 / 10_000.0));
            h.record(x);
            xs.push(x);
        }
        for p in [10.0, 50.0, 90.0, 95.0, 99.0] {
            let exact = stats::percentile(&xs, p);
            let est = h.percentile(p);
            assert!(
                (est - exact).abs() <= 0.03 * exact,
                "p{p}: est {est} vs exact {exact}"
            );
        }
        assert_eq!(h.count(), 10_000);
    }

    #[test]
    fn histogram_edges_are_safe() {
        let mut h = DelayHistogram::new();
        h.record(0.0);
        h.record(-1.0);
        h.record(f64::NAN);
        h.record(1e12);
        assert_eq!(h.count(), 4);
        assert!(h.percentile(0.0) > 0.0);
        assert!(h.percentile(100.0) <= HIST_MAX_MS);
        assert_eq!(DelayHistogram::new().percentile(50.0), 0.0);
    }

    #[test]
    fn memory_flat_under_many_records() {
        // streaming path: a million records must not grow any buffer —
        // the collector's only growable store is the (disabled) retained
        // buffer; everything else is fixed-size accumulators.
        let mut c = MetricsCollector::new(4);
        for i in 0..1_000_000u64 {
            let dp = if i % 10 == 0 { 1 } else { 3 };
            c.record(outcome(i, dp, 2, 0.8, 0.05));
        }
        assert!(c.outcomes().is_none());
        assert_eq!(c.total_recorded(), 1_000_000);
        let r = c.finish(100);
        assert_eq!(r.total_tasks, 1_000_000);
        assert_eq!(r.completed_tasks, 900_000);
        assert!((r.avg_delay_ms - 850.0).abs() < 1e-6);
        // p50 within histogram resolution of the single delay value
        assert!((r.delay_p50_ms - 850.0).abs() < 0.02 * 850.0);
    }

    #[test]
    fn json_roundtrips() {
        let mut c = MetricsCollector::new(1);
        c.record(outcome(0, 3, 2, 1.0, 0.5));
        let r = c.finish(5);
        let j = r.to_json().to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(
            parsed.get("completion_rate").unwrap().as_f64(),
            Some(1.0)
        );
    }
}
