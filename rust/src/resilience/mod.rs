//! Resilience layer: recovery policies, ISL link outages, and scripted
//! fault traces (ISSUE 10).
//!
//! The paper's system model (Eq. 9) treats every satellite fault as fatal:
//! the legacy `FaultInjector` drops every affected task outright. This
//! module adds the machinery both engines need to *survive* faults instead:
//!
//! * [`RecoveryPolicy`] — the `--recovery drop|reoffload[:<max_retries>]`
//!   knob. `Drop` is the default and keeps whole runs bit-for-bit
//!   identical with legacy behaviour; `Reoffload` re-runs the offloading
//!   decision for a task's *remaining* segment chain from the last
//!   completed segment, charging re-uplink of intermediate activations
//!   over ISL hops, bounded by a per-task retry budget and a
//!   deadline-aware give-up.
//! * [`LinkFaultInjector`] — Bernoulli per-ISL-link outages (plus a
//!   Walker-star seam-outage mode), mirroring the per-satellite
//!   `sim::dynamics::FaultInjector` but over the constellation edge set.
//! * [`FaultTrace`] — scripted `(t_start, t_end, sat|link)` outage
//!   windows (`--fault-trace <file>`) feeding the same injection points,
//!   for reproducible chaos runs.
//! * [`OutageMap`] — an outage-masked all-pairs hop table rebuilt by BFS
//!   whenever the set of dead links changes; the deficit kernels' tran
//!   term and the event engine's `IslTransfer` routing consume it so
//!   decisions steer around dead links.
//!
//! Everything here is off-is-free: with all fault knobs at their
//! defaults no injector is constructed, no `Report.resilience` block is
//! allocated, and output stays byte-identical (`tests/prop_resilience.rs`).

use crate::topology::{Constellation, SatId};
use crate::util::rng::Pcg64;

/// Default bounded retry budget for `--recovery reoffload`.
pub const DEFAULT_MAX_RETRIES: u32 = 2;

/// Hop count reported by [`OutageMap::hops_or_penalty`] for unreachable
/// pairs — large enough that any deficit term containing it loses every
/// GA comparison, small enough not to overflow `f64` arithmetic.
pub const UNREACHABLE_HOPS: u16 = u16::MAX;

/// What to do with the surviving segment chain when a satellite hosting
/// it faults mid-task.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecoveryPolicy {
    /// Legacy behaviour: every task touching a failed satellite is
    /// dropped. Whole-run bit-for-bit identical with the pre-resilience
    /// engines.
    Drop,
    /// Re-run `decide_into` for the remaining segments from the last
    /// completed one, up to `max_retries` times per task.
    Reoffload { max_retries: u32 },
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy::Drop
    }
}

impl RecoveryPolicy {
    /// Parse a `--recovery` selector: `drop` | `reoffload[:<max_retries>]`.
    pub fn parse(s: &str) -> Result<Self, String> {
        let low = s.trim().to_ascii_lowercase();
        let (head, arg) = match low.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (low.as_str(), None),
        };
        match head {
            "drop" => match arg {
                None => Ok(RecoveryPolicy::Drop),
                Some(a) => Err(format!(
                    "recovery 'drop' takes no argument (got ':{a}')"
                )),
            },
            "reoffload" | "retry" => {
                let max_retries = match arg {
                    None => DEFAULT_MAX_RETRIES,
                    Some(a) => a.parse::<u32>().map_err(|_| {
                        format!("recovery max_retries '{a}' is not an integer")
                    })?,
                };
                if max_retries == 0 {
                    return Err(
                        "recovery 'reoffload' needs >= 1 retry (use 'drop' to disable)"
                            .to_string(),
                    );
                }
                Ok(RecoveryPolicy::Reoffload { max_retries })
            }
            other => Err(format!(
                "unknown recovery policy '{other}' (drop|reoffload[:<max_retries>])"
            )),
        }
    }

    /// Stable selector label, the inverse of [`RecoveryPolicy::parse`].
    pub fn label(&self) -> String {
        match self {
            RecoveryPolicy::Drop => "drop".to_string(),
            RecoveryPolicy::Reoffload { max_retries } => {
                format!("reoffload:{max_retries}")
            }
        }
    }

    /// True for the legacy drop-everything policy.
    pub fn is_drop(&self) -> bool {
        matches!(self, RecoveryPolicy::Drop)
    }

    /// Per-task retry budget (0 under `Drop`).
    pub fn max_retries(&self) -> u32 {
        match self {
            RecoveryPolicy::Drop => 0,
            RecoveryPolicy::Reoffload { max_retries } => *max_retries,
        }
    }
}

/// One scripted outage target: a whole satellite or a single ISL link.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultTarget {
    Sat(SatId),
    /// Normalized so that `.0 < .1`.
    Link(SatId, SatId),
}

/// One scripted outage window: the target is down for `t` in
/// `[t_start, t_end)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultWindow {
    pub t_start: f64,
    pub t_end: f64,
    pub target: FaultTarget,
}

/// A scripted fault trace (`--fault-trace <file>`): one window per line,
/// `<t_start> <t_end> sat:<id>` or `<t_start> <t_end> link:<a>-<b>`.
/// Blank lines and `#` comments are ignored; commas are accepted as
/// field separators.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct FaultTrace {
    windows: Vec<FaultWindow>,
}

impl FaultTrace {
    /// Parse the trace text format. Errors name the offending line.
    pub fn parse_str(text: &str) -> Result<Self, String> {
        let mut windows = Vec::new();
        for (i, raw) in text.lines().enumerate() {
            let line = match raw.split_once('#') {
                Some((head, _)) => head,
                None => raw,
            };
            let norm = line.replace(',', " ");
            let fields: Vec<&str> = norm.split_whitespace().collect();
            if fields.is_empty() {
                continue;
            }
            let n = i + 1;
            if fields.len() != 3 {
                return Err(format!(
                    "fault-trace line {n}: expected '<t_start> <t_end> sat:<id>|link:<a>-<b>', got {} fields",
                    fields.len()
                ));
            }
            let t_start: f64 = fields[0].parse().map_err(|_| {
                format!("fault-trace line {n}: bad t_start '{}'", fields[0])
            })?;
            let t_end: f64 = fields[1].parse().map_err(|_| {
                format!("fault-trace line {n}: bad t_end '{}'", fields[1])
            })?;
            if !t_start.is_finite() || !t_end.is_finite() || t_start < 0.0 {
                return Err(format!(
                    "fault-trace line {n}: window times must be finite and t_start >= 0"
                ));
            }
            if t_end <= t_start {
                return Err(format!(
                    "fault-trace line {n}: t_end ({t_end}) must be > t_start ({t_start})"
                ));
            }
            let spec = fields[2].to_ascii_lowercase();
            let target = match spec.split_once(':') {
                Some(("sat", id)) => {
                    let id: SatId = id.parse().map_err(|_| {
                        format!("fault-trace line {n}: bad sat id '{id}'")
                    })?;
                    FaultTarget::Sat(id)
                }
                Some(("link", pair)) => {
                    let (a, b) = pair.split_once('-').ok_or_else(|| {
                        format!(
                            "fault-trace line {n}: link spec '{pair}' must be '<a>-<b>'"
                        )
                    })?;
                    let a: SatId = a.parse().map_err(|_| {
                        format!("fault-trace line {n}: bad link endpoint '{a}'")
                    })?;
                    let b: SatId = b.parse().map_err(|_| {
                        format!("fault-trace line {n}: bad link endpoint '{b}'")
                    })?;
                    if a == b {
                        return Err(format!(
                            "fault-trace line {n}: link endpoints must differ (got {a}-{b})"
                        ));
                    }
                    FaultTarget::Link(a.min(b), a.max(b))
                }
                _ => {
                    return Err(format!(
                        "fault-trace line {n}: target '{spec}' must be 'sat:<id>' or 'link:<a>-<b>'"
                    ));
                }
            };
            windows.push(FaultWindow { t_start, t_end, target });
        }
        Ok(FaultTrace { windows })
    }

    /// Load and parse a trace file; errors name the path.
    pub fn from_file(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("fault-trace '{path}': {e}"))?;
        Self::parse_str(&text)
            .map_err(|e| format!("fault-trace '{path}': {e}"))
    }

    pub fn windows(&self) -> &[FaultWindow] {
        &self.windows
    }

    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    /// Any scripted per-satellite windows?
    pub fn has_sat_windows(&self) -> bool {
        self.windows
            .iter()
            .any(|w| matches!(w.target, FaultTarget::Sat(_)))
    }

    /// Any scripted per-link windows?
    pub fn has_link_windows(&self) -> bool {
        self.windows
            .iter()
            .any(|w| matches!(w.target, FaultTarget::Link(_, _)))
    }

    /// Largest satellite id referenced anywhere in the trace (for config
    /// validation against the constellation size).
    pub fn max_sat_id(&self) -> Option<SatId> {
        self.windows
            .iter()
            .flat_map(|w| match w.target {
                FaultTarget::Sat(s) => vec![s],
                FaultTarget::Link(a, b) => vec![a, b],
            })
            .max()
    }

    /// Is satellite `s` scripted down at time `t`? Windows are
    /// half-open: `t` in `[t_start, t_end)`.
    pub fn sat_down_at(&self, s: SatId, t: f64) -> bool {
        self.windows.iter().any(|w| {
            matches!(w.target, FaultTarget::Sat(id) if id == s)
                && t >= w.t_start
                && t < w.t_end
        })
    }

    /// Is link `(a, b)` scripted down at time `t`?
    pub fn link_down_at(&self, a: SatId, b: SatId, t: f64) -> bool {
        let (lo, hi) = (a.min(b), a.max(b));
        self.windows.iter().any(|w| {
            matches!(w.target, FaultTarget::Link(x, y) if x == lo && y == hi)
                && t >= w.t_start
                && t < w.t_end
        })
    }

    /// End time of the last window (0 for an empty trace).
    pub fn last_end(&self) -> f64 {
        self.windows
            .iter()
            .map(|w| w.t_end)
            .fold(0.0, f64::max)
    }
}

/// Bernoulli per-ISL-link outage process over a constellation's edge
/// set, with an optional Walker-star seam-only eligibility mode and a
/// scripted-trace overlay. Mirrors `sim::dynamics::FaultInjector`'s
/// draw discipline: links are visited in sorted `(min, max)` edge order
/// every tick and the RNG stream is consumed uniformly regardless of
/// eligibility, so the realized schedule depends only on the seed.
#[derive(Clone, Debug)]
pub struct LinkFaultInjector {
    links: Vec<(SatId, SatId)>,
    eligible: Vec<bool>,
    down: Vec<bool>,
    forced: Vec<bool>,
    p_fail: f64,
    p_recover: f64,
    rng: Pcg64,
    version: u64,
    failures: u64,
}

impl LinkFaultInjector {
    /// One injector tick per simulated second, matching the satellite
    /// `FaultInjector`'s cadence.
    pub const TICK_SECS: f64 = 1.0;

    /// Build over `topo`'s edge set. With `seam_only`, Bernoulli draws
    /// only take effect on links touching the first or last orbital
    /// plane (the Walker-star seam region; on a torus this is the wrap
    /// band) — scripted trace windows are unaffected by eligibility.
    pub fn new(
        topo: &Constellation,
        p_fail: f64,
        p_recover: f64,
        seam_only: bool,
        seed: u64,
    ) -> Self {
        assert!((0.0..=1.0).contains(&p_fail), "p_fail must be in [0,1]");
        assert!(
            (0.0..=1.0).contains(&p_recover),
            "p_recover must be in [0,1]"
        );
        let links = topo.edges();
        let planes = topo.planes().max(1);
        let eligible: Vec<bool> = links
            .iter()
            .map(|&(a, b)| {
                if !seam_only {
                    return true;
                }
                let pa = topo.coords(a).0;
                let pb = topo.coords(b).0;
                pa == 0 || pa == planes - 1 || pb == 0 || pb == planes - 1
            })
            .collect();
        let n = links.len();
        LinkFaultInjector {
            links,
            eligible,
            down: vec![false; n],
            forced: vec![false; n],
            p_fail,
            p_recover,
            rng: Pcg64::new(seed, 0x11FA),
            version: 0,
            failures: 0,
        }
    }

    fn idx(&self, a: SatId, b: SatId) -> Option<usize> {
        let key = (a.min(b), a.max(b));
        self.links.binary_search(&key).ok()
    }

    /// Advance the Bernoulli process one tick. Returns true when the
    /// *effective* (Bernoulli ∪ forced) outage set changed.
    pub fn step(&mut self) -> bool {
        let mut changed = false;
        for i in 0..self.links.len() {
            let was = self.down[i] || self.forced[i];
            if self.down[i] {
                if self.rng.bool(self.p_recover) {
                    self.down[i] = false;
                }
            } else {
                // Draw unconditionally so the stream is uniform across
                // eligibility configurations.
                let fail = self.rng.bool(self.p_fail);
                if fail && self.eligible[i] {
                    self.down[i] = true;
                    self.failures += 1;
                }
            }
            if (self.down[i] || self.forced[i]) != was {
                changed = true;
            }
        }
        if changed {
            self.version += 1;
        }
        changed
    }

    /// Overlay scripted trace windows for time `t`. Returns true when
    /// the effective outage set changed.
    pub fn apply_trace(&mut self, trace: &FaultTrace, t: f64) -> bool {
        let mut changed = false;
        for i in 0..self.links.len() {
            let (a, b) = self.links[i];
            let was = self.down[i] || self.forced[i];
            self.forced[i] = trace.link_down_at(a, b, t);
            if (self.down[i] || self.forced[i]) != was {
                changed = true;
            }
        }
        if changed {
            self.version += 1;
        }
        changed
    }

    /// One full injector tick at time `t`: Bernoulli step, then the
    /// scripted overlay. Returns true when the effective set changed.
    pub fn step_at(&mut self, t: f64, trace: Option<&FaultTrace>) -> bool {
        let mut changed = self.step();
        if let Some(tr) = trace {
            changed |= self.apply_trace(tr, t);
        }
        changed
    }

    /// Is the `(a, b)` ISL currently out? Non-edges report false.
    pub fn link_down(&self, a: SatId, b: SatId) -> bool {
        match self.idx(a, b) {
            Some(i) => self.down[i] || self.forced[i],
            None => false,
        }
    }

    /// Any link currently out?
    pub fn any_down(&self) -> bool {
        (0..self.links.len()).any(|i| self.down[i] || self.forced[i])
    }

    /// Number of links currently out.
    pub fn down_count(&self) -> usize {
        (0..self.links.len())
            .filter(|&i| self.down[i] || self.forced[i])
            .count()
    }

    /// Monotone counter bumped on every effective-set change — consumed
    /// by [`OutageMap`] and the decision-index cache.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Total Bernoulli link failures injected so far.
    pub fn failures(&self) -> u64 {
        self.failures
    }

    /// The sorted edge set this injector tracks.
    pub fn links(&self) -> &[(SatId, SatId)] {
        &self.links
    }
}

/// Outage-masked all-pairs hop table: BFS over the constellation with
/// dead links removed, rebuilt whenever the outage set changes. Hop
/// queries fall back to [`UNREACHABLE_HOPS`] for severed pairs so the
/// deficit kernels steer the GA away from them.
#[derive(Clone, Debug, Default)]
pub struct OutageMap {
    n: usize,
    dist: Vec<u16>,
    version: u64,
}

impl OutageMap {
    pub fn new() -> Self {
        OutageMap::default()
    }

    /// Rebuild the table for `topo` with every link where
    /// `link_down(a, b)` holds removed. Bumps [`OutageMap::version`].
    pub fn rebuild_with(
        &mut self,
        topo: &Constellation,
        link_down: impl Fn(SatId, SatId) -> bool,
    ) {
        let n = topo.len();
        self.n = n;
        self.dist.resize(n * n, UNREACHABLE_HOPS);
        self.dist.fill(UNREACHABLE_HOPS);
        let mut queue = std::collections::VecDeque::new();
        for src in 0..n {
            let row = src * n;
            self.dist[row + src] = 0;
            queue.clear();
            queue.push_back(src);
            while let Some(u) = queue.pop_front() {
                let du = self.dist[row + u];
                for v in topo.neighbors(u) {
                    if link_down(u, v) {
                        continue;
                    }
                    if self.dist[row + v] == UNREACHABLE_HOPS {
                        self.dist[row + v] = du + 1;
                        queue.push_back(v);
                    }
                }
            }
        }
        self.version += 1;
    }

    /// Has [`OutageMap::rebuild_with`] run at least once?
    pub fn built(&self) -> bool {
        self.n > 0
    }

    /// Outage-masked hop count, `None` when `b` is unreachable from `a`.
    pub fn hops(&self, a: SatId, b: SatId) -> Option<usize> {
        let d = self.dist[a * self.n + b];
        if d == UNREACHABLE_HOPS {
            None
        } else {
            Some(d as usize)
        }
    }

    /// Outage-masked hop count with [`UNREACHABLE_HOPS`] standing in
    /// for severed pairs — the form the deficit tran term consumes.
    pub fn hops_or_penalty(&self, a: SatId, b: SatId) -> usize {
        self.dist[a * self.n + b] as usize
    }

    /// Is `b` reachable from `a` over alive links?
    pub fn reachable(&self, a: SatId, b: SatId) -> bool {
        self.dist[a * self.n + b] != UNREACHABLE_HOPS
    }

    /// Fill `out` with the pairwise hop rows for `ids` (row-major,
    /// `out[i * ids.len() + j] = hops(ids[i], ids[j])`, penalty for
    /// severed pairs) — the shape `DecisionSpaceIndex` expects, matching
    /// `Constellation::hops_lut`.
    pub fn hops_lut(&self, ids: &[SatId], out: &mut Vec<u16>) {
        out.clear();
        out.reserve(ids.len() * ids.len());
        for &a in ids {
            let row = &self.dist[a * self.n..(a + 1) * self.n];
            for &b in ids {
                out.push(row[b]);
            }
        }
    }

    /// Monotone rebuild counter (for decision-index cache invalidation).
    pub fn version(&self) -> u64 {
        self.version
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::TopologyKind;

    fn torus(n: usize) -> Constellation {
        TopologyKind::Torus { n }.build()
    }

    #[test]
    fn recovery_policy_parse_roundtrip() {
        assert_eq!(RecoveryPolicy::parse("drop").unwrap(), RecoveryPolicy::Drop);
        assert_eq!(
            RecoveryPolicy::parse("reoffload").unwrap(),
            RecoveryPolicy::Reoffload { max_retries: DEFAULT_MAX_RETRIES }
        );
        assert_eq!(
            RecoveryPolicy::parse("reoffload:5").unwrap(),
            RecoveryPolicy::Reoffload { max_retries: 5 }
        );
        assert_eq!(
            RecoveryPolicy::parse("Retry:3").unwrap(),
            RecoveryPolicy::Reoffload { max_retries: 3 }
        );
        for p in [
            RecoveryPolicy::Drop,
            RecoveryPolicy::Reoffload { max_retries: 1 },
            RecoveryPolicy::Reoffload { max_retries: 7 },
        ] {
            assert_eq!(RecoveryPolicy::parse(&p.label()).unwrap(), p);
        }
    }

    #[test]
    fn recovery_policy_rejects_malformed() {
        for bad in ["bogus", "drop:1", "reoffload:abc", "reoffload:0", "reoffload:-1", ""] {
            assert!(RecoveryPolicy::parse(bad).is_err(), "{bad:?} should err");
        }
    }

    #[test]
    fn fault_trace_parses_and_queries() {
        let text = "\
# scripted chaos
0.0 5.0 sat:3
2.5, 4.0, link:1-2
10 12 LINK:7-6
";
        let tr = FaultTrace::parse_str(text).unwrap();
        assert_eq!(tr.windows().len(), 3);
        assert!(tr.has_sat_windows() && tr.has_link_windows());
        assert_eq!(tr.max_sat_id(), Some(7));
        assert!(tr.sat_down_at(3, 0.0));
        assert!(tr.sat_down_at(3, 4.999));
        assert!(!tr.sat_down_at(3, 5.0)); // half-open
        assert!(!tr.sat_down_at(2, 1.0));
        assert!(tr.link_down_at(2, 1, 3.0)); // normalized both ways
        assert!(!tr.link_down_at(1, 2, 4.0));
        assert!(tr.link_down_at(6, 7, 11.0));
        assert_eq!(tr.last_end(), 12.0);
    }

    #[test]
    fn fault_trace_rejects_malformed_lines() {
        for bad in [
            "1.0 2.0",
            "x 2.0 sat:1",
            "1.0 y sat:1",
            "2.0 1.0 sat:1",
            "-1.0 2.0 sat:1",
            "1.0 2.0 sat:abc",
            "1.0 2.0 node:1",
            "1.0 2.0 link:1",
            "1.0 2.0 link:1-1",
            "1.0 2.0 link:a-b",
        ] {
            let err = FaultTrace::parse_str(bad).unwrap_err();
            assert!(err.contains("line 1"), "{bad:?} -> {err}");
        }
        assert!(FaultTrace::parse_str("# only comments\n\n").unwrap().is_empty());
    }

    #[test]
    fn link_injector_deterministic_and_inert_at_zero() {
        let topo = torus(4);
        let mut a = LinkFaultInjector::new(&topo, 0.3, 0.2, false, 99);
        let mut b = LinkFaultInjector::new(&topo, 0.3, 0.2, false, 99);
        for _ in 0..50 {
            a.step();
            b.step();
            for &(x, y) in a.links() {
                assert_eq!(a.link_down(x, y), b.link_down(x, y));
            }
        }
        assert!(a.failures() > 0);

        let mut z = LinkFaultInjector::new(&topo, 0.0, 0.5, false, 1);
        for _ in 0..50 {
            assert!(!z.step());
        }
        assert!(!z.any_down());
        assert_eq!(z.version(), 0);
    }

    #[test]
    fn seam_only_restricts_bernoulli_failures() {
        let topo = TopologyKind::parse("walker-star:4x4").unwrap().build();
        let planes = topo.planes();
        let mut inj = LinkFaultInjector::new(&topo, 1.0, 0.0, true, 7);
        inj.step();
        for &(a, b) in inj.links() {
            let seam = {
                let pa = topo.coords(a).0;
                let pb = topo.coords(b).0;
                pa == 0 || pa == planes - 1 || pb == 0 || pb == planes - 1
            };
            assert_eq!(inj.link_down(a, b), seam, "link {a}-{b}");
        }
    }

    #[test]
    fn outage_map_matches_topology_when_healthy() {
        let topo = torus(4);
        let mut map = OutageMap::new();
        map.rebuild_with(&topo, |_, _| false);
        for a in 0..topo.len() {
            for b in 0..topo.len() {
                assert_eq!(map.hops(a, b), Some(topo.hops(a, b)));
            }
        }
        assert_eq!(map.version(), 1);
    }

    #[test]
    fn outage_map_severed_sat_unreachable() {
        let topo = torus(4);
        let mut map = OutageMap::new();
        // Cut every link touching satellite 5.
        map.rebuild_with(&topo, |a, b| a == 5 || b == 5);
        assert!(!map.reachable(0, 5));
        assert_eq!(map.hops(0, 5), None);
        assert_eq!(map.hops_or_penalty(0, 5), UNREACHABLE_HOPS as usize);
        // Everything else still connected (torus is 4-regular).
        for b in 0..topo.len() {
            if b != 5 {
                assert!(map.reachable(0, b), "0 -> {b}");
            }
        }
    }

    #[test]
    fn outage_map_hops_lut_shape() {
        let topo = torus(3);
        let mut map = OutageMap::new();
        map.rebuild_with(&topo, |_, _| false);
        let ids = [0usize, 4, 8];
        let mut out = Vec::new();
        map.hops_lut(&ids, &mut out);
        assert_eq!(out.len(), 9);
        for (i, &a) in ids.iter().enumerate() {
            for (j, &b) in ids.iter().enumerate() {
                assert_eq!(out[i * 3 + j] as usize, topo.hops(a, b));
            }
        }
    }
}
