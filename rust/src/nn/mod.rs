//! Neural-network substrate: a small fully-connected MLP with manual
//! backprop and SGD, used by the DQN offloading baseline (§V-A) for
//! online Q-learning, plus an experience-replay buffer.
//!
//! Implemented from scratch (the offline image has no ML crates); the
//! network is deliberately the same architecture as the AOT-exported
//! `qnet` artifact (STATE_DIM → 64 → 64 → N_ACTIONS) so the coordinator
//! can serve Q-values through PJRT with identical semantics.

use crate::util::rng::Pcg64;

/// One dense layer: y = W·x + b with optional ReLU.
#[derive(Clone, Debug)]
struct Dense {
    w: Vec<f64>, // row-major (out, in)
    b: Vec<f64>,
    inp: usize,
    out: usize,
    relu: bool,
}

impl Dense {
    fn new(inp: usize, out: usize, relu: bool, rng: &mut Pcg64) -> Dense {
        // He initialization
        let scale = (2.0 / inp as f64).sqrt();
        Dense {
            w: (0..inp * out).map(|_| rng.normal() * scale).collect(),
            b: vec![0.0; out],
            inp,
            out,
            relu,
        }
    }

    fn forward(&self, x: &[f64], pre: &mut Vec<f64>, post: &mut Vec<f64>) {
        pre.clear();
        post.clear();
        for o in 0..self.out {
            let row = &self.w[o * self.inp..(o + 1) * self.inp];
            let mut acc = self.b[o];
            for (wi, xi) in row.iter().zip(x) {
                acc += wi * xi;
            }
            pre.push(acc);
            post.push(if self.relu { acc.max(0.0) } else { acc });
        }
    }
}

/// A multi-layer perceptron: hidden layers use ReLU, output is linear.
#[derive(Clone, Debug)]
pub struct Mlp {
    layers: Vec<Dense>,
    dims: Vec<usize>,
}

impl Mlp {
    /// `dims = [input, hidden..., output]`.
    pub fn new(dims: &[usize], seed: u64) -> Mlp {
        assert!(dims.len() >= 2);
        let mut rng = Pcg64::new(seed, 0x4E4E);
        let layers = dims
            .windows(2)
            .enumerate()
            .map(|(i, w)| Dense::new(w[0], w[1], i + 2 < dims.len(), &mut rng))
            .collect();
        Mlp {
            layers,
            dims: dims.to_vec(),
        }
    }

    pub fn input_dim(&self) -> usize {
        self.dims[0]
    }

    pub fn output_dim(&self) -> usize {
        *self.dims.last().unwrap()
    }

    /// Forward pass; returns the output activations.
    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.input_dim());
        let mut cur = x.to_vec();
        let (mut pre, mut post) = (Vec::new(), Vec::new());
        for l in &self.layers {
            l.forward(&cur, &mut pre, &mut post);
            cur = post.clone();
        }
        cur
    }

    /// One SGD step on squared error of a SINGLE output unit (the taken
    /// action's Q-value) against `target` — the DQN per-transition update.
    /// Returns the pre-update TD error.
    pub fn sgd_step_single(&mut self, x: &[f64], action: usize, target: f64, lr: f64) -> f64 {
        // forward, caching activations
        let mut acts: Vec<Vec<f64>> = vec![x.to_vec()];
        let mut pres: Vec<Vec<f64>> = Vec::new();
        let (mut pre, mut post) = (Vec::new(), Vec::new());
        for l in &self.layers {
            l.forward(acts.last().unwrap(), &mut pre, &mut post);
            pres.push(pre.clone());
            acts.push(post.clone());
        }
        let out = acts.last().unwrap();
        let td = out[action] - target;

        // backward: dL/dout = td on the taken action only (L = 0.5·td²)
        let mut grad = vec![0.0; out.len()];
        grad[action] = td;
        for (li, l) in self.layers.iter_mut().enumerate().rev() {
            let a_in = &acts[li];
            let pre = &pres[li];
            // through relu
            let mut gz = grad.clone();
            if l.relu {
                for (g, p) in gz.iter_mut().zip(pre) {
                    if *p <= 0.0 {
                        *g = 0.0;
                    }
                }
            }
            // grads wrt inputs for next (lower) layer
            let mut gin = vec![0.0; l.inp];
            for o in 0..l.out {
                let go = gz[o];
                if go == 0.0 {
                    continue;
                }
                let row = &mut l.w[o * l.inp..(o + 1) * l.inp];
                for i in 0..l.inp {
                    gin[i] += row[i] * go;
                    row[i] -= lr * go * a_in[i];
                }
                l.b[o] -= lr * go;
            }
            grad = gin;
        }
        td
    }

    /// Polyak/hard copy from another network (target-network sync).
    pub fn copy_from(&mut self, other: &Mlp) {
        assert_eq!(self.dims, other.dims);
        for (a, b) in self.layers.iter_mut().zip(&other.layers) {
            a.w.copy_from_slice(&b.w);
            a.b.copy_from_slice(&b.b);
        }
    }

    /// Flattened parameters (for artifact-parity checks / export).
    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|l| l.w.len() + l.b.len()).sum()
    }
}

/// One DQN transition.
#[derive(Clone, Debug)]
pub struct Transition {
    pub state: Vec<f64>,
    pub action: usize,
    pub reward: f64,
    pub next_state: Vec<f64>,
    pub terminal: bool,
}

/// Fixed-capacity ring-buffer experience replay.
#[derive(Debug)]
pub struct ReplayBuffer {
    buf: Vec<Transition>,
    cap: usize,
    next: usize,
}

impl ReplayBuffer {
    pub fn new(cap: usize) -> ReplayBuffer {
        assert!(cap > 0);
        ReplayBuffer {
            buf: Vec::with_capacity(cap),
            cap,
            next: 0,
        }
    }

    pub fn push(&mut self, t: Transition) {
        if self.buf.len() < self.cap {
            self.buf.push(t);
        } else {
            self.buf[self.next] = t;
        }
        self.next = (self.next + 1) % self.cap;
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn sample<'a>(&'a self, rng: &mut Pcg64, k: usize) -> Vec<&'a Transition> {
        (0..k.min(self.buf.len()))
            .map(|_| &self.buf[rng.usize_in(0, self.buf.len())])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shapes() {
        let net = Mlp::new(&[4, 8, 3], 1);
        let y = net.forward(&[0.1, -0.2, 0.3, 0.4]);
        assert_eq!(y.len(), 3);
        assert_eq!(net.num_params(), 4 * 8 + 8 + 8 * 3 + 3);
    }

    #[test]
    fn deterministic_init() {
        let a = Mlp::new(&[4, 8, 2], 7);
        let b = Mlp::new(&[4, 8, 2], 7);
        assert_eq!(a.forward(&[1.0; 4]), b.forward(&[1.0; 4]));
    }

    #[test]
    fn sgd_reduces_td_error() {
        let mut net = Mlp::new(&[3, 16, 2], 3);
        let x = [0.5, -0.3, 0.8];
        let target = 2.0;
        let before = (net.forward(&x)[1] - target).abs();
        for _ in 0..200 {
            net.sgd_step_single(&x, 1, target, 0.01);
        }
        let after = (net.forward(&x)[1] - target).abs();
        assert!(after < 0.05 * before + 1e-3, "before={before} after={after}");
    }

    #[test]
    fn sgd_single_leaves_other_outputs_mostly_alone() {
        let mut net = Mlp::new(&[3, 32, 2], 4);
        let x = [0.2, 0.1, -0.4];
        let other_before = net.forward(&x)[0];
        for _ in 0..50 {
            net.sgd_step_single(&x, 1, 1.5, 0.005);
        }
        let other_after = net.forward(&x)[0];
        // shared hidden layers move it a little, but far less than the target unit
        assert!((other_after - other_before).abs() < 1.0);
    }

    #[test]
    fn learns_xor_style_function() {
        // regression sanity: fit q(a) = x0 XOR x1 on action 0
        let mut net = Mlp::new(&[2, 24, 1], 5);
        let data = [
            ([0.0, 0.0], 0.0),
            ([0.0, 1.0], 1.0),
            ([1.0, 0.0], 1.0),
            ([1.0, 1.0], 0.0),
        ];
        let mut rng = Pcg64::seed_from_u64(6);
        for _ in 0..4000 {
            let (x, y) = data[rng.usize_in(0, 4)];
            net.sgd_step_single(&x, 0, y, 0.05);
        }
        for (x, y) in data {
            assert!((net.forward(&x)[0] - y).abs() < 0.25, "xor({x:?}) != {y}");
        }
    }

    #[test]
    fn copy_from_syncs() {
        let a = Mlp::new(&[3, 8, 2], 8);
        let mut b = Mlp::new(&[3, 8, 2], 9);
        let x = [0.3, 0.6, -0.1];
        assert_ne!(a.forward(&x), b.forward(&x));
        b.copy_from(&a);
        assert_eq!(a.forward(&x), b.forward(&x));
    }

    #[test]
    fn replay_ring_overwrites() {
        let mut rb = ReplayBuffer::new(4);
        for i in 0..10 {
            rb.push(Transition {
                state: vec![i as f64],
                action: 0,
                reward: 0.0,
                next_state: vec![],
                terminal: false,
            });
        }
        assert_eq!(rb.len(), 4);
        let mut rng = Pcg64::seed_from_u64(10);
        for t in rb.sample(&mut rng, 8) {
            assert!(t.state[0] >= 6.0); // only the newest 4 remain
        }
    }
}
