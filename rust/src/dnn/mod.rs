//! DNN workload profiles (§V-A): per-layer computation and activation
//! sizes for the two evaluated models, VGG19 and ResNet101.
//!
//! Splitting (Alg. 1) consumes the per-layer workload vector `w_1..w_{N^l}`;
//! offloading consumes per-segment workloads and the activation bytes
//! crossing each cut (the tensors shipped over ISLs). Both are pure
//! architecture properties, computed here from layer shapes — no weights
//! involved (DESIGN.md §4).

pub mod early_exit;
mod resnet;
mod vgg;

pub use early_exit::{EarlyExitProfile, ExitBranch};
pub use resnet::resnet101_layers;
pub use vgg::vgg19_layers;

/// The DNN models evaluated in the paper (§V-B).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DnnModel {
    Vgg19,
    Resnet101,
}

impl DnnModel {
    pub fn parse(s: &str) -> Result<DnnModel, String> {
        match s.to_ascii_lowercase().as_str() {
            "vgg19" | "vgg" => Ok(DnnModel::Vgg19),
            "resnet101" | "resnet" => Ok(DnnModel::Resnet101),
            other => Err(format!("unknown model '{other}' (vgg19|resnet101)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DnnModel::Vgg19 => "VGG19",
            DnnModel::Resnet101 => "ResNet101",
        }
    }

    /// Table I defaults: (L, D_M).
    pub fn table1_defaults(&self) -> (usize, usize) {
        match self {
            DnnModel::Vgg19 => (3, 2),
            DnnModel::Resnet101 => (4, 3),
        }
    }

    /// Per-layer profile at the model's canonical 224×224×3 input.
    pub fn profile(&self) -> DnnProfile {
        match self {
            DnnModel::Vgg19 => DnnProfile::new(self.name(), vgg19_layers()),
            DnnModel::Resnet101 => DnnProfile::new(self.name(), resnet101_layers()),
        }
    }
}

/// Kinds of layers that contribute workload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    Fc,
    Pool,
    /// Residual add + ReLU (ResNet block ends).
    Residual,
}

/// One schedulable layer: the unit Alg. 1 groups into blocks.
#[derive(Clone, Debug)]
pub struct LayerSpec {
    pub name: String,
    pub kind: LayerKind,
    /// Computation amount [MFLOP] — the `w_k` of Alg. 1.
    pub workload_mflops: f64,
    /// Output activation size [bytes] — the tensor shipped over an ISL if
    /// the partition cuts after this layer.
    pub output_bytes: f64,
}

/// A whole-model profile with the derived quantities the schemes need.
#[derive(Clone, Debug)]
pub struct DnnProfile {
    pub model_name: &'static str,
    pub layers: Vec<LayerSpec>,
}

impl DnnProfile {
    pub fn new(model_name: &'static str, layers: Vec<LayerSpec>) -> DnnProfile {
        assert!(!layers.is_empty());
        DnnProfile { model_name, layers }
    }

    /// N^l — number of layers (constraint 11e demands N^l >= L).
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Per-layer workload vector `{w_1, ..., w_{N^l}}` [MFLOP].
    pub fn workloads(&self) -> Vec<f64> {
        self.layers.iter().map(|l| l.workload_mflops).collect()
    }

    /// Total model workload [MFLOP].
    pub fn total_mflops(&self) -> f64 {
        self.layers.iter().map(|l| l.workload_mflops).sum()
    }

    /// Largest single-layer workload — Alg. 1's binary-search lower bound.
    pub fn max_layer_mflops(&self) -> f64 {
        self.layers
            .iter()
            .map(|l| l.workload_mflops)
            .fold(0.0, f64::max)
    }

    /// Activation bytes crossing a cut *after* layer `i` (0-based).
    pub fn cut_bytes(&self, i: usize) -> f64 {
        self.layers[i].output_bytes
    }
}

/// FLOPs of a conv layer: 2·OH·OW·K²·Cin·Cout (MAC = 2 FLOP), in MFLOP.
pub fn conv_mflops(oh: usize, ow: usize, k: usize, cin: usize, cout: usize) -> f64 {
    2.0 * (oh * ow) as f64 * (k * k * cin) as f64 * cout as f64 / 1e6
}

/// FLOPs of a fully-connected layer: 2·In·Out, in MFLOP.
pub fn fc_mflops(input: usize, output: usize) -> f64 {
    2.0 * input as f64 * output as f64 / 1e6
}

/// Activation bytes of an NHWC f32 tensor.
pub fn act_bytes(h: usize, w: usize, c: usize) -> f64 {
    (h * w * c * 4) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg19_total_flops_matches_literature() {
        // VGG19 @224 is ~39 GFLOPs (19.6 GMACs).
        let p = DnnModel::Vgg19.profile();
        let total = p.total_mflops();
        assert!(
            (37_000.0..42_000.0).contains(&total),
            "VGG19 total = {total} MFLOP"
        );
    }

    #[test]
    fn resnet101_total_flops_matches_literature() {
        // ResNet101 @224 is ~15.2 GFLOPs (7.6 GMACs).
        let p = DnnModel::Resnet101.profile();
        let total = p.total_mflops();
        assert!(
            (14_000.0..17_000.0).contains(&total),
            "ResNet101 total = {total} MFLOP"
        );
    }

    #[test]
    fn vgg19_has_19_weight_layers() {
        let p = DnnModel::Vgg19.profile();
        let weighted = p
            .layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Conv | LayerKind::Fc))
            .count();
        assert_eq!(weighted, 19); // 16 conv + 3 fc
    }

    #[test]
    fn resnet101_weighted_layer_count() {
        // 1 stem + 33 bottlenecks × 3 + 1 fc = 101 Conv/Fc entries; the 4
        // downsample projections are folded into their block's Residual
        // entry (they run on the same satellite as the add).
        let p = DnnModel::Resnet101.profile();
        let weighted = p
            .layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Conv | LayerKind::Fc))
            .count();
        assert_eq!(weighted, 101);
    }

    #[test]
    fn layer_count_supports_table1_l(/* constraint 11e */) {
        for m in [DnnModel::Vgg19, DnnModel::Resnet101] {
            let (l, _) = m.table1_defaults();
            assert!(m.profile().num_layers() >= l);
        }
    }

    #[test]
    fn workloads_positive_and_cut_bytes_positive() {
        for m in [DnnModel::Vgg19, DnnModel::Resnet101] {
            let p = m.profile();
            for (i, l) in p.layers.iter().enumerate() {
                assert!(l.workload_mflops >= 0.0, "{}: {}", p.model_name, l.name);
                assert!(p.cut_bytes(i) > 0.0);
            }
            assert!(p.max_layer_mflops() <= p.total_mflops());
        }
    }

    #[test]
    fn flop_helpers() {
        // conv3x3, 224x224, 3->64: 2*224*224*9*3*64 = 173.4 MFLOP
        let f = conv_mflops(224, 224, 3, 3, 64);
        assert!((f - 173.408256).abs() < 1e-6);
        assert_eq!(fc_mflops(4096, 1000), 8.192);
        assert_eq!(act_bytes(224, 224, 64), 224.0 * 224.0 * 64.0 * 4.0);
    }

    #[test]
    fn model_parse() {
        assert_eq!(DnnModel::parse("VGG19").unwrap(), DnnModel::Vgg19);
        assert_eq!(DnnModel::parse("resnet").unwrap(), DnnModel::Resnet101);
        assert!(DnnModel::parse("alexnet").is_err());
    }
}
