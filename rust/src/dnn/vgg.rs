//! VGG19 per-layer profile at 224×224×3 (Simonyan & Zisserman config E).
//!
//! 16 conv3×3 layers in five stages (64, 128, 256, 512, 512 channels),
//! 2×2 max-pool after each stage, then FC-4096, FC-4096, FC-1000.
//! ReLU cost is folded into the preceding conv/fc (it is < 0.1 % of the
//! MACs and never a cut point by itself).

use super::{act_bytes, conv_mflops, fc_mflops, LayerKind, LayerSpec};

/// Build the 24-entry layer list (16 conv + 5 pool + 3 fc).
pub fn vgg19_layers() -> Vec<LayerSpec> {
    // (stage channels, convs in stage)
    const STAGES: [(usize, usize); 5] = [(64, 2), (128, 2), (256, 4), (512, 4), (512, 4)];
    let mut layers = Vec::with_capacity(24);
    let mut h = 224usize;
    let mut cin = 3usize;
    for (si, &(cout, reps)) in STAGES.iter().enumerate() {
        for r in 0..reps {
            layers.push(LayerSpec {
                name: format!("conv{}_{}", si + 1, r + 1),
                kind: LayerKind::Conv,
                workload_mflops: conv_mflops(h, h, 3, cin, cout),
                output_bytes: act_bytes(h, h, cout),
            });
            cin = cout;
        }
        h /= 2;
        layers.push(LayerSpec {
            name: format!("pool{}", si + 1),
            kind: LayerKind::Pool,
            // 2x2 max-pool: one compare per output element ≈ 3 ops/out elem
            workload_mflops: 3.0 * (h * h * cout) as f64 / 1e6,
            output_bytes: act_bytes(h, h, cout),
        });
    }
    // h is now 7; flatten 7*7*512 = 25088
    let flat = h * h * cin;
    for (i, (inp, out)) in [(flat, 4096), (4096, 4096), (4096, 1000)]
        .into_iter()
        .enumerate()
    {
        layers.push(LayerSpec {
            name: format!("fc{}", i + 6),
            kind: LayerKind::Fc,
            workload_mflops: fc_mflops(inp, out),
            output_bytes: (out * 4) as f64,
        });
    }
    layers
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count_and_order() {
        let l = vgg19_layers();
        assert_eq!(l.len(), 24);
        assert_eq!(l[0].name, "conv1_1");
        assert_eq!(l[2].name, "pool1");
        assert_eq!(l.last().unwrap().name, "fc8");
    }

    #[test]
    fn conv3_workloads_known_values() {
        let l = vgg19_layers();
        // conv1_2: 224x224, 64->64, 3x3 => 2*224^2*9*64*64 / 1e6
        let conv1_2 = l.iter().find(|x| x.name == "conv1_2").unwrap();
        let expect = 2.0 * 224.0 * 224.0 * 9.0 * 64.0 * 64.0 / 1e6;
        assert!((conv1_2.workload_mflops - expect).abs() < 1e-9);
    }

    #[test]
    fn fc6_is_the_biggest_fc() {
        let l = vgg19_layers();
        let fc6 = l.iter().find(|x| x.name == "fc6").unwrap();
        assert!((fc6.workload_mflops - 2.0 * 25088.0 * 4096.0 / 1e6).abs() < 1e-9);
    }

    #[test]
    fn activations_shrink_across_pools() {
        let l = vgg19_layers();
        let p1 = l.iter().find(|x| x.name == "pool1").unwrap();
        let p5 = l.iter().find(|x| x.name == "pool5").unwrap();
        assert!(p1.output_bytes > p5.output_bytes);
    }
}
