//! ResNet101 per-layer profile at 224×224×3 (He et al., bottleneck v1).
//!
//! Stem conv7×7/2 + maxpool/2, then bottleneck stages [3, 4, 23, 3] with
//! widths (64, 128, 256, 512)×4, global average pool, FC-1000. Each
//! bottleneck contributes its three convs as separate schedulable layers
//! plus a `Residual` add entry; the first block of each stage carries a
//! 1×1 projection on the shortcut (its cost is folded into that block's
//! residual entry, since it executes on the same satellite as the add).

use super::{act_bytes, conv_mflops, fc_mflops, LayerKind, LayerSpec};

/// Build the full layer list (105 weighted layers' worth of work).
pub fn resnet101_layers() -> Vec<LayerSpec> {
    let mut layers = Vec::with_capacity(140);
    // stem: conv7x7/2 3->64 at 112x112, then 3x3 maxpool/2 -> 56x56
    layers.push(LayerSpec {
        name: "conv1".into(),
        kind: LayerKind::Conv,
        workload_mflops: conv_mflops(112, 112, 7, 3, 64),
        output_bytes: act_bytes(112, 112, 64),
    });
    layers.push(LayerSpec {
        name: "maxpool".into(),
        kind: LayerKind::Pool,
        workload_mflops: 8.0 * (56 * 56 * 64) as f64 / 1e6,
        output_bytes: act_bytes(56, 56, 64),
    });

    // (blocks, mid channels, output spatial size)
    const STAGES: [(usize, usize, usize); 4] =
        [(3, 64, 56), (4, 128, 28), (23, 256, 14), (3, 512, 7)];
    let mut cin = 64usize;
    for (si, &(blocks, mid, oh)) in STAGES.iter().enumerate() {
        let cout = mid * 4;
        for b in 0..blocks {
            // the first block of stages 2-4 downsamples: its 3x3 conv has
            // stride 2, so its *input* spatial size is 2*oh.
            let in_h = if b == 0 && si > 0 { oh * 2 } else { oh };
            let stage = si + 2; // torchvision naming: layer2_0 etc. offset
            let prefix = format!("res{}_{:02}", stage, b);
            // 1x1 reduce (spatial = input size)
            layers.push(LayerSpec {
                name: format!("{prefix}_a"),
                kind: LayerKind::Conv,
                workload_mflops: conv_mflops(in_h, in_h, 1, cin, mid),
                output_bytes: act_bytes(in_h, in_h, mid),
            });
            // 3x3 (stride 2 in first block of stages 2-4 => output oh)
            layers.push(LayerSpec {
                name: format!("{prefix}_b"),
                kind: LayerKind::Conv,
                workload_mflops: conv_mflops(oh, oh, 3, mid, mid),
                output_bytes: act_bytes(oh, oh, mid),
            });
            // 1x1 expand
            layers.push(LayerSpec {
                name: format!("{prefix}_c"),
                kind: LayerKind::Conv,
                workload_mflops: conv_mflops(oh, oh, 1, mid, cout),
                output_bytes: act_bytes(oh, oh, cout),
            });
            // residual add (+ 1x1/stride projection in the first block)
            let mut res_mflops = (oh * oh * cout) as f64 / 1e6; // add+relu
            if b == 0 {
                res_mflops += conv_mflops(oh, oh, 1, cin, cout);
            }
            layers.push(LayerSpec {
                name: format!("{prefix}_add"),
                kind: LayerKind::Residual,
                workload_mflops: res_mflops,
                output_bytes: act_bytes(oh, oh, cout),
            });
            cin = cout;
        }
    }

    // global average pool 7x7x2048 -> 2048, then fc1000
    layers.push(LayerSpec {
        name: "avgpool".into(),
        kind: LayerKind::Pool,
        workload_mflops: (7 * 7 * 2048) as f64 / 1e6,
        output_bytes: (2048 * 4) as f64,
    });
    layers.push(LayerSpec {
        name: "fc".into(),
        kind: LayerKind::Fc,
        workload_mflops: fc_mflops(2048, 1000),
        output_bytes: (1000 * 4) as f64,
    });
    layers
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::LayerKind;

    #[test]
    fn block_structure() {
        let l = resnet101_layers();
        // 2 stem entries + (3+4+23+3)*4 block entries + avgpool + fc
        assert_eq!(l.len(), 2 + 33 * 4 + 2);
        let convs = l.iter().filter(|x| x.kind == LayerKind::Conv).count();
        assert_eq!(convs, 1 + 33 * 3); // stem + three per bottleneck
        let residuals = l.iter().filter(|x| x.kind == LayerKind::Residual).count();
        assert_eq!(residuals, 33);
    }

    #[test]
    fn stage3_dominates_depth(/* 23 blocks at 14x14 */) {
        let l = resnet101_layers();
        let stage4_layers = l.iter().filter(|x| x.name.starts_with("res4_")).count();
        assert_eq!(stage4_layers, 23 * 4);
    }

    #[test]
    fn stem_workload_known_value() {
        let l = resnet101_layers();
        // conv7x7/2: 2 * 112^2 * 49 * 3 * 64 / 1e6 ≈ 236.0 MFLOP
        let expect = 2.0 * 112.0 * 112.0 * 49.0 * 3.0 * 64.0 / 1e6;
        assert!((l[0].workload_mflops - expect).abs() < 1e-9);
    }

    #[test]
    fn downsample_blocks_have_projection_cost() {
        let l = resnet101_layers();
        let first_add = l.iter().find(|x| x.name == "res3_00_add").unwrap();
        let later_add = l.iter().find(|x| x.name == "res3_01_add").unwrap();
        assert!(first_add.workload_mflops > 10.0 * later_add.workload_mflops);
    }
}
