//! Early-exit extension (§VI): the paper's stated future work — "the
//! integration of an early exit technique that balances the trade-off
//! between processing delay and accuracy during the DNN partitioning
//! process" (BranchyNet-style side branches, cf. reference [7]).
//!
//! We graft exit branches onto the VGG19/ResNet101 profiles at the stage
//! boundaries. A task that exits at branch `b` only executes the layers
//! up to `b` plus the branch classifier — cutting workload and every
//! downstream transmission — at an accuracy cost taken from the
//! BranchyNet-style accuracy ladder. The split/offload pipeline is
//! unchanged: an exited task simply has a truncated layer-workload
//! vector, so Alg. 1 and Alg. 2 operate on exactly what will execute.

use super::{DnnModel, DnnProfile, LayerKind};

/// One exit branch: after `layer_idx`, a small classifier head can
/// terminate the task with `accuracy` (relative to full-model = 1.0).
#[derive(Clone, Debug)]
pub struct ExitBranch {
    /// Exit after this layer index (0-based, inclusive).
    pub layer_idx: usize,
    /// Workload of the branch classifier head [MFLOP].
    pub head_mflops: f64,
    /// Top-1 accuracy relative to running the full network.
    pub accuracy: f64,
}

/// A profile augmented with exit branches (final "branch" = full model).
#[derive(Clone, Debug)]
pub struct EarlyExitProfile {
    pub base: DnnProfile,
    /// Sorted by layer_idx ascending; does NOT include the natural end.
    pub branches: Vec<ExitBranch>,
}

impl EarlyExitProfile {
    /// Standard branch placement: one exit at each pooling boundary after
    /// the second stage (too-early exits are useless), with an accuracy
    /// ladder shaped like BranchyNet's reported curves (earlier exits are
    /// cheaper and less accurate).
    pub fn for_model(model: DnnModel) -> EarlyExitProfile {
        let base = model.profile();
        let mut pool_idxs: Vec<usize> = base
            .layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.kind == LayerKind::Pool)
            .map(|(i, _)| i)
            .collect();
        // skip the first pooling stage; keep at most 3 interior exits and
        // never the terminal pool (exiting there saves only the head).
        if !pool_idxs.is_empty() {
            pool_idxs.remove(0);
        }
        pool_idxs.pop();
        if pool_idxs.is_empty() {
            // residual nets: the only pools are the stem maxpool and the
            // terminal avgpool, so anchor on bottleneck adds at quarter
            // depths instead
            let res: Vec<usize> = base
                .layers
                .iter()
                .enumerate()
                .filter(|(_, l)| l.kind == LayerKind::Residual)
                .map(|(i, _)| i)
                .collect();
            pool_idxs = (1..=3)
                .filter_map(|q| res.get(q * res.len() / 4).copied())
                .collect();
            pool_idxs.dedup();
        }
        pool_idxs.truncate(3);
        let n = pool_idxs.len().max(1) as f64;
        let branches = pool_idxs
            .iter()
            .enumerate()
            .map(|(rank, &layer_idx)| {
                let depth_frac = (rank as f64 + 1.0) / (n + 1.0);
                ExitBranch {
                    layer_idx,
                    // small FC head over the pooled activation
                    head_mflops: base.layers[layer_idx].output_bytes / 4.0 * 2.0
                        * 256.0
                        / 1e6,
                    // accuracy ladder: 0.80 at the earliest kept exit,
                    // approaching 1.0 with depth
                    accuracy: 0.78 + 0.20 * depth_frac,
                }
            })
            .collect();
        EarlyExitProfile { base, branches }
    }

    /// Layer workload vector for a task exiting at `branch` (None = run
    /// the full model). The branch head is folded into the final layer.
    pub fn workloads_for_exit(&self, branch: Option<usize>) -> Vec<f64> {
        match branch {
            None => self.base.workloads(),
            Some(b) => {
                let br = &self.branches[b];
                let mut w: Vec<f64> = self.base.layers[..=br.layer_idx]
                    .iter()
                    .map(|l| l.workload_mflops)
                    .collect();
                if let Some(last) = w.last_mut() {
                    *last += br.head_mflops;
                }
                w
            }
        }
    }

    /// Accuracy of exiting at `branch` (None = 1.0).
    pub fn accuracy_for_exit(&self, branch: Option<usize>) -> f64 {
        match branch {
            None => 1.0,
            Some(b) => self.branches[b].accuracy,
        }
    }

    /// Workload saving fraction of exiting at `branch` vs the full model.
    pub fn saving_for_exit(&self, branch: usize) -> f64 {
        let full = self.base.total_mflops();
        let exited: f64 = self.workloads_for_exit(Some(branch)).iter().sum();
        1.0 - exited / full
    }

    /// Pick the shallowest exit meeting `min_accuracy`; None if only the
    /// full model qualifies. This is the delay/accuracy policy knob.
    pub fn cheapest_exit(&self, min_accuracy: f64) -> Option<usize> {
        self.branches
            .iter()
            .enumerate()
            .find(|(_, b)| b.accuracy >= min_accuracy)
            .map(|(i, _)| i)
    }

    /// Resolve the full early-exit policy for a simulation engine:
    /// the cheapest branch meeting `min_accuracy`, returned as
    /// `(delivered accuracy, truncated per-layer workload vector)`.
    /// Shared by the slotted and event-driven engines so the exit policy
    /// can never diverge between them.
    pub fn plan(model: crate::dnn::DnnModel, min_accuracy: f64) -> (f64, Vec<f64>) {
        let ee = EarlyExitProfile::for_model(model);
        let branch = ee.cheapest_exit(min_accuracy);
        (ee.accuracy_for_exit(branch), ee.workloads_for_exit(branch))
    }

    /// Expected accuracy/workload pair for a confidence-threshold policy
    /// where a fraction `exit_probs[i]` of tasks exits at branch i (the
    /// remainder runs to completion).
    pub fn expected(&self, exit_probs: &[f64]) -> (f64, f64) {
        assert_eq!(exit_probs.len(), self.branches.len());
        let p_full: f64 = 1.0 - exit_probs.iter().sum::<f64>();
        assert!(
            (-1e-9..=1.0 + 1e-9).contains(&p_full),
            "exit probabilities sum > 1"
        );
        let mut acc = p_full * 1.0;
        let mut work = p_full * self.base.total_mflops();
        for (i, &p) in exit_probs.iter().enumerate() {
            acc += p * self.branches[i].accuracy;
            work += p * self.workloads_for_exit(Some(i)).iter().sum::<f64>();
        }
        (acc, work)
    }

    /// Chainable constraint 11e check for a given split L.
    pub fn supports_l(&self, branch: Option<usize>, l: usize) -> bool {
        self.workloads_for_exit(branch).len() >= l
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn branches_exist_and_are_sorted() {
        for m in [DnnModel::Vgg19, DnnModel::Resnet101] {
            let p = EarlyExitProfile::for_model(m);
            assert!(!p.branches.is_empty(), "{m:?}");
            for w in p.branches.windows(2) {
                assert!(w[0].layer_idx < w[1].layer_idx);
                assert!(w[0].accuracy <= w[1].accuracy, "accuracy ladder");
            }
        }
    }

    #[test]
    fn exit_workloads_truncate_and_save() {
        let p = EarlyExitProfile::for_model(DnnModel::Vgg19);
        let full: f64 = p.workloads_for_exit(None).iter().sum();
        for b in 0..p.branches.len() {
            let exited: f64 = p.workloads_for_exit(Some(b)).iter().sum();
            assert!(exited < full, "exit {b} must save work");
            assert!(p.saving_for_exit(b) > 0.0 && p.saving_for_exit(b) < 1.0);
        }
        // earlier exits save more
        if p.branches.len() >= 2 {
            assert!(p.saving_for_exit(0) > p.saving_for_exit(p.branches.len() - 1));
        }
    }

    #[test]
    fn accuracy_tradeoff_monotone() {
        let p = EarlyExitProfile::for_model(DnnModel::Resnet101);
        let mut prev_acc = 0.0;
        for b in 0..p.branches.len() {
            let acc = p.accuracy_for_exit(Some(b));
            assert!((0.5..1.0).contains(&acc));
            assert!(acc >= prev_acc);
            prev_acc = acc;
        }
        assert_eq!(p.accuracy_for_exit(None), 1.0);
    }

    #[test]
    fn cheapest_exit_respects_floor() {
        let p = EarlyExitProfile::for_model(DnnModel::Vgg19);
        // an impossible floor forces the full model
        assert_eq!(p.cheapest_exit(0.999), None);
        // a trivial floor takes the first branch
        assert_eq!(p.cheapest_exit(0.0), Some(0));
        // the returned exit actually meets the floor
        if let Some(b) = p.cheapest_exit(0.9) {
            assert!(p.branches[b].accuracy >= 0.9);
        }
    }

    #[test]
    fn expected_policy_interpolates() {
        let p = EarlyExitProfile::for_model(DnnModel::Vgg19);
        let k = p.branches.len();
        // nobody exits -> full accuracy/work
        let (acc, work) = p.expected(&vec![0.0; k]);
        assert!((acc - 1.0).abs() < 1e-12);
        assert!((work - p.base.total_mflops()).abs() < 1e-6);
        // everyone exits at branch 0 -> branch-0 accuracy, less work
        let mut probs = vec![0.0; k];
        probs[0] = 1.0;
        let (acc0, work0) = p.expected(&probs);
        assert!((acc0 - p.branches[0].accuracy).abs() < 1e-12);
        assert!(work0 < work);
    }

    #[test]
    fn no_branch_sits_on_the_terminal_pool() {
        // regression: with exactly two Pool layers (ResNet101's stem
        // maxpool + terminal avgpool) the old `len() > 1` guard let the
        // terminal pool through as the only exit — an "exit" that saves
        // nothing but the FC head
        for m in [DnnModel::Vgg19, DnnModel::Resnet101] {
            let p = EarlyExitProfile::for_model(m);
            let last_pool = p
                .base
                .layers
                .iter()
                .rposition(|l| l.kind == LayerKind::Pool)
                .unwrap();
            for b in &p.branches {
                assert_ne!(
                    b.layer_idx, last_pool,
                    "{m:?}: exit anchored on the terminal pool"
                );
                assert!(b.layer_idx < p.base.layers.len() - 1);
            }
            // every kept exit still saves a meaningful fraction of work
            for b in 0..p.branches.len() {
                assert!(p.saving_for_exit(b) > 0.05, "{m:?} exit {b} saves ~nothing");
            }
        }
    }

    #[test]
    fn first_layer_exit_truncates_to_one_layer() {
        let base = DnnModel::Vgg19.profile();
        let w0 = base.layers[0].workload_mflops;
        let p = EarlyExitProfile {
            base,
            branches: vec![ExitBranch {
                layer_idx: 0,
                head_mflops: 1.5,
                accuracy: 0.6,
            }],
        };
        let w = p.workloads_for_exit(Some(0));
        assert_eq!(w.len(), 1);
        assert!((w[0] - (w0 + 1.5)).abs() < 1e-12, "head folds into layer 0");
        assert!(p.supports_l(Some(0), 1));
        assert!(!p.supports_l(Some(0), 2));
    }

    #[test]
    fn last_layer_exit_keeps_full_length() {
        let base = DnnModel::Vgg19.profile();
        let n = base.layers.len();
        let full: f64 = base.total_mflops();
        let p = EarlyExitProfile {
            base,
            branches: vec![ExitBranch {
                layer_idx: n - 1,
                head_mflops: 2.0,
                accuracy: 0.99,
            }],
        };
        let w = p.workloads_for_exit(Some(0));
        assert_eq!(w.len(), n, "an exit after the last layer truncates nothing");
        let sum: f64 = w.iter().sum();
        assert!((sum - (full + 2.0)).abs() < 1e-6);
        // such an "exit" costs more than the full model — negative saving
        assert!(p.saving_for_exit(0) < 0.0);
    }

    #[test]
    fn zero_confidence_floor_takes_the_earliest_exit() {
        for m in [DnnModel::Vgg19, DnnModel::Resnet101] {
            let p = EarlyExitProfile::for_model(m);
            assert_eq!(p.cheapest_exit(0.0), Some(0), "{m:?}");
            // a floor exactly on a branch's accuracy admits that branch
            let acc0 = p.branches[0].accuracy;
            assert_eq!(p.cheapest_exit(acc0), Some(0), "{m:?}");
            // the shared engine-facing policy agrees
            let (acc, w) = EarlyExitProfile::plan(m, 0.0);
            assert!((acc - acc0).abs() < 1e-12, "{m:?}");
            assert_eq!(w.len(), p.branches[0].layer_idx + 1, "{m:?}");
            assert!(w.len() < p.base.layers.len(), "{m:?}");
        }
    }

    #[test]
    fn truncated_profiles_still_splittable() {
        let p = EarlyExitProfile::for_model(DnnModel::Vgg19);
        for b in 0..p.branches.len() {
            let w = p.workloads_for_exit(Some(b));
            assert!(p.supports_l(Some(b), 3.min(w.len())));
            let res = crate::splitting::balanced_split(&w, 3.min(w.len()), 1.0);
            assert_eq!(res.blocks.len(), 3.min(w.len()));
        }
    }
}
