//! TOML-subset parser for configuration files (the image has no `toml`
//! crate). Supports: `[section]` headers, `key = value` with string,
//! integer, float, and boolean values, `#` comments, and blank lines.
//! Nested tables beyond one level and arrays are intentionally out of
//! scope — config files stay flat.

use std::collections::BTreeMap;

/// A parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

/// A parsed document: `(section, key) -> value`, root section is `""`.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    map: BTreeMap<(String, String), TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(body) = line.strip_prefix('[') {
                let name = body
                    .strip_suffix(']')
                    .ok_or(format!("line {}: unterminated section header", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or(format!("line {}: expected 'key = value'", lineno + 1))?;
            let key = k.trim().to_string();
            let value = parse_value(v.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            doc.map.insert((section.clone(), key), value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.map.get(&(section.to_string(), key.to_string()))
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<String> {
        match self.get(section, key) {
            Some(TomlValue::Str(s)) => Some(s.clone()),
            _ => None,
        }
    }

    pub fn get_i64(&self, section: &str, key: &str) -> Option<i64> {
        match self.get(section, key) {
            Some(TomlValue::Int(i)) => Some(*i),
            Some(TomlValue::Float(f)) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key) {
            Some(TomlValue::Bool(b)) => Some(*b),
            _ => None,
        }
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Option<f64> {
        match self.get(section, key) {
            Some(TomlValue::Float(f)) => Some(*f),
            Some(TomlValue::Int(i)) => Some(*i as f64),
            _ => None,
        }
    }

    /// Read-into-helpers: assign only if the key is present.
    pub fn read_f64(&self, section: &str, key: &str, out: &mut f64) {
        if let Some(v) = self.get_f64(section, key) {
            *out = v;
        }
    }

    pub fn read_usize(&self, section: &str, key: &str, out: &mut usize) {
        if let Some(v) = self.get_i64(section, key) {
            if v >= 0 {
                *out = v as usize;
            }
        }
    }

    pub fn read_u64(&self, section: &str, key: &str, out: &mut u64) {
        if let Some(v) = self.get_i64(section, key) {
            if v >= 0 {
                *out = v as u64;
            }
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &(String, String)> {
        self.map.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // a '#' inside a quoted string does not start a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if let Some(body) = s.strip_prefix('"') {
        let inner = body
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string: {s}"))?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let clean = s.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value: {s}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
# top comment
n = 10
lambda = 25.5        # inline comment
name = "vgg # 19"
flag = true

[ga]
n_iter = 10
"#,
        )
        .unwrap();
        assert_eq!(doc.get_i64("", "n"), Some(10));
        assert_eq!(doc.get_f64("", "lambda"), Some(25.5));
        assert_eq!(doc.get_str("", "name").as_deref(), Some("vgg # 19"));
        assert_eq!(doc.get("", "flag"), Some(&TomlValue::Bool(true)));
        assert_eq!(doc.get_i64("ga", "n_iter"), Some(10));
    }

    #[test]
    fn int_float_coercion() {
        let doc = TomlDoc::parse("a = 3\nb = 4.0\n").unwrap();
        assert_eq!(doc.get_f64("", "a"), Some(3.0));
        assert_eq!(doc.get_i64("", "b"), Some(4));
    }

    #[test]
    fn underscored_numbers() {
        let doc = TomlDoc::parse("big = 1_000_000\n").unwrap();
        assert_eq!(doc.get_i64("", "big"), Some(1_000_000));
    }

    #[test]
    fn errors_are_line_numbered() {
        let e = TomlDoc::parse("ok = 1\nbroken line\n").unwrap_err();
        assert!(e.contains("line 2"), "{e}");
    }

    #[test]
    fn missing_keys_leave_defaults() {
        let doc = TomlDoc::parse("").unwrap();
        let mut x = 7.0;
        doc.read_f64("", "nope", &mut x);
        assert_eq!(x, 7.0);
    }
}
