//! Configuration system: every Table I parameter, with TOML-file loading
//! (a self-contained TOML-subset parser — the image has no serde/toml) and
//! CLI overrides.
//!
//! Defaults reproduce Table I of the paper exactly; see
//! [`SimConfig::default`] and [`GaConfig::default`].

mod toml_lite;

pub use toml_lite::TomlDoc;

use crate::dnn::DnnModel;
use crate::obs::{ObsConfig, TraceConfig};
use crate::resilience::{FaultTrace, RecoveryPolicy};
use crate::state::DisseminationKind;
use crate::tasks::TaskKind;
use crate::topology::{Constellation, TopologyKind};
use crate::util::cli::Args;

/// Which simulation engine executes the run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// The paper's fixed-slot simulator ([`crate::sim::Simulation`]).
    Slotted,
    /// The continuous-time discrete-event kernel
    /// ([`crate::eventsim::EventSim`]).
    Event,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<EngineKind, String> {
        match s.to_ascii_lowercase().as_str() {
            "slotted" | "slot" => Ok(EngineKind::Slotted),
            "event" | "eventsim" | "des" => Ok(EngineKind::Event),
            other => Err(format!("unknown engine '{other}' (slotted|event)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Slotted => "slotted",
            EngineKind::Event => "event",
        }
    }

    pub fn all() -> [EngineKind; 2] {
        [EngineKind::Slotted, EngineKind::Event]
    }
}

/// Traffic profile driving the event engine's arrival processes (the
/// slotted engine always runs the paper's homogeneous Poisson traffic).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScenarioKind {
    /// Homogeneous Poisson(λ) — the paper baseline (§V-A).
    Poisson,
    /// Sinusoidal diurnal rate, phase-staggered across gateway areas.
    Diurnal,
    /// Bursty MMPP on/off traffic.
    Bursty,
    /// Ground-track hotspot concentrating load on a moving area subset.
    Hotspot,
}

impl ScenarioKind {
    pub fn parse(s: &str) -> Result<ScenarioKind, String> {
        match s.to_ascii_lowercase().as_str() {
            "poisson" | "homogeneous" => Ok(ScenarioKind::Poisson),
            "diurnal" | "sinusoidal" => Ok(ScenarioKind::Diurnal),
            "bursty" | "mmpp" => Ok(ScenarioKind::Bursty),
            "hotspot" | "ground-track" => Ok(ScenarioKind::Hotspot),
            other => Err(format!(
                "unknown scenario '{other}' (poisson|diurnal|bursty|hotspot)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ScenarioKind::Poisson => "poisson",
            ScenarioKind::Diurnal => "diurnal",
            ScenarioKind::Bursty => "bursty",
            ScenarioKind::Hotspot => "hotspot",
        }
    }

    pub fn all() -> [ScenarioKind; 4] {
        [
            ScenarioKind::Poisson,
            ScenarioKind::Diurnal,
            ScenarioKind::Bursty,
            ScenarioKind::Hotspot,
        ]
    }
}

/// GA hyper-parameters (Table I, last row).
#[derive(Clone, Debug, PartialEq)]
pub struct GaConfig {
    /// θ1 — computation-delay weight in the deficit (Eq. 12).
    pub theta1: f64,
    /// θ2 — transmission (workload × Manhattan-hops) weight in Eq. 12.
    pub theta2: f64,
    /// θ3 — drop-count weight in Eq. 12.
    pub theta3: f64,
    /// N_ini — initial population size.
    pub n_ini: usize,
    /// N_iter — maximum GA iterations.
    pub n_iter: usize,
    /// N_K — population size kept after elimination.
    pub n_k: usize,
    /// N_summ — fresh random chromosomes injected per iteration.
    pub n_summ: usize,
    /// ε — early-stop threshold on the best-deficit delta between iterations.
    pub epsilon: f64,
}

impl Default for GaConfig {
    fn default() -> Self {
        // Table I: θ1, θ2, θ3, N_ini, N_iter, N_K, N_summ, ε = 1, 20, 1e6, 20, 10, 20, 10, 1
        GaConfig {
            theta1: 1.0,
            theta2: 20.0,
            theta3: 1e6,
            n_ini: 20,
            n_iter: 10,
            n_k: 20,
            n_summ: 10,
            epsilon: 1.0,
        }
    }
}

/// Communication-model parameters (Eq. 1–2, Table I).
#[derive(Clone, Debug, PartialEq)]
pub struct CommConfig {
    /// B — inter-satellite bandwidth [Hz] (Table I: 20 MHz).
    pub isl_bandwidth_hz: f64,
    /// P_t — satellite transmit power [dBW] (Table I: 30 dBW).
    pub sat_tx_power_dbw: f64,
    /// B0 — gateway channel bandwidth [Hz] (Table I: 10 MHz).
    pub gw_bandwidth_hz: f64,
    /// P_g — gateway transmit power [dBW].
    pub gw_tx_power_dbw: f64,
    /// Transmit/receive antenna gain product G_i(j)·G_j(i) [dBi sum].
    pub antenna_gain_dbi: f64,
    /// Beam-pointing loss coefficient L_i(j)=L_j(i) (< 1).
    pub pointing_coeff: f64,
    /// System noise temperature T [K].
    pub noise_temp_k: f64,
    /// Gateway AWGN power M_G [dBW].
    pub gw_noise_dbw: f64,
    /// Mean shadowing attenuation for the shadowed-Rician gateway channel [dB].
    pub shadow_sigma_db: f64,
    /// Rician K-factor for the gateway small-scale fading [dB].
    pub rician_k_db: f64,
    /// Per-hop ISL store-and-forward latency [ms] (`--isl-latency-ms`).
    /// Sets the default gossip dissemination tick: state flooded over
    /// ISLs advances one hop per this interval. ~25 ms is the typical
    /// LEO ISL store-and-forward figure.
    pub isl_latency_ms: f64,
}

impl Default for CommConfig {
    fn default() -> Self {
        CommConfig {
            isl_bandwidth_hz: 20e6,
            sat_tx_power_dbw: 30.0,
            gw_bandwidth_hz: 10e6,
            gw_tx_power_dbw: 10.0,
            antenna_gain_dbi: 60.0, // 30 dBi per LEO dish, tx+rx
            pointing_coeff: 0.9,
            noise_temp_k: 354.8, // typical LEO ISL system temperature
            gw_noise_dbw: -130.0,
            shadow_sigma_db: 2.0,
            rician_k_db: 10.0,
            isl_latency_ms: 25.0,
        }
    }
}

/// Defaults for the LLM-era autoregressive workload class (`[llm]` TOML
/// block): an unstated parameter of `--task-kind autoregressive[:...]`
/// falls back to these, and the per-round execution knobs
/// (`round_deadline_s`, `small_model_factor`) live here because they are
/// engine parameters, not part of the task-kind selector itself.
#[derive(Clone, Debug, PartialEq)]
pub struct LlmConfig {
    /// Decode rounds per task after the prefill chain.
    pub rounds: u32,
    /// Full-model workload of one decode round [MFLOP].
    pub decode_flops: f64,
    /// KV-cache size shipped over ISLs when the serving satellite
    /// changes [bytes].
    pub state_bytes: f64,
    /// Small-model-first escalation threshold [s] (`None` = no
    /// escalation: decode on the chain's last satellite).
    pub escalate: Option<f64>,
    /// Per-round deadline [s]: a round whose ready-to-done delay exceeds
    /// this drops the task's remaining rounds.
    pub round_deadline_s: f64,
    /// Workload ratio of the serving satellite's small model (escalation
    /// mode runs `decode_flops × small_model_factor` per round until the
    /// threshold trips).
    pub small_model_factor: f64,
}

impl Default for LlmConfig {
    fn default() -> Self {
        LlmConfig {
            rounds: 8,
            // ~one token of a distilled ~100M-param on-board model
            decode_flops: 200.0,
            state_bytes: 262_144.0, // 256 KiB KV cache
            escalate: None,
            round_deadline_s: 0.5,
            small_model_factor: 0.25,
        }
    }
}

/// Fault injection + recovery knobs (`[resilience]` TOML block,
/// `--p-fail` / `--link-p-fail` / `--recovery` / `--fault-trace` on the
/// CLI). Everything defaults off: no injector is constructed, recovery
/// is the legacy drop, and runs stay byte-identical with pre-resilience
/// builds (`tests/prop_resilience.rs`).
#[derive(Clone, Debug, PartialEq)]
pub struct ResilienceConfig {
    /// Per-satellite Bernoulli failure probability per injector tick
    /// (`--p-fail`). 0 disables the satellite fault process entirely.
    pub p_fail: f64,
    /// Per-satellite Bernoulli recovery probability per tick while down
    /// (`--p-recover`).
    pub p_recover: f64,
    /// Per-ISL-link Bernoulli failure probability per tick
    /// (`--link-p-fail`). 0 disables the link outage process.
    pub link_p_fail: f64,
    /// Per-link Bernoulli recovery probability per tick while out
    /// (`--link-p-recover`).
    pub link_p_recover: f64,
    /// Restrict Bernoulli link failures to links touching the first or
    /// last orbital plane — the Walker-star seam region
    /// (`--seam-outage`).
    pub seam_only: bool,
    /// What happens to a task's surviving segment chain on a satellite
    /// fault (`--recovery drop|reoffload[:<max_retries>]`).
    pub recovery: RecoveryPolicy,
    /// Scripted outage windows (`--fault-trace <file>`), parsed eagerly
    /// at load time so malformed traces fail at the CLI boundary.
    pub fault_trace: Option<FaultTrace>,
    /// Path the trace came from (for `table()` rendering).
    pub fault_trace_path: Option<String>,
    /// How long an in-flight ISL transfer stalls on a dead link before
    /// retrying the route [s].
    pub link_timeout_s: f64,
    /// Deadline-aware give-up: a faulted task older than this is dropped
    /// rather than re-offloaded [s].
    pub deadline_s: f64,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        ResilienceConfig {
            p_fail: 0.0,
            p_recover: 0.3,
            link_p_fail: 0.0,
            link_p_recover: 0.3,
            seam_only: false,
            recovery: RecoveryPolicy::Drop,
            fault_trace: None,
            fault_trace_path: None,
            link_timeout_s: 1.0,
            deadline_s: 10.0,
        }
    }
}

impl ResilienceConfig {
    /// Does this config inject satellite faults at all (Bernoulli
    /// process or scripted windows)? Engines skip constructing the
    /// `FaultInjector` — and scheduling its per-tick `Fault` events —
    /// when false.
    pub fn sat_faults_active(&self) -> bool {
        self.p_fail > 0.0
            || self
                .fault_trace
                .as_ref()
                .is_some_and(|t| t.has_sat_windows())
    }

    /// Does this config inject ISL link outages at all?
    pub fn link_faults_active(&self) -> bool {
        self.link_p_fail > 0.0
            || self
                .fault_trace
                .as_ref()
                .is_some_and(|t| t.has_link_windows())
    }
}

/// Satellite compute parameters (Table I + Eq. 4).
#[derive(Clone, Debug, PartialEq)]
pub struct SatelliteConfig {
    /// C_x — computation capability [MFLOP per slot] (Table I: 3 GHz ⇒ 3000).
    pub capacity_mflops: f64,
    /// M_w — maximum total loaded workload [MFLOP] before segments are
    /// rejected (Eq. 4); backlog depth × capacity.
    pub max_workload_mflops: f64,
}

impl Default for SatelliteConfig {
    fn default() -> Self {
        SatelliteConfig {
            // Table I: 3 GHz. An in-orbit SBC core retires ~16 f32 FLOPs
            // per cycle (dual-issue 128-bit SIMD FMA), so one 1-second
            // slot services 48 GFLOP. With 5 gateway areas x D_M-reachable
            // neighbourhoods this puts the constellation at a ~0.9 load
            // factor at λ=70 — the paper's operating regime (all schemes
            // complete most tasks; delays in the 1-4 s band with
            // scheme gaps of hundreds of ms).
            capacity_mflops: 48_000.0,
            max_workload_mflops: 240_000.0, // 5-slot admission window (M_w)
        }
    }
}

/// Full simulation configuration (Table I + objective weights of Eq. 10).
#[derive(Clone, Debug, PartialEq)]
pub struct SimConfig {
    /// N — constellation is N orbits × N satellites (Table I: 4–32, default 10).
    /// Only used when `topology` is unset (the paper-default torus).
    pub n: usize,
    /// Constellation geometry override
    /// (`--topology torus:<n>|walker-delta:<p>x<s>[:f]|walker-star:<p>x<s>`,
    /// TOML `topology = "..."`). `None` keeps the paper's N×N torus from
    /// `n` — see [`SimConfig::effective_topology`].
    pub topology: Option<TopologyKind>,
    /// Γ — number of time slots to simulate.
    pub slots: usize,
    /// λ — Poisson task incidence per decision satellite per slot (4–70).
    pub lambda: f64,
    /// Fraction of satellites that act as decision-making satellites
    /// (those with a gateway in view generating tasks).
    pub decision_fraction: f64,
    /// DNN model whose tasks arrive (VGG19 or ResNet101).
    pub model: DnnModel,
    /// L — task splitting number; `None` ⇒ Table I default per model
    /// (3 for VGG19, 4 for ResNet101).
    pub split_l: Option<usize>,
    /// D_M — maximum Manhattan offloading distance; `None` ⇒ Table I
    /// default per model (2 for VGG19, 3 for ResNet101).
    pub d_max: Option<usize>,
    /// α — drop-rate weight in the objective (Eq. 10).
    pub alpha: f64,
    /// β — delay weight in the objective (Eq. 10).
    pub beta: f64,
    /// RNG seed for the whole experiment.
    pub seed: u64,
    /// Simulation engine: the paper's slotted loop or the event kernel.
    pub engine: EngineKind,
    /// Traffic scenario for the event engine (ignored by the slotted one).
    pub scenario: ScenarioKind,
    /// How resource state reaches decision satellites
    /// (`--dissemination instant|periodic:<s>|gossip[:<s>]`, TOML
    /// `dissemination = "..."`). `None` keeps each engine's legacy model:
    /// the event engine decides on fresh state (`instant`), the slotted
    /// engine on its slot-start snapshot (`periodic:1`) — see
    /// [`SimConfig::effective_dissemination_for`].
    pub dissemination: Option<DisseminationKind>,
    /// True when `dissemination` is a bare `gossip` whose tick was
    /// derived from `comm.isl_latency_ms` — a later `--isl-latency-ms`
    /// re-derives it. An explicit `gossip:<tick>` pins the tick and
    /// leaves this false. Maintained by the TOML/CLI loaders.
    pub gossip_tick_derived: bool,
    /// Pending-event queue shards for the event engine (`--shards`, TOML
    /// `shards = ...`). `1` (default) is the classic single-heap queue;
    /// `0` means auto — one shard per orbital plane of the effective
    /// topology; `K > 1` pins K shards. Sharding preserves the global
    /// `(time, seq)` event order exactly, so every setting produces
    /// byte-identical reports (enforced by `tests/prop_sharded.rs`);
    /// ignored by the slotted engine.
    pub shards: usize,
    /// Keep the full per-task `TaskOutcome` buffer in the report (memory
    /// grows with task count). Default false: metrics stream into
    /// constant-size accumulators so million-task runs stay flat in
    /// memory; enable only when plots/traces need per-task data
    /// (`--retain-outcomes` on the CLI, `retain_outcomes = true` in TOML).
    pub retain_outcomes: bool,
    /// Worker lanes for pooled GA generation evaluation
    /// (`--decide-threads`, TOML `decide_threads = ...`). `1` (default)
    /// is the sequential kernel; `0` means auto — one lane per available
    /// core; `K > 1` pins K lanes. Chromosome deficits are independent
    /// reductions, so every setting produces byte-identical runs
    /// (enforced by `tests/prop_pool.rs`); only the GA (SCC) scheme has
    /// generations to pool.
    pub decide_threads: usize,
    /// Epoch-keyed final-placement cache for the GA scheme
    /// (`--decision-cache`, TOML `decision_cache = true`). Between view
    /// epochs (broadcasts / faults / handovers), decides for the same
    /// (origin, segment profile, migration) replay the cached placement.
    /// A hit skips the GA's RNG draws, so this is NOT byte-identical —
    /// default false, and off == legacy is pinned by `tests/prop_pool.rs`.
    pub decision_cache: bool,
    /// Observability knobs (`--telemetry`, `--trace`, `--counter-period`,
    /// TOML `[obs]`). Default: everything off — engines then skip every
    /// telemetry hook behind one `enabled` branch, keeping runs
    /// bit-for-bit identical to pre-telemetry builds.
    pub obs: ObsConfig,
    /// Workload class (`--task-kind oneshot|autoregressive[:...]`, TOML
    /// `task_kind = "..."`). `None` keeps the paper's one-shot tasks —
    /// bit-for-bit the pre-task-kind behaviour on both engines
    /// (`tests/prop_taskkind.rs`) — see [`SimConfig::effective_task_kind`].
    pub task_kind: Option<TaskKind>,
    /// Defaults + execution knobs for the autoregressive class
    /// (`[llm]` TOML block).
    pub llm: LlmConfig,
    /// Fault injection + recovery (`[resilience]` TOML block,
    /// `--p-fail` / `--link-p-fail` / `--recovery` / `--fault-trace`).
    /// Defaults off — see [`ResilienceConfig`].
    pub resilience: ResilienceConfig,
    pub ga: GaConfig,
    pub comm: CommConfig,
    pub satellite: SatelliteConfig,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            n: 10,
            topology: None,
            slots: 40,
            lambda: 25.0,
            // "multiple remote rural areas" (Fig. 1): 5 gateway areas on
            // the default 100-satellite constellation.
            decision_fraction: 0.05,
            model: DnnModel::Vgg19,
            split_l: None,
            d_max: None,
            alpha: 1.0,
            beta: 1.0,
            seed: 42,
            engine: EngineKind::Slotted,
            scenario: ScenarioKind::Poisson,
            dissemination: None,
            gossip_tick_derived: false,
            shards: 1,
            retain_outcomes: false,
            decide_threads: 1,
            decision_cache: false,
            obs: ObsConfig::default(),
            task_kind: None,
            llm: LlmConfig::default(),
            resilience: ResilienceConfig::default(),
            ga: GaConfig::default(),
            comm: CommConfig::default(),
            satellite: SatelliteConfig::default(),
        }
    }
}

impl SimConfig {
    /// Effective L (Table I: 3 for VGG19, 4 for ResNet101).
    pub fn effective_l(&self) -> usize {
        self.split_l.unwrap_or(match self.model {
            DnnModel::Vgg19 => 3,
            DnnModel::Resnet101 => 4,
        })
    }

    /// Effective D_M (Table I: 2 for VGG19, 3 for ResNet101).
    pub fn effective_d_max(&self) -> usize {
        self.d_max.unwrap_or(match self.model {
            DnnModel::Vgg19 => 2,
            DnnModel::Resnet101 => 3,
        })
    }

    /// The topology selector this run uses: the configured one, or the
    /// paper's N×N torus built from `n`. The default path is bit-for-bit
    /// the legacy torus behaviour (enforced by `tests/prop_topology.rs`).
    pub fn effective_topology(&self) -> TopologyKind {
        self.topology
            .clone()
            .unwrap_or(TopologyKind::Torus { n: self.n })
    }

    /// Build the constellation for this run (Walker kinds pay their
    /// one-time BFS APSP here; engines call this once per simulation).
    pub fn build_topology(&self) -> Constellation {
        self.effective_topology().build()
    }

    /// The dissemination model the given engine runs: the configured one,
    /// or the engine's legacy default — `instant` for the event engine
    /// (pre-dissemination behaviour, enforced bit-for-bit by
    /// `tests/prop_staleness.rs`), `periodic:1` (one slot) for the slotted
    /// engine (its classic slot-start snapshot, likewise enforced).
    ///
    /// The slotted clock can disseminate at most once per slot, so for
    /// [`EngineKind::Slotted`] the configured model is quantized via
    /// [`DisseminationKind::quantized_to_slots`] — what this returns is
    /// what actually runs (and what [`SimConfig::table`] prints).
    ///
    /// Parameterized by engine rather than reading `self.engine` because
    /// `Simulation::new` / `EventSim::new` can be called directly with a
    /// config whose `engine` field names the *other* engine.
    pub fn effective_dissemination_for(&self, engine: EngineKind) -> DisseminationKind {
        let configured = self.dissemination.unwrap_or(match engine {
            EngineKind::Event => DisseminationKind::Instant,
            EngineKind::Slotted => DisseminationKind::Periodic { period_s: 1.0 },
        });
        match engine {
            EngineKind::Event => configured,
            EngineKind::Slotted => configured.quantized_to_slots(),
        }
    }

    /// [`SimConfig::effective_dissemination_for`] on `self.engine`.
    pub fn effective_dissemination(&self) -> DisseminationKind {
        self.effective_dissemination_for(self.engine)
    }

    /// The workload class this run generates: the configured one, or the
    /// paper's one-shot tasks. The default path is bit-for-bit the legacy
    /// behaviour (enforced by `tests/prop_taskkind.rs`).
    pub fn effective_task_kind(&self) -> TaskKind {
        self.task_kind.unwrap_or(TaskKind::OneShot)
    }

    /// Validate parameter ranges; returns a description of each violation.
    pub fn validate(&self) -> Result<(), Vec<String>> {
        let mut errs = Vec::new();
        if self.n < 2 {
            errs.push(format!("n={} must be >= 2", self.n));
        }
        if self.lambda < 0.0 {
            errs.push(format!("lambda={} must be >= 0", self.lambda));
        }
        if !(0.0..=1.0).contains(&self.decision_fraction) {
            errs.push(format!(
                "decision_fraction={} must be in [0,1]",
                self.decision_fraction
            ));
        }
        if self.effective_l() == 0 {
            errs.push("L must be >= 1".into());
        }
        if self.satellite.capacity_mflops <= 0.0 {
            errs.push("satellite.capacity_mflops must be > 0".into());
        }
        if self.satellite.max_workload_mflops <= 0.0 {
            errs.push("satellite.max_workload_mflops must be > 0".into());
        }
        if self.ga.n_ini == 0 || self.ga.n_k == 0 {
            errs.push("ga.n_ini and ga.n_k must be >= 1".into());
        }
        if let Some(t) = &self.topology {
            if let Err(e) = t.validate() {
                errs.push(e);
            }
        }
        if !self.comm.isl_latency_ms.is_finite() || self.comm.isl_latency_ms <= 0.0 {
            errs.push(format!(
                "comm.isl_latency_ms={} must be finite and > 0",
                self.comm.isl_latency_ms
            ));
        }
        if let Some(d) = &self.dissemination {
            if let Err(e) = d.validate() {
                errs.push(e);
            }
        }
        if let Err(e) = self.obs.validate() {
            errs.push(format!("obs: {e}"));
        }
        if let Some(k) = &self.task_kind {
            if let Err(e) = k.validate() {
                errs.push(e);
            }
        }
        if !self.llm.round_deadline_s.is_finite() || self.llm.round_deadline_s <= 0.0 {
            errs.push(format!(
                "llm.round_deadline_s={} must be finite and > 0",
                self.llm.round_deadline_s
            ));
        }
        if !(self.llm.small_model_factor > 0.0 && self.llm.small_model_factor <= 1.0) {
            errs.push(format!(
                "llm.small_model_factor={} must be in (0,1]",
                self.llm.small_model_factor
            ));
        }
        let r = &self.resilience;
        for (name, p) in [
            ("resilience.p_fail", r.p_fail),
            ("resilience.p_recover", r.p_recover),
            ("resilience.link_p_fail", r.link_p_fail),
            ("resilience.link_p_recover", r.link_p_recover),
        ] {
            if !p.is_finite() || !(0.0..=1.0).contains(&p) {
                errs.push(format!("{name}={p} must be in [0,1]"));
            }
        }
        if !r.link_timeout_s.is_finite() || r.link_timeout_s <= 0.0 {
            errs.push(format!(
                "resilience.link_timeout_s={} must be finite and > 0",
                r.link_timeout_s
            ));
        }
        if !r.deadline_s.is_finite() || r.deadline_s <= 0.0 {
            errs.push(format!(
                "resilience.deadline_s={} must be finite and > 0",
                r.deadline_s
            ));
        }
        if let Some(trace) = &r.fault_trace {
            if let Some(max) = trace.max_sat_id() {
                let n_sats = self.effective_topology().n_sats();
                if max >= n_sats {
                    errs.push(format!(
                        "fault-trace references satellite {max} but the topology has {n_sats} sats"
                    ));
                }
            }
        }
        if errs.is_empty() {
            Ok(())
        } else {
            Err(errs)
        }
    }

    /// Load from a TOML file then apply CLI overrides.
    pub fn load(path: Option<&str>, args: &Args) -> Result<SimConfig, String> {
        let mut cfg = match path {
            Some(p) => {
                let text = std::fs::read_to_string(p)
                    .map_err(|e| format!("reading {p}: {e}"))?;
                Self::from_toml(&text)?
            }
            None => SimConfig::default(),
        };
        cfg.apply_args(args)?;
        cfg.validate().map_err(|v| v.join("; "))?;
        Ok(cfg)
    }

    /// Parse the TOML-subset format (see [`toml_lite`]).
    pub fn from_toml(text: &str) -> Result<SimConfig, String> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = SimConfig::default();
        let d = &mut cfg;
        doc.read_usize("", "n", &mut d.n);
        doc.read_usize("", "slots", &mut d.slots);
        doc.read_f64("", "lambda", &mut d.lambda);
        doc.read_f64("", "decision_fraction", &mut d.decision_fraction);
        doc.read_f64("", "alpha", &mut d.alpha);
        doc.read_f64("", "beta", &mut d.beta);
        doc.read_u64("", "seed", &mut d.seed);
        if let Some(m) = doc.get_str("", "model") {
            d.model = DnnModel::parse(&m)?;
        }
        if let Some(l) = doc.get_i64("", "split_l") {
            d.split_l = Some(l as usize);
        }
        if let Some(dm) = doc.get_i64("", "d_max") {
            d.d_max = Some(dm as usize);
        }
        if let Some(e) = doc.get_str("", "engine") {
            d.engine = EngineKind::parse(&e)?;
        }
        if let Some(s) = doc.get_str("", "scenario") {
            d.scenario = ScenarioKind::parse(&s)?;
        }
        if let Some(t) = doc.get_str("", "topology") {
            d.topology = Some(TopologyKind::parse(&t)?);
        }
        if let Some(b) = doc.get_bool("", "retain_outcomes") {
            d.retain_outcomes = b;
        }
        doc.read_usize("", "shards", &mut d.shards);
        doc.read_usize("", "decide_threads", &mut d.decide_threads);
        if let Some(b) = doc.get_bool("", "decision_cache") {
            d.decision_cache = b;
        }
        if let Some(b) = doc.get_bool("obs", "telemetry") {
            d.obs.telemetry = b;
        }
        if let Some(t) = doc.get_str("obs", "trace") {
            d.obs.trace = Some(TraceConfig::parse(&t)?);
        }
        doc.read_f64("obs", "counter_period_s", &mut d.obs.counter_period_s);
        doc.read_f64("ga", "theta1", &mut d.ga.theta1);
        doc.read_f64("ga", "theta2", &mut d.ga.theta2);
        doc.read_f64("ga", "theta3", &mut d.ga.theta3);
        doc.read_usize("ga", "n_ini", &mut d.ga.n_ini);
        doc.read_usize("ga", "n_iter", &mut d.ga.n_iter);
        doc.read_usize("ga", "n_k", &mut d.ga.n_k);
        doc.read_usize("ga", "n_summ", &mut d.ga.n_summ);
        doc.read_f64("ga", "epsilon", &mut d.ga.epsilon);
        doc.read_f64("satellite", "capacity_mflops", &mut d.satellite.capacity_mflops);
        doc.read_f64(
            "satellite",
            "max_workload_mflops",
            &mut d.satellite.max_workload_mflops,
        );
        doc.read_f64("comm", "isl_bandwidth_hz", &mut d.comm.isl_bandwidth_hz);
        doc.read_f64("comm", "sat_tx_power_dbw", &mut d.comm.sat_tx_power_dbw);
        doc.read_f64("comm", "gw_bandwidth_hz", &mut d.comm.gw_bandwidth_hz);
        doc.read_f64("comm", "gw_tx_power_dbw", &mut d.comm.gw_tx_power_dbw);
        doc.read_f64("comm", "antenna_gain_dbi", &mut d.comm.antenna_gain_dbi);
        doc.read_f64("comm", "pointing_coeff", &mut d.comm.pointing_coeff);
        doc.read_f64("comm", "noise_temp_k", &mut d.comm.noise_temp_k);
        doc.read_f64("comm", "gw_noise_dbw", &mut d.comm.gw_noise_dbw);
        doc.read_f64("comm", "isl_latency_ms", &mut d.comm.isl_latency_ms);
        // parsed after [comm]: a bare `gossip` derives its tick from the
        // per-hop ISL latency knob instead of a hard-coded constant
        if let Some(s) = doc.get_str("", "dissemination") {
            d.dissemination = Some(DisseminationKind::parse_with(
                &s,
                d.comm.isl_latency_ms * 1e-3,
            )?);
            d.gossip_tick_derived =
                matches!(d.dissemination, Some(DisseminationKind::Gossip { .. }))
                    && !s.contains(':');
        }
        // [llm] is read before `task_kind` so a bare `autoregressive`
        // selector picks up the block's values (the isl_latency_ms /
        // dissemination ordering precedent)
        if let Some(r) = doc.get_i64("llm", "rounds") {
            d.llm.rounds = r as u32;
        }
        doc.read_f64("llm", "decode_flops", &mut d.llm.decode_flops);
        doc.read_f64("llm", "state_bytes", &mut d.llm.state_bytes);
        if let Some(e) = doc.get_f64("llm", "escalate") {
            d.llm.escalate = Some(e);
        }
        doc.read_f64("llm", "round_deadline_s", &mut d.llm.round_deadline_s);
        doc.read_f64("llm", "small_model_factor", &mut d.llm.small_model_factor);
        if let Some(s) = doc.get_str("", "task_kind") {
            d.task_kind = Some(TaskKind::parse_with(&s, &d.llm)?);
        }
        doc.read_f64("resilience", "p_fail", &mut d.resilience.p_fail);
        doc.read_f64("resilience", "p_recover", &mut d.resilience.p_recover);
        doc.read_f64("resilience", "link_p_fail", &mut d.resilience.link_p_fail);
        doc.read_f64(
            "resilience",
            "link_p_recover",
            &mut d.resilience.link_p_recover,
        );
        if let Some(b) = doc.get_bool("resilience", "seam_only") {
            d.resilience.seam_only = b;
        }
        if let Some(s) = doc.get_str("resilience", "recovery") {
            d.resilience.recovery = RecoveryPolicy::parse(&s)?;
        }
        if let Some(p) = doc.get_str("resilience", "fault_trace") {
            d.resilience.fault_trace = Some(FaultTrace::from_file(&p)?);
            d.resilience.fault_trace_path = Some(p);
        }
        doc.read_f64(
            "resilience",
            "link_timeout_s",
            &mut d.resilience.link_timeout_s,
        );
        doc.read_f64("resilience", "deadline_s", &mut d.resilience.deadline_s);
        Ok(cfg)
    }

    /// Apply `--key value` CLI overrides (subset: the sweep-relevant knobs).
    pub fn apply_args(&mut self, args: &Args) -> Result<(), String> {
        if let Some(n) = args.get_parsed::<usize>("n")? {
            self.n = n;
        }
        if let Some(s) = args.get_parsed::<usize>("slots")? {
            self.slots = s;
        }
        if let Some(l) = args.get_parsed::<f64>("lambda")? {
            self.lambda = l;
        }
        if let Some(m) = args.get("model") {
            self.model = DnnModel::parse(m)?;
        }
        if let Some(l) = args.get_parsed::<usize>("split-l")? {
            self.split_l = Some(l);
        }
        if let Some(d) = args.get_parsed::<usize>("d-max")? {
            self.d_max = Some(d);
        }
        if let Some(s) = args.get_parsed::<u64>("seed")? {
            self.seed = s;
        }
        if let Some(f) = args.get_parsed::<f64>("decision-fraction")? {
            self.decision_fraction = f;
        }
        if let Some(x) = args.get_parsed::<f64>("capacity")? {
            self.satellite.capacity_mflops = x;
        }
        if let Some(x) = args.get_parsed::<f64>("max-workload")? {
            self.satellite.max_workload_mflops = x;
        }
        if let Some(x) = args.get_parsed::<usize>("ga-iters")? {
            self.ga.n_iter = x;
        }
        if let Some(e) = args.get("engine") {
            self.engine = EngineKind::parse(e)?;
        }
        if let Some(s) = args.get("scenario") {
            self.scenario = ScenarioKind::parse(s)?;
        }
        if let Some(t) = args.get("topology") {
            self.topology = Some(TopologyKind::parse(t)?);
        }
        // applied before --dissemination: a bare `gossip` derives its
        // tick from this per-hop ISL latency knob
        if let Some(x) = args.get_parsed::<f64>("isl-latency-ms")? {
            self.comm.isl_latency_ms = x;
            // a derived (bare-`gossip`) tick keeps tracking the knob; an
            // explicit `gossip:<tick>` stays pinned
            if self.gossip_tick_derived && args.get("dissemination").is_none() {
                self.dissemination = Some(DisseminationKind::Gossip { tick_s: x * 1e-3 });
            }
        }
        if let Some(s) = args.get("dissemination") {
            self.dissemination = Some(DisseminationKind::parse_with(
                s,
                self.comm.isl_latency_ms * 1e-3,
            )?);
            self.gossip_tick_derived =
                matches!(self.dissemination, Some(DisseminationKind::Gossip { .. }))
                    && !s.contains(':');
        }
        if let Some(k) = args.get_parsed::<usize>("shards")? {
            self.shards = k;
        }
        if let Some(k) = args.get_parsed::<usize>("decide-threads")? {
            self.decide_threads = k;
        }
        if args.has_flag("decision-cache") {
            self.decision_cache = true;
        }
        // unstated selector parameters fall back to the [llm] block
        // (already applied from TOML at this point)
        if let Some(s) = args.get("task-kind") {
            self.task_kind = Some(TaskKind::parse_with(s, &self.llm)?);
        }
        if args.has_flag("retain-outcomes") {
            self.retain_outcomes = true;
        }
        if args.has_flag("telemetry") {
            self.obs.telemetry = true;
        }
        if let Some(spec) = args.get("trace") {
            self.obs.trace = Some(TraceConfig::parse(spec)?);
        } else if args.has_flag("trace") {
            return Err("--trace requires a path: --trace <path>[:<max-events>]".into());
        }
        if let Some(x) = args.get_parsed::<f64>("counter-period")? {
            self.obs.counter_period_s = x;
        }
        if let Some(p) = args.get_parsed::<f64>("p-fail")? {
            self.resilience.p_fail = p;
        }
        if let Some(p) = args.get_parsed::<f64>("p-recover")? {
            self.resilience.p_recover = p;
        }
        if let Some(p) = args.get_parsed::<f64>("link-p-fail")? {
            self.resilience.link_p_fail = p;
        }
        if let Some(p) = args.get_parsed::<f64>("link-p-recover")? {
            self.resilience.link_p_recover = p;
        }
        if args.has_flag("seam-outage") {
            self.resilience.seam_only = true;
        }
        if let Some(s) = args.get("recovery") {
            self.resilience.recovery = RecoveryPolicy::parse(s)?;
        } else if args.has_flag("recovery") {
            return Err(
                "--recovery requires a policy: --recovery drop|reoffload[:<max_retries>]".into(),
            );
        }
        if let Some(p) = args.get("fault-trace") {
            self.resilience.fault_trace = Some(FaultTrace::from_file(p)?);
            self.resilience.fault_trace_path = Some(p.to_string());
        } else if args.has_flag("fault-trace") {
            return Err("--fault-trace requires a path: --fault-trace <file>".into());
        }
        if let Some(x) = args.get_parsed::<f64>("link-timeout")? {
            self.resilience.link_timeout_s = x;
        }
        if let Some(x) = args.get_parsed::<f64>("recovery-deadline")? {
            self.resilience.deadline_s = x;
        }
        Ok(())
    }

    /// Render the effective configuration as a Table-I-style listing.
    /// The telemetry line appears only when observability is enabled, so
    /// default runs print byte-identically to pre-telemetry builds.
    pub fn table(&self) -> String {
        let mut t = format!(
            "Network topology                       {} ({} sats)\n\
             Satellite bandwidth B                  {:.0} MHz\n\
             Satellite computation capability C_x   {:.0} MFLOP/slot\n\
             Satellite transmission power P_t       {:.0} dBW\n\
             Gateway bandwidth B0                   {:.0} MHz\n\
             Generated task incidence lambda        {}\n\
             Task splitting number L                {}\n\
             Maximum communication distance D_M     {}\n\
             theta1, theta2, theta3                 {}, {}, {:.0e}\n\
             N_ini, N_iter, N_K, N_summ, epsilon    {}, {}, {}, {}, {}\n\
             Model                                  {}\n\
             Engine, scenario                       {}, {}\n\
             State dissemination                    {}\n\
             Slots, seed                            {}, {}",
            self.effective_topology().label(),
            self.effective_topology().n_sats(),
            self.comm.isl_bandwidth_hz / 1e6,
            self.satellite.capacity_mflops,
            self.comm.sat_tx_power_dbw,
            self.comm.gw_bandwidth_hz / 1e6,
            self.lambda,
            self.effective_l(),
            self.effective_d_max(),
            self.ga.theta1,
            self.ga.theta2,
            self.ga.theta3,
            self.ga.n_ini,
            self.ga.n_iter,
            self.ga.n_k,
            self.ga.n_summ,
            self.ga.epsilon,
            self.model.name(),
            self.engine.name(),
            self.scenario.name(),
            self.effective_dissemination().label(),
            self.slots,
            self.seed,
        );
        if self.shards != 1 {
            use std::fmt::Write as _;
            let _ = match self.shards {
                0 => write!(t, "\nEvent queue shards                     auto (one per plane)"),
                k => write!(t, "\nEvent queue shards                     {k}"),
            };
        }
        if self.decide_threads != 1 {
            use std::fmt::Write as _;
            let _ = match self.decide_threads {
                0 => write!(t, "\nDecide eval lanes                      auto (one per core)"),
                k => write!(t, "\nDecide eval lanes                      {k}"),
            };
        }
        if self.decision_cache {
            use std::fmt::Write as _;
            let _ = write!(t, "\nDecision cache                         epoch-keyed (on)");
        }
        // printed only for a non-default kind, so default runs keep the
        // classic table byte-for-byte
        let kind = self.effective_task_kind();
        if kind != TaskKind::OneShot {
            use std::fmt::Write as _;
            let _ = write!(
                t,
                "\nTask kind                              {} (round deadline {} s)",
                kind.label(),
                self.llm.round_deadline_s
            );
        }
        // printed only when some fault knob is non-default, so default
        // runs keep the classic table byte-for-byte
        let r = &self.resilience;
        if r.sat_faults_active() || r.link_faults_active() || !r.recovery.is_drop() {
            use std::fmt::Write as _;
            let _ = write!(
                t,
                "\nFault injection                        sat p={}/{} link p={}/{}{}",
                r.p_fail,
                r.p_recover,
                r.link_p_fail,
                r.link_p_recover,
                if r.seam_only { " (seam only)" } else { "" }
            );
            if let Some(path) = &r.fault_trace_path {
                let _ = write!(t, ", trace {path}");
            }
            let _ = write!(
                t,
                "\nRecovery policy                        {}",
                r.recovery.label()
            );
        }
        if self.obs.enabled() {
            use std::fmt::Write as _;
            let _ = write!(
                t,
                "\nTelemetry                              counters @ {} s",
                self.obs.counter_period_s
            );
            if let Some(tr) = &self.obs.trace {
                let _ = write!(t, ", trace -> {} (cap {})", tr.path, tr.max_events);
            }
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table1() {
        let c = SimConfig::default();
        assert_eq!(c.n, 10);
        assert_eq!(c.ga.theta1, 1.0);
        assert_eq!(c.ga.theta2, 20.0);
        assert_eq!(c.ga.theta3, 1e6);
        assert_eq!(c.ga.n_ini, 20);
        assert_eq!(c.ga.n_iter, 10);
        assert_eq!(c.ga.n_k, 20);
        assert_eq!(c.ga.n_summ, 10);
        assert_eq!(c.ga.epsilon, 1.0);
        assert_eq!(c.comm.isl_bandwidth_hz, 20e6);
        assert_eq!(c.comm.gw_bandwidth_hz, 10e6);
        assert_eq!(c.comm.sat_tx_power_dbw, 30.0);
        assert_eq!(c.satellite.capacity_mflops, 48_000.0);
    }

    #[test]
    fn per_model_l_and_dmax() {
        let mut c = SimConfig::default();
        c.model = DnnModel::Vgg19;
        assert_eq!((c.effective_l(), c.effective_d_max()), (3, 2));
        c.model = DnnModel::Resnet101;
        assert_eq!((c.effective_l(), c.effective_d_max()), (4, 3));
        c.split_l = Some(7);
        assert_eq!(c.effective_l(), 7);
    }

    #[test]
    fn toml_roundtrip() {
        let text = r#"
n = 16
lambda = 40.5
model = "resnet101"
seed = 9

[ga]
n_iter = 25
theta2 = 30.0

[satellite]
capacity_mflops = 6000.0
"#;
        let c = SimConfig::from_toml(text).unwrap();
        assert_eq!(c.n, 16);
        assert_eq!(c.lambda, 40.5);
        assert_eq!(c.model, DnnModel::Resnet101);
        assert_eq!(c.seed, 9);
        assert_eq!(c.ga.n_iter, 25);
        assert_eq!(c.ga.theta2, 30.0);
        assert_eq!(c.satellite.capacity_mflops, 6000.0);
        // untouched keys keep defaults
        assert_eq!(c.ga.n_k, 20);
    }

    #[test]
    fn cli_overrides() {
        let args = crate::util::cli::Args::parse(
            "x --n 8 --lambda 55 --model vgg19 --seed 3 --ga-iters 4"
                .split_whitespace()
                .map(String::from),
        );
        let mut c = SimConfig::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.n, 8);
        assert_eq!(c.lambda, 55.0);
        assert_eq!(c.ga.n_iter, 4);
    }

    #[test]
    fn engine_and_scenario_parse_roundtrip() {
        assert_eq!(EngineKind::parse("event").unwrap(), EngineKind::Event);
        assert_eq!(EngineKind::parse("SLOTTED").unwrap(), EngineKind::Slotted);
        assert!(EngineKind::parse("warp").is_err());
        for k in ScenarioKind::all() {
            assert_eq!(ScenarioKind::parse(k.name()).unwrap(), k);
        }
        assert!(ScenarioKind::parse("solar-storm").is_err());

        let text = "engine = \"event\"\nscenario = \"hotspot\"\nretain_outcomes = true\n";
        let c = SimConfig::from_toml(text).unwrap();
        assert_eq!(c.engine, EngineKind::Event);
        assert_eq!(c.scenario, ScenarioKind::Hotspot);
        assert!(c.retain_outcomes);
        assert!(!SimConfig::default().retain_outcomes);

        let args = crate::util::cli::Args::parse(
            "x --engine event --scenario bursty --retain-outcomes"
                .split_whitespace()
                .map(String::from),
        );
        let mut d = SimConfig::default();
        d.apply_args(&args).unwrap();
        assert_eq!(d.engine, EngineKind::Event);
        assert_eq!(d.scenario, ScenarioKind::Bursty);
        assert!(d.retain_outcomes);
    }

    #[test]
    fn dissemination_parse_defaults_and_overrides() {
        // unset: each engine keeps its legacy observability model
        let mut c = SimConfig::default();
        assert_eq!(
            c.effective_dissemination_for(EngineKind::Event),
            DisseminationKind::Instant
        );
        assert_eq!(
            c.effective_dissemination_for(EngineKind::Slotted),
            DisseminationKind::Periodic { period_s: 1.0 }
        );
        // explicit setting wins for both engines
        c.dissemination = Some(DisseminationKind::Periodic { period_s: 2.5 });
        for e in EngineKind::all() {
            assert_eq!(
                c.effective_dissemination_for(e),
                DisseminationKind::Periodic { period_s: 2.5 }
            );
        }
        // the slotted clock quantizes sub-slot intervals up to one slot;
        // the event engine honours them as configured
        c.dissemination = Some(DisseminationKind::Periodic { period_s: 0.25 });
        assert_eq!(
            c.effective_dissemination_for(EngineKind::Slotted),
            DisseminationKind::Periodic { period_s: 1.0 }
        );
        assert_eq!(
            c.effective_dissemination_for(EngineKind::Event),
            DisseminationKind::Periodic { period_s: 0.25 }
        );
        c.dissemination = Some(DisseminationKind::Gossip { tick_s: 0.25 });
        assert_eq!(
            c.effective_dissemination_for(EngineKind::Slotted),
            DisseminationKind::Gossip { tick_s: 1.0 }
        );

        let text = "dissemination = \"gossip:0.25\"\n";
        let t = SimConfig::from_toml(text).unwrap();
        assert_eq!(
            t.dissemination,
            Some(DisseminationKind::Gossip { tick_s: 0.25 })
        );
        assert!(SimConfig::from_toml("dissemination = \"warp\"\n").is_err());

        let args = crate::util::cli::Args::parse(
            "x --dissemination periodic:0.5".split_whitespace().map(String::from),
        );
        let mut d = SimConfig::default();
        d.apply_args(&args).unwrap();
        assert_eq!(
            d.dissemination,
            Some(DisseminationKind::Periodic { period_s: 0.5 })
        );
        assert!(d.validate().is_ok());
        d.dissemination = Some(DisseminationKind::Periodic { period_s: 0.0 });
        assert!(d.validate().is_err());
    }

    #[test]
    fn validation_rejects_bad() {
        let mut c = SimConfig::default();
        c.n = 1;
        c.lambda = -1.0;
        let errs = c.validate().unwrap_err();
        assert_eq!(errs.len(), 2);
    }

    #[test]
    fn topology_defaults_parses_and_validates() {
        // unset: the paper torus from n
        let c = SimConfig::default();
        assert_eq!(c.effective_topology(), TopologyKind::Torus { n: 10 });
        assert_eq!(c.build_topology().len(), 100);

        let t = SimConfig::from_toml("topology = \"walker-star:5x8\"\n").unwrap();
        assert_eq!(
            t.effective_topology(),
            TopologyKind::WalkerStar {
                planes: 5,
                sats_per_plane: 8
            }
        );
        assert_eq!(t.effective_topology().n_sats(), 40);
        assert!(SimConfig::from_toml("topology = \"moebius:3\"\n").is_err());

        let args = crate::util::cli::Args::parse(
            "x --topology walker-delta:4x6:1".split_whitespace().map(String::from),
        );
        let mut d = SimConfig::default();
        d.apply_args(&args).unwrap();
        assert_eq!(
            d.topology,
            Some(TopologyKind::WalkerDelta {
                planes: 4,
                sats_per_plane: 6,
                phasing: 1
            })
        );
        assert!(d.validate().is_ok());
        assert!(d.table().contains("walker-delta:4x6:1"));
        // n stays valid independently; topology wins for the build
        assert_eq!(d.build_topology().len(), 24);
    }

    #[test]
    fn isl_latency_knob_drives_bare_gossip_tick() {
        // bare gossip: tick = isl_latency_ms / 1000 (default 25 ms)
        let args = crate::util::cli::Args::parse(
            "x --dissemination gossip".split_whitespace().map(String::from),
        );
        let mut c = SimConfig::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.dissemination, Some(DisseminationKind::Gossip { tick_s: 0.025 }));

        // the knob applies before --dissemination regardless of CLI order
        let args = crate::util::cli::Args::parse(
            "x --dissemination gossip --isl-latency-ms 40"
                .split_whitespace()
                .map(String::from),
        );
        let mut c = SimConfig::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.dissemination, Some(DisseminationKind::Gossip { tick_s: 0.04 }));

        // an explicit tick wins over the knob
        let args = crate::util::cli::Args::parse(
            "x --isl-latency-ms 40 --dissemination gossip:0.5"
                .split_whitespace()
                .map(String::from),
        );
        let mut c = SimConfig::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.dissemination, Some(DisseminationKind::Gossip { tick_s: 0.5 }));

        // TOML: [comm] isl_latency_ms feeds a bare gossip too
        let t = SimConfig::from_toml(
            "dissemination = \"gossip\"\n\n[comm]\nisl_latency_ms = 50.0\n",
        )
        .unwrap();
        assert_eq!(t.dissemination, Some(DisseminationKind::Gossip { tick_s: 0.05 }));

        // periodic / instant are untouched by the knob
        let t = SimConfig::from_toml(
            "dissemination = \"periodic:2\"\n\n[comm]\nisl_latency_ms = 50.0\n",
        )
        .unwrap();
        assert_eq!(
            t.dissemination,
            Some(DisseminationKind::Periodic { period_s: 2.0 })
        );

        let mut bad = SimConfig::default();
        bad.comm.isl_latency_ms = 0.0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn cli_knob_reticks_toml_bare_gossip() {
        let knob_only = crate::util::cli::Args::parse(
            "x --isl-latency-ms 40".split_whitespace().map(String::from),
        );
        // TOML bare gossip froze its tick at the TOML-time knob (25 ms);
        // a CLI --isl-latency-ms alone must re-derive it
        let mut c = SimConfig::from_toml("dissemination = \"gossip\"\n").unwrap();
        assert_eq!(c.dissemination, Some(DisseminationKind::Gossip { tick_s: 0.025 }));
        c.apply_args(&knob_only).unwrap();
        assert_eq!(c.dissemination, Some(DisseminationKind::Gossip { tick_s: 0.04 }));

        // an explicit TOML tick is preserved
        let mut c = SimConfig::from_toml("dissemination = \"gossip:0.5\"\n").unwrap();
        assert!(!c.gossip_tick_derived);
        c.apply_args(&knob_only).unwrap();
        assert_eq!(c.dissemination, Some(DisseminationKind::Gossip { tick_s: 0.5 }));

        // ...even when the pinned tick happens to equal the derived value
        let mut c = SimConfig::from_toml("dissemination = \"gossip:0.025\"\n").unwrap();
        c.apply_args(&knob_only).unwrap();
        assert_eq!(c.dissemination, Some(DisseminationKind::Gossip { tick_s: 0.025 }));

        // periodic stays untouched by the knob
        let mut c = SimConfig::from_toml("dissemination = \"periodic:2\"\n").unwrap();
        c.apply_args(&knob_only).unwrap();
        assert_eq!(
            c.dissemination,
            Some(DisseminationKind::Periodic { period_s: 2.0 })
        );
    }

    #[test]
    fn shards_knob_parses_and_defaults() {
        let c = SimConfig::default();
        assert_eq!(c.shards, 1);
        assert!(!c.table().contains("Event queue shards"));

        let t = SimConfig::from_toml("shards = 8\n").unwrap();
        assert_eq!(t.shards, 8);
        assert!(t.validate().is_ok());
        assert!(t.table().contains("Event queue shards"));

        let args = crate::util::cli::Args::parse(
            "x --shards 0".split_whitespace().map(String::from),
        );
        let mut d = SimConfig::default();
        d.apply_args(&args).unwrap();
        assert_eq!(d.shards, 0);
        assert!(d.validate().is_ok());
        assert!(d.table().contains("auto (one per plane)"));
    }

    #[test]
    fn decide_knobs_parse_and_default() {
        let c = SimConfig::default();
        assert_eq!(c.decide_threads, 1);
        assert!(!c.decision_cache);
        assert!(!c.table().contains("Decide eval lanes"));
        assert!(!c.table().contains("Decision cache"));

        let t = SimConfig::from_toml("decide_threads = 4\ndecision_cache = true\n").unwrap();
        assert_eq!(t.decide_threads, 4);
        assert!(t.decision_cache);
        assert!(t.validate().is_ok());
        assert!(t.table().contains("Decide eval lanes"));
        assert!(t.table().contains("Decision cache"));

        let args = crate::util::cli::Args::parse(
            "x --decide-threads 0 --decision-cache".split_whitespace().map(String::from),
        );
        let mut d = SimConfig::default();
        d.apply_args(&args).unwrap();
        assert_eq!(d.decide_threads, 0);
        assert!(d.decision_cache);
        assert!(d.validate().is_ok());
        assert!(d.table().contains("auto (one per core)"));
    }

    #[test]
    fn table_contains_key_params() {
        let t = SimConfig::default().table();
        assert!(t.contains("N_ini"));
        assert!(t.contains("20 MHz"));
    }

    #[test]
    fn task_kind_knob_parses_and_defaults() {
        let c = SimConfig::default();
        assert_eq!(c.effective_task_kind(), TaskKind::OneShot);
        assert!(!c.table().contains("Task kind"));

        // TOML: [llm] feeds a bare `autoregressive` selector
        let t = SimConfig::from_toml(
            "task_kind = \"autoregressive\"\n\n[llm]\nrounds = 5\ndecode_flops = 123.0\nescalate = 0.1\n",
        )
        .unwrap();
        assert_eq!(
            t.effective_task_kind(),
            TaskKind::Autoregressive {
                rounds: 5,
                decode_flops: 123.0,
                state_bytes: 262_144.0,
                escalate: Some(0.1),
            }
        );
        assert!(t.validate().is_ok());
        assert!(t.table().contains("Task kind"));
        assert!(SimConfig::from_toml("task_kind = \"warp\"\n").is_err());

        // CLI: explicit selector parameters win over the block
        let args = crate::util::cli::Args::parse(
            "x --task-kind autoregressive:3:50:1024".split_whitespace().map(String::from),
        );
        let mut d = SimConfig::default();
        d.apply_args(&args).unwrap();
        assert_eq!(
            d.task_kind,
            Some(TaskKind::Autoregressive {
                rounds: 3,
                decode_flops: 50.0,
                state_bytes: 1024.0,
                escalate: None,
            })
        );
        assert!(d.validate().is_ok());

        // explicit oneshot stays the default behaviour and prints nothing
        let args = crate::util::cli::Args::parse(
            "x --task-kind oneshot".split_whitespace().map(String::from),
        );
        let mut d = SimConfig::default();
        d.apply_args(&args).unwrap();
        assert_eq!(d.task_kind, Some(TaskKind::OneShot));
        assert_eq!(d.table(), SimConfig::default().table());

        // malformed selector is an error, not a panic
        let args = crate::util::cli::Args::parse(
            "x --task-kind autoregressive:x".split_whitespace().map(String::from),
        );
        let mut d = SimConfig::default();
        assert!(d.apply_args(&args).is_err());

        // validation catches bad [llm] execution knobs
        let mut bad = SimConfig::default();
        bad.llm.round_deadline_s = 0.0;
        assert!(bad.validate().is_err());
        let mut bad = SimConfig::default();
        bad.llm.small_model_factor = 1.5;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn resilience_knobs_parse_and_default_off() {
        let c = SimConfig::default();
        assert!(!c.resilience.sat_faults_active());
        assert!(!c.resilience.link_faults_active());
        assert!(c.resilience.recovery.is_drop());
        assert!(!c.table().contains("Fault injection"));
        assert!(!c.table().contains("Recovery policy"));

        // TOML [resilience] block
        let t = SimConfig::from_toml(
            "[resilience]\np_fail = 0.05\nrecovery = \"reoffload:3\"\nlink_p_fail = 0.02\nseam_only = true\n",
        )
        .unwrap();
        assert_eq!(t.resilience.p_fail, 0.05);
        assert_eq!(t.resilience.link_p_fail, 0.02);
        assert!(t.resilience.seam_only);
        assert_eq!(
            t.resilience.recovery,
            RecoveryPolicy::Reoffload { max_retries: 3 }
        );
        assert!(t.validate().is_ok());
        assert!(t.table().contains("Fault injection"));
        assert!(t.table().contains("reoffload:3"));
        assert!(SimConfig::from_toml("[resilience]\nrecovery = \"warp\"\n").is_err());

        // CLI knobs
        let args = crate::util::cli::Args::parse(
            "x --p-fail 0.1 --p-recover 0.4 --link-p-fail 0.05 --seam-outage --recovery reoffload"
                .split_whitespace()
                .map(String::from),
        );
        let mut d = SimConfig::default();
        d.apply_args(&args).unwrap();
        assert_eq!(d.resilience.p_fail, 0.1);
        assert_eq!(d.resilience.p_recover, 0.4);
        assert_eq!(d.resilience.link_p_fail, 0.05);
        assert!(d.resilience.seam_only);
        assert_eq!(
            d.resilience.recovery,
            RecoveryPolicy::Reoffload {
                max_retries: crate::resilience::DEFAULT_MAX_RETRIES
            }
        );
        assert!(d.validate().is_ok());

        // explicit drop keeps the default table byte-for-byte
        let args = crate::util::cli::Args::parse(
            "x --recovery drop".split_whitespace().map(String::from),
        );
        let mut d = SimConfig::default();
        d.apply_args(&args).unwrap();
        assert_eq!(d.table(), SimConfig::default().table());

        // out-of-range probabilities are validation errors, not panics
        for (k, v) in [
            ("p_fail", 1.5),
            ("p_fail", -0.1),
            ("p_recover", f64::NAN),
            ("link_p_fail", 2.0),
            ("link_p_recover", -1.0),
        ] {
            let mut bad = SimConfig::default();
            match k {
                "p_fail" => bad.resilience.p_fail = v,
                "p_recover" => bad.resilience.p_recover = v,
                "link_p_fail" => bad.resilience.link_p_fail = v,
                _ => bad.resilience.link_p_recover = v,
            }
            assert!(bad.validate().is_err(), "{k}={v} should fail validation");
        }
        let mut bad = SimConfig::default();
        bad.resilience.link_timeout_s = 0.0;
        assert!(bad.validate().is_err());
        let mut bad = SimConfig::default();
        bad.resilience.deadline_s = -1.0;
        assert!(bad.validate().is_err());

        // a trace referencing sats outside the topology is caught
        let mut bad = SimConfig::default();
        bad.n = 2; // 4 sats
        bad.resilience.fault_trace =
            Some(FaultTrace::parse_str("0 5 sat:9\n").unwrap());
        assert!(bad.validate().is_err());

        // missing trace file errors at the CLI boundary
        let args = crate::util::cli::Args::parse(
            "x --fault-trace /nonexistent/trace.txt"
                .split_whitespace()
                .map(String::from),
        );
        let mut d = SimConfig::default();
        assert!(d.apply_args(&args).is_err());
    }

    #[test]
    fn obs_defaults_off_and_knobs_parse() {
        let c = SimConfig::default();
        assert!(!c.obs.enabled());
        assert!(!c.table().contains("Telemetry"));

        // TOML [obs] section
        let t = SimConfig::from_toml(
            "[obs]\ntelemetry = true\ntrace = \"t.json:500\"\ncounter_period_s = 0.25\n",
        )
        .unwrap();
        assert!(t.obs.telemetry);
        assert_eq!(t.obs.trace.as_ref().unwrap().path, "t.json");
        assert_eq!(t.obs.trace.as_ref().unwrap().max_events, 500);
        assert_eq!(t.obs.counter_period_s, 0.25);
        assert!(t.validate().is_ok());

        // CLI: --trace enables, --telemetry alone enables counters only
        let args = crate::util::cli::Args::parse(
            "x --trace out.json --counter-period 2".split_whitespace().map(String::from),
        );
        let mut d = SimConfig::default();
        d.apply_args(&args).unwrap();
        assert!(d.obs.enabled());
        assert!(!d.obs.telemetry);
        assert_eq!(d.obs.trace.as_ref().unwrap().path, "out.json");
        assert_eq!(d.obs.counter_period_s, 2.0);
        assert!(d.table().contains("Telemetry"));
        assert!(d.table().contains("out.json"));

        let args = crate::util::cli::Args::parse(
            "x --telemetry".split_whitespace().map(String::from),
        );
        let mut d = SimConfig::default();
        d.apply_args(&args).unwrap();
        assert!(d.obs.enabled());
        assert!(d.obs.trace.is_none());

        // a bare --trace with no path is a clear error, not a silent flag
        let args =
            crate::util::cli::Args::parse("x --trace".split_whitespace().map(String::from));
        let mut d = SimConfig::default();
        assert!(d.apply_args(&args).is_err());

        // validation catches a bad cadence
        let mut bad = SimConfig::default();
        bad.obs.telemetry = true;
        bad.obs.counter_period_s = -1.0;
        assert!(bad.validate().is_err());
    }
}
