//! Terminal plotting substrate: renders the figure panels as ASCII line
//! charts so `satkit experiment`/`cargo bench` output is readable without
//! an external plotting stack (the offline image has none).

/// One named series: (x, y) points, x ascending.
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

/// Render series as an ASCII chart of `width` × `height` characters
/// (plus axes). Each series draws with its own glyph; overlaps show the
/// later series.
pub fn ascii_chart(title: &str, series: &[Series], width: usize, height: usize) -> String {
    const GLYPHS: [char; 6] = ['*', 'o', '+', 'x', '#', '@'];
    if series.is_empty() || series.iter().all(|s| s.points.is_empty()) {
        return format!("{title}\n(no data)\n");
    }
    let (mut x_min, mut x_max) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y_min, mut y_max) = (f64::INFINITY, f64::NEG_INFINITY);
    for s in series {
        for &(x, y) in &s.points {
            x_min = x_min.min(x);
            x_max = x_max.max(x);
            y_min = y_min.min(y);
            y_max = y_max.max(y);
        }
    }
    if (x_max - x_min).abs() < 1e-12 {
        x_max = x_min + 1.0;
    }
    if (y_max - y_min).abs() < 1e-12 {
        y_max = y_min + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let glyph = GLYPHS[si % GLYPHS.len()];
        // draw line-interpolated points
        for w in s.points.windows(2) {
            let steps = width * 2;
            for t in 0..=steps {
                let f = t as f64 / steps as f64;
                let x = w[0].0 + f * (w[1].0 - w[0].0);
                let y = w[0].1 + f * (w[1].1 - w[0].1);
                let col = (((x - x_min) / (x_max - x_min)) * (width - 1) as f64).round()
                    as usize;
                let row = (((y - y_min) / (y_max - y_min)) * (height - 1) as f64).round()
                    as usize;
                grid[height - 1 - row][col.min(width - 1)] = glyph;
            }
        }
        if s.points.len() == 1 {
            let (x, y) = s.points[0];
            let col =
                (((x - x_min) / (x_max - x_min)) * (width - 1) as f64).round() as usize;
            let row =
                (((y - y_min) / (y_max - y_min)) * (height - 1) as f64).round() as usize;
            grid[height - 1 - row][col.min(width - 1)] = glyph;
        }
    }
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    for (i, row) in grid.iter().enumerate() {
        let y_label = if i == 0 {
            format!("{y_max:>10.3e} |")
        } else if i == height - 1 {
            format!("{y_min:>10.3e} |")
        } else {
            format!("{:>10} |", "")
        };
        out.push_str(&y_label);
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!(
        "{:>10} +{}\n{:>12}{:<w$.0}{:>.0}\n",
        "",
        "-".repeat(width),
        "",
        x_min,
        x_max,
        w = width.saturating_sub(2)
    ));
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, s)| format!("{} {}", GLYPHS[i % GLYPHS.len()], s.name))
        .collect();
    out.push_str(&format!("{:>12}{}\n", "", legend.join("   ")));
    out
}

/// Build the per-scheme series of one metric from experiment rows.
pub fn series_from_rows<F: Fn(&crate::metrics::Report) -> f64>(
    rows: &[super::Row],
    metric: F,
) -> Vec<Series> {
    let mut out: Vec<Series> = Vec::new();
    for kind in crate::offload::SchemeKind::all() {
        let mut pts: Vec<(f64, f64)> = rows
            .iter()
            .filter(|r| r.scheme == kind)
            .map(|r| (r.x, metric(&r.report)))
            .collect();
        pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        if !pts.is_empty() {
            out.push(Series {
                name: kind.name().to_string(),
                points: pts,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_two_series() {
        let s = vec![
            Series {
                name: "up".into(),
                points: vec![(0.0, 0.0), (1.0, 1.0), (2.0, 4.0)],
            },
            Series {
                name: "down".into(),
                points: vec![(0.0, 4.0), (2.0, 0.0)],
            },
        ];
        let chart = ascii_chart("test", &s, 40, 10);
        assert!(chart.contains('*'));
        assert!(chart.contains('o'));
        assert!(chart.contains("up"));
        assert!(chart.contains("down"));
        assert!(chart.lines().count() >= 12);
    }

    #[test]
    fn empty_is_safe() {
        assert!(ascii_chart("t", &[], 10, 5).contains("no data"));
    }

    #[test]
    fn constant_series_no_panic() {
        let s = vec![Series {
            name: "flat".into(),
            points: vec![(1.0, 2.0), (2.0, 2.0)],
        }];
        let chart = ascii_chart("flat", &s, 20, 5);
        assert!(chart.contains('*'));
    }

    #[test]
    fn single_point_series() {
        let s = vec![Series {
            name: "dot".into(),
            points: vec![(1.0, 1.0)],
        }];
        assert!(ascii_chart("p", &s, 10, 5).contains('*'));
    }
}
