//! Experiment harness: regenerates every table and figure of §V.
//!
//! * [`fig2`] — ResNet101 λ-sweep: completion rate (a), total average
//!   delay (b), workload variance (c) for SCC/Random/RRP/DQN.
//! * [`fig3`] — the same three panels for VGG19.
//! * [`scale`] — completion rate vs network size N ∈ {4..32} at λ = 25.
//! * [`ablation_split`] — balanced (Alg. 1) vs naive equal-layer splitting.
//! * [`ablation_ga`] — GA solution quality vs iteration budget.
//! * [`staleness_sweep`] — completion rate & p95 delay vs the state
//!   dissemination interval `T_d` per scheme (the §V-B stale-state
//!   herding study); exported as `BENCH_staleness.json`.
//! * [`topology_sweep`] — completion rate & p95 delay per scheme per
//!   constellation topology (torus vs Walker-Delta vs Walker-Star at
//!   equal satellite count); exported as `BENCH_topology.json`.
//! * [`decidecache_sweep`] — the epoch-keyed GA decision cache
//!   (`--decision-cache`) on vs off per periodic `T_d`: completion/p95
//!   deltas plus hit rate and decides/s; exported as
//!   `BENCH_decidecache.json`.
//! * [`resilience_sweep`] — completion rate & p95 delay vs satellite
//!   fault rate, recovery off (`drop`) vs on (`reoffload:2`) per scheme;
//!   exported as `BENCH_resilience.json`.
//!
//! Every function returns structured rows and can render the paper-style
//! table; the benches in `rust/benches/` wrap these with timing.

pub mod plot;

use crate::config::{EngineKind, LlmConfig, ScenarioKind, SimConfig};
use crate::dnn::DnnModel;
use crate::metrics::{LlmReport, Report, ResilienceReport};
use crate::offload::SchemeKind;
use crate::resilience::RecoveryPolicy;
use crate::sim::{Simulation, SplitPolicy};
use crate::state::DisseminationKind;
use crate::tasks::TaskKind;
use crate::topology::TopologyKind;
use crate::util::json::Json;

/// One data point of a figure: a (x, scheme) cell.
#[derive(Clone, Debug)]
pub struct Row {
    /// Sweep coordinate (λ for Figs. 2–3, N for the scale study).
    pub x: f64,
    pub scheme: SchemeKind,
    pub report: Report,
}

/// Sweep settings; `quick` shrinks slots for CI-speed runs.
#[derive(Clone, Debug)]
pub struct SweepOpts {
    pub slots: usize,
    pub seed: u64,
    pub decision_fraction: f64,
    /// Independent repetitions averaged per point (seeds seed..seed+r).
    pub repeats: usize,
    /// Which engine runs the points (slotted = the paper's loop).
    pub engine: EngineKind,
    /// Traffic profile for the event engine.
    pub scenario: ScenarioKind,
    /// State-dissemination override (`None` = each engine's legacy
    /// model); [`staleness_sweep`] sets this per cell.
    pub dissemination: Option<DisseminationKind>,
    /// Constellation topology override (`None` = the paper torus);
    /// [`topology_sweep`] sets this per cell.
    pub topology: Option<TopologyKind>,
    /// Event-queue shard count (`SimConfig::shards`, `--shards`): pure
    /// mechanics, byte-identical rows at every setting.
    pub shards: usize,
    /// GA generation-evaluation lanes (`SimConfig::decide_threads`,
    /// `--decide-threads`): pure mechanics, byte-identical rows at every
    /// setting (`tests/prop_pool.rs`).
    pub decide_threads: usize,
    /// Epoch-keyed GA decision cache (`SimConfig::decision_cache`,
    /// `--decision-cache`): **not** byte-identical — default off.
    pub decision_cache: bool,
    /// Worker threads for [`run_cells`]: 0 = one per available core,
    /// 1 = force the sequential path (the parallel runner's oracle).
    pub threads: usize,
    /// Per-cell progress lines on **stderr** (`--progress` on the CLI):
    /// stdout (tables, JSON) is untouched, rows are unchanged.
    pub progress: bool,
}

impl Default for SweepOpts {
    fn default() -> Self {
        SweepOpts {
            slots: 20,
            seed: 42,
            decision_fraction: 0.05,
            repeats: 1,
            engine: EngineKind::Slotted,
            scenario: ScenarioKind::Poisson,
            dissemination: None,
            topology: None,
            shards: 1,
            decide_threads: 1,
            decision_cache: false,
            threads: 0,
            progress: false,
        }
    }
}

impl SweepOpts {
    pub fn quick() -> SweepOpts {
        SweepOpts {
            slots: 6,
            ..SweepOpts::default()
        }
    }
}

/// Per-cell sweep progress on stderr (`--progress`): one `start` and one
/// `done` line per cell, numbered against the sweep total. Sits beside
/// [`run_cells`] — workers share it by reference (atomic counters), stdout
/// (tables, JSON) is untouched, and rows are byte-identical either way.
pub struct Progress {
    enabled: bool,
    total: usize,
    started: std::sync::atomic::AtomicUsize,
    done: std::sync::atomic::AtomicUsize,
}

impl Progress {
    pub fn new(enabled: bool, total: usize) -> Progress {
        Progress {
            enabled,
            total,
            started: std::sync::atomic::AtomicUsize::new(0),
            done: std::sync::atomic::AtomicUsize::new(0),
        }
    }

    /// Run one cell under progress accounting. `label` is lazy so the
    /// disabled path is a single branch — no formatting, no allocation.
    pub fn cell<R>(&self, label: impl Fn() -> String, f: impl FnOnce() -> R) -> R {
        if !self.enabled {
            return f();
        }
        let label = label();
        let k = self.started.fetch_add(1, std::sync::atomic::Ordering::Relaxed) + 1;
        eprintln!("[{k}/{}] start {label}", self.total);
        let r = f();
        let d = self.done.fetch_add(1, std::sync::atomic::Ordering::Relaxed) + 1;
        eprintln!("[{d}/{}] done  {label}", self.total);
        r
    }
}

/// Fan independent sweep cells across cores on `std::thread::scope` (no
/// external dependencies): `f` runs once per item, workers pull cells
/// from a shared cursor, and results return **in input order** regardless
/// of which worker finished first. Every cell builds its own engine from
/// its own `SimConfig`, so cell results are independent of scheduling and
/// the assembled rows are byte-identical to a sequential run (enforced by
/// `tests/integration_experiments.rs::parallel_sweep_rows_match_sequential`).
///
/// `threads`: 0 = one worker per available core, 1 = run inline
/// (sequential oracle), n = exactly n workers (capped at the cell count).
pub fn run_cells<T, R, F>(threads: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    let workers = match threads {
        0 => std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1),
        t => t,
    }
    .min(n.max(1));
    if workers <= 1 {
        return items.into_iter().map(f).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let jobs: Vec<std::sync::Mutex<Option<T>>> = items
        .into_iter()
        .map(|t| std::sync::Mutex::new(Some(t)))
        .collect();
    let mut collected: Vec<(usize, R)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(|| {
                    let mut done: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let item = jobs[i]
                            .lock()
                            .expect("job mutex poisoned")
                            .take()
                            .expect("cell dispatched twice");
                        done.push((i, f(item)));
                    }
                    done
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("sweep worker panicked"))
            .collect()
    });
    collected.sort_by_key(|&(i, _)| i);
    collected.into_iter().map(|(_, r)| r).collect()
}

/// Fan `cells × repeats` across cores: every (cell, repeat) pair is an
/// independent engine run, so a few-cell/many-repeat sweep saturates the
/// machine even when the cell grid alone cannot. `f` receives the cell
/// and the repeat index; results come back grouped per cell **in input
/// order** with the repeats of each cell in repeat order — exactly the
/// sequence the sequential repeat loop produces, so downstream averaging
/// is byte-identical (enforced by `tests/integration_experiments.rs::
/// per_repeat_dispatch_rows_match_sequential`).
pub fn run_cells_repeated<T, R, F>(
    threads: usize,
    repeats: usize,
    items: Vec<T>,
    f: F,
) -> Vec<Vec<R>>
where
    T: Send + Sync + Clone,
    R: Send,
    F: Fn(&T, usize) -> R + Sync,
{
    let repeats = repeats.max(1);
    let pairs: Vec<(T, usize)> = items
        .into_iter()
        .flat_map(|t| (0..repeats).map(move |r| (t.clone(), r)))
        .collect();
    let flat = run_cells(threads, pairs, |(t, r)| f(&t, r));
    let mut out: Vec<Vec<R>> = Vec::with_capacity(flat.len() / repeats);
    let mut cur: Vec<R> = Vec::with_capacity(repeats);
    for r in flat {
        cur.push(r);
        if cur.len() == repeats {
            out.push(std::mem::replace(&mut cur, Vec::with_capacity(repeats)));
        }
    }
    debug_assert!(cur.is_empty());
    out
}

/// The repeat protocol every sweep shares, dispatched per (cell, repeat):
/// `run_one` gets the cell and the repeat's seed (`opts.seed + r·1000`),
/// each pair runs as its own parallel unit, and the repeats of each cell
/// average into one report — byte-identical to the sequential
/// [`repeat_mean`] loop because grouping preserves repeat order.
fn repeat_mean_cells<T>(
    opts: &SweepOpts,
    cells: Vec<T>,
    label: impl Fn(&T) -> String + Sync,
    run_one: impl Fn(&T, u64) -> Report + Sync,
) -> Vec<Report>
where
    T: Send + Sync + Clone,
{
    let repeats = opts.repeats.max(1);
    let progress = Progress::new(opts.progress, cells.len() * repeats);
    let grouped = run_cells_repeated(opts.threads, repeats, cells, |cell, r| {
        progress.cell(
            || {
                if repeats == 1 {
                    label(cell)
                } else {
                    format!("{} repeat={}/{repeats}", label(cell), r + 1)
                }
            },
            || run_one(cell, opts.seed + r as u64 * 1000),
        )
    });
    grouped.into_iter().map(mean_reports).collect()
}

fn base_cfg(model: DnnModel, opts: &SweepOpts) -> SimConfig {
    SimConfig {
        model,
        slots: opts.slots,
        seed: opts.seed,
        decision_fraction: opts.decision_fraction,
        engine: opts.engine,
        scenario: opts.scenario,
        dissemination: opts.dissemination,
        topology: opts.topology.clone(),
        shards: opts.shards,
        decide_threads: opts.decide_threads,
        decision_cache: opts.decision_cache,
        ..SimConfig::default()
    }
}

fn mean_reports(reports: Vec<Report>) -> Report {
    // average the headline metrics across repetitions (simple field mean)
    let n = reports.len() as f64;
    let mut out = reports[0].clone();
    if reports.len() > 1 {
        let sum_u64 = |f: fn(&Report) -> u64| -> u64 {
            (reports.iter().map(|r| f(r) as f64).sum::<f64>() / n).round() as u64
        };
        let sum_f = |f: fn(&Report) -> f64| -> f64 {
            reports.iter().map(f).sum::<f64>() / n
        };
        out.total_tasks = sum_u64(|r| r.total_tasks);
        out.completed_tasks = sum_u64(|r| r.completed_tasks);
        out.dropped_tasks = out.total_tasks - out.completed_tasks;
        out.avg_delay_ms = sum_f(|r| r.avg_delay_ms);
        out.avg_comp_ms = sum_f(|r| r.avg_comp_ms);
        out.avg_tran_ms = sum_f(|r| r.avg_tran_ms);
        out.avg_uplink_ms = sum_f(|r| r.avg_uplink_ms);
        out.workload_variance = sum_f(|r| r.workload_variance);
        out.workload_mean = sum_f(|r| r.workload_mean);
        out.delay_p50_ms = sum_f(|r| r.delay_p50_ms);
        out.delay_p95_ms = sum_f(|r| r.delay_p95_ms);
        out.horizon_s = sum_f(|r| r.horizon_s);
        out.last_finish_s = sum_f(|r| r.last_finish_s);
        // resilience block (recovery/reroute runs): field means when
        // every repeat produced one — a mixed set keeps the first
        // repeat's (fault-free repeats never have it)
        if reports.iter().all(|r| r.resilience.is_some()) {
            let rs: Vec<&ResilienceReport> = reports
                .iter()
                .filter_map(|r| r.resilience.as_ref())
                .collect();
            let sum_ru = |f: fn(&ResilienceReport) -> u64| -> u64 {
                (rs.iter().map(|x| f(x) as f64).sum::<f64>() / n).round() as u64
            };
            let sum_rf = |f: fn(&ResilienceReport) -> f64| -> f64 {
                rs.iter().map(|x| f(x)).sum::<f64>() / n
            };
            out.resilience = Some(ResilienceReport {
                recovered_tasks: sum_ru(|x| x.recovered_tasks),
                retries: sum_ru(|x| x.retries),
                reroutes: sum_ru(|x| x.reroutes),
                give_ups: sum_ru(|x| x.give_ups),
                rework_mflops: sum_rf(|x| x.rework_mflops),
                mean_time_to_recover_ms: sum_rf(|x| x.mean_time_to_recover_ms),
            });
        }
        // round-level block (autoregressive runs): field means when every
        // repeat produced one — a mixed set keeps the first repeat's
        // (one-shot repeats never have it, so `None` stays `None`)
        if reports.iter().all(|r| r.llm.is_some()) {
            let ls: Vec<&LlmReport> = reports.iter().filter_map(|r| r.llm.as_ref()).collect();
            let sum_lu = |f: fn(&LlmReport) -> u64| -> u64 {
                (ls.iter().map(|l| f(l) as f64).sum::<f64>() / n).round() as u64
            };
            let sum_lf =
                |f: fn(&LlmReport) -> f64| -> f64 { ls.iter().map(|l| f(l)).sum::<f64>() / n };
            out.llm = Some(LlmReport {
                decode_tasks: sum_lu(|l| l.decode_tasks),
                rounds_completed: sum_lu(|l| l.rounds_completed),
                rounds_dropped: sum_lu(|l| l.rounds_dropped),
                avg_round_delay_ms: sum_lf(|l| l.avg_round_delay_ms),
                time_to_first_round_ms: sum_lf(|l| l.time_to_first_round_ms),
                time_to_last_round_ms: sum_lf(|l| l.time_to_last_round_ms),
            });
        }
    }
    out
}

/// Average one sweep cell over `opts.repeats` independent seeds
/// (`opts.seed + r·1000`, the repeat protocol every sweep shares):
/// `tweak` stamps the cell's coordinates (λ, topology, dissemination, N,
/// …) onto the base config before each run.
fn repeat_mean(
    model: DnnModel,
    scheme: SchemeKind,
    opts: &SweepOpts,
    tweak: impl Fn(&mut SimConfig),
) -> Report {
    let reports: Vec<Report> = (0..opts.repeats.max(1))
        .map(|r| {
            let mut cfg = base_cfg(model, opts);
            cfg.seed = opts.seed + r as u64 * 1000;
            tweak(&mut cfg);
            crate::engine::run(&cfg, scheme)
        })
        .collect();
    mean_reports(reports)
}

/// Run one (model, λ, scheme) point, averaged over `opts.repeats` seeds,
/// on the engine/scenario selected by `opts` (slotted Poisson = paper).
pub fn run_point(
    model: DnnModel,
    lambda: f64,
    scheme: SchemeKind,
    opts: &SweepOpts,
) -> Report {
    repeat_mean(model, scheme, opts, |cfg| cfg.lambda = lambda)
}

/// Run one (model, λ, scheme) point on the EVENT engine under a traffic
/// scenario (a [`run_point`] override, sharing its repeat/seed protocol).
pub fn run_point_event(
    model: DnnModel,
    lambda: f64,
    scheme: SchemeKind,
    scenario: ScenarioKind,
    opts: &SweepOpts,
) -> Report {
    let opts = SweepOpts {
        engine: EngineKind::Event,
        scenario,
        ..opts.clone()
    };
    run_point(model, lambda, scheme, &opts)
}

/// λ-sweep over all four schemes on the event-driven engine (the eventsim
/// companion to [`fig2`]/[`fig3`]), every (cell, repeat) fanned across
/// cores.
pub fn eventsim_sweep(
    model: DnnModel,
    lambdas: &[f64],
    scenario: ScenarioKind,
    opts: &SweepOpts,
) -> Vec<Row> {
    let cells: Vec<(f64, SchemeKind)> = lambdas
        .iter()
        .flat_map(|&lambda| SchemeKind::all().into_iter().map(move |s| (lambda, s)))
        .collect();
    let reports = repeat_mean_cells(
        opts,
        cells.clone(),
        |(lambda, scheme)| format!("lambda={lambda} scheme={}", scheme.name()),
        |&(lambda, scheme), seed| {
            let mut cfg = base_cfg(model, opts);
            cfg.engine = EngineKind::Event;
            cfg.scenario = scenario;
            cfg.seed = seed;
            cfg.lambda = lambda;
            crate::engine::run(&cfg, scheme)
        },
    );
    cells
        .into_iter()
        .zip(reports)
        .map(|((lambda, scheme), report)| Row {
            x: lambda,
            scheme,
            report,
        })
        .collect()
}

/// λ grid for the eventsim experiment. `quick` shrinks it to two points so
/// a CI smoke run finishes in seconds (pair with [`SweepOpts::quick`]).
pub fn eventsim_lambdas(quick: bool) -> Vec<f64> {
    if quick {
        vec![4.0, 25.0]
    } else {
        default_lambdas()
    }
}

/// One point of the staleness sweep: a (dissemination, scheme) cell.
#[derive(Clone, Debug)]
pub struct StalenessRow {
    /// Staleness scale `T_d` [s] (0 for instant; the tick for gossip).
    pub t_d: f64,
    /// The dissemination model this cell ran under.
    pub dissemination: DisseminationKind,
    pub scheme: SchemeKind,
    pub report: Report,
}

/// Default `T_d` grid for the staleness sweep; `quick` trims it to two
/// points for the CI smoke run.
pub fn staleness_periods(quick: bool) -> Vec<f64> {
    if quick {
        vec![1.0, 4.0]
    } else {
        vec![0.25, 0.5, 1.0, 2.0, 4.0]
    }
}

/// The λ the staleness sweep runs at by default: the paper's high-traffic
/// end, where contention makes stale-state herding (§V-B) visible.
pub const STALENESS_LAMBDA: f64 = 55.0;

/// Sweep completion rate & tail delay vs the dissemination interval on
/// the engine selected by `opts.engine` (the CLI defaults this to the
/// event engine, which honours sub-slot intervals): `instant` (the
/// fresh-state upper bound), `periodic` at every `T_d` in `periods`,
/// plus the default hop-delayed gossip — each for all four schemes,
/// averaged over `opts.repeats` seeds.
pub fn staleness_sweep(
    model: DnnModel,
    lambda: f64,
    periods: &[f64],
    opts: &SweepOpts,
) -> Vec<StalenessRow> {
    let mut kinds = vec![DisseminationKind::Instant];
    kinds.extend(
        periods
            .iter()
            .map(|&p| DisseminationKind::Periodic { period_s: p }),
    );
    kinds.push(DisseminationKind::Gossip {
        tick_s: crate::state::DEFAULT_GOSSIP_TICK_S,
    });
    let cells: Vec<(DisseminationKind, SchemeKind)> = kinds
        .iter()
        .flat_map(|&d| SchemeKind::all().into_iter().map(move |s| (d, s)))
        .collect();
    let reports = repeat_mean_cells(
        opts,
        cells.clone(),
        |(d, scheme)| format!("dissemination={} scheme={}", d.label(), scheme.name()),
        |&(d, scheme), seed| {
            let mut cfg = base_cfg(model, opts);
            cfg.seed = seed;
            cfg.lambda = lambda;
            cfg.dissemination = Some(d);
            crate::engine::run(&cfg, scheme)
        },
    );
    cells
        .into_iter()
        .zip(reports)
        .map(|((d, scheme), report)| StalenessRow {
            t_d: d.t_d_s(),
            dissemination: d,
            scheme,
            report,
        })
        .collect()
}

/// Render the staleness sweep as two panels (completion rate and p95
/// delay, dissemination × scheme).
pub fn render_staleness(title: &str, rows: &[StalenessRow]) -> String {
    let mut kinds: Vec<DisseminationKind> = Vec::new();
    for r in rows {
        if !kinds.contains(&r.dissemination) {
            kinds.push(r.dissemination);
        }
    }
    let schemes = SchemeKind::all();
    let mut out = format!("== {title} ==\n");
    for (panel, metric) in [
        ("(a) task completion rate", 0usize),
        ("(b) p95 total delay [ms]", 1),
    ] {
        out.push_str(&format!("-- {panel} --\n{:>14}", "dissemination"));
        for s in schemes {
            out.push_str(&format!("{:>14}", s.name()));
        }
        out.push('\n');
        for &k in &kinds {
            out.push_str(&format!("{:>14}", k.label()));
            for s in schemes {
                let row = rows
                    .iter()
                    .find(|r| r.dissemination == k && r.scheme == s)
                    .expect("missing staleness row");
                let v = match metric {
                    0 => row.report.completion_rate(),
                    _ => row.report.delay_p95_ms,
                };
                match metric {
                    0 => out.push_str(&format!("{v:>14.4}")),
                    _ => out.push_str(&format!("{v:>14.1}")),
                }
            }
            out.push('\n');
        }
    }
    out
}

/// The machine-readable `BENCH_staleness.json` payload (per-cell
/// completion rate, mean/p95 delay, and drop counts — see the README's
/// "Experiment cookbook" for the schema). `engine` records which clock
/// produced the rows.
pub fn staleness_json(
    model: DnnModel,
    lambda: f64,
    engine: EngineKind,
    quick: bool,
    rows: &[StalenessRow],
) -> Json {
    Json::obj(vec![
        ("bench", Json::Str("staleness".into())),
        ("quick", Json::Bool(quick)),
        ("model", Json::Str(model.name().into())),
        ("engine", Json::Str(engine.name().into())),
        ("lambda", Json::Num(lambda)),
        (
            "results",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("dissemination", Json::Str(r.dissemination.label())),
                            ("t_d_s", Json::Num(r.t_d)),
                            ("scheme", Json::Str(r.scheme.name().into())),
                            (
                                "completion_rate",
                                Json::Num(r.report.completion_rate()),
                            ),
                            ("avg_delay_ms", Json::Num(r.report.avg_delay_ms)),
                            ("delay_p95_ms", Json::Num(r.report.delay_p95_ms)),
                            (
                                "total_tasks",
                                Json::Num(r.report.total_tasks as f64),
                            ),
                            (
                                "dropped_tasks",
                                Json::Num(r.report.dropped_tasks as f64),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// One point of the decision-cache sweep: a (`T_d`, cache on/off) cell.
/// SCC-only — the cache lives in the GA scheme; heuristics never consult
/// it (pinned by `tests/prop_pool.rs`).
#[derive(Clone, Debug)]
pub struct DecideCacheRow {
    /// Broadcast period `T_d` [s] of the periodic dissemination the cell
    /// ran under — the epoch length the cache keys on.
    pub t_d: f64,
    /// Whether `--decision-cache` was on for this cell.
    pub cache: bool,
    pub report: Report,
    /// Cache hits / lookups across the cell's repeats (0.0 off or when
    /// no decide ever consulted the cache).
    pub hit_rate: f64,
    /// GA placement decisions per run (mean over repeats).
    pub decides: f64,
    /// Placement decisions per wall-clock second, summed decides over
    /// summed wall time — the sweep's headline throughput number.
    pub decides_per_s: f64,
}

/// Default `T_d` grid for the decision-cache sweep; `quick` trims it to
/// two points for the CI smoke run.
pub fn decidecache_periods(quick: bool) -> Vec<f64> {
    if quick {
        vec![1.0, 4.0]
    } else {
        vec![0.5, 1.0, 2.0, 4.0]
    }
}

/// The λ the decision-cache sweep runs at by default: the staleness
/// sweep's high-traffic point, where decides between broadcasts are
/// dense enough for the cache to matter.
pub const DECIDECACHE_LAMBDA: f64 = STALENESS_LAMBDA;

/// Sweep the epoch-keyed decision cache (`--decision-cache`) against the
/// default path at each periodic `T_d`: SCC on the engine selected by
/// `opts.engine`, averaged over `opts.repeats` seeds. Each cell runs
/// with telemetry enabled to harvest the GA kernel counters (decides,
/// cache hits/lookups) and times the runs for decides/s. The cache is
/// **not** byte-identical to off (hits skip the GA's RNG draws), so the
/// interesting check is that completion rate and p95 stay inside the
/// repeat noise band while decides/s moves.
pub fn decidecache_sweep(
    model: DnnModel,
    lambda: f64,
    periods: &[f64],
    opts: &SweepOpts,
) -> Vec<DecideCacheRow> {
    let cells: Vec<(f64, bool)> = periods
        .iter()
        .flat_map(|&p| [(p, false), (p, true)])
        .collect();
    let repeats = opts.repeats.max(1);
    let progress = Progress::new(opts.progress, cells.len() * repeats);
    // (report, decides, hits, lookups, wall_s) per repeat; counters come
    // from the telemetry block's `scheme` object (crate::offload::ga).
    let grouped = run_cells_repeated(opts.threads, repeats, cells.clone(), |&(p, cache), r| {
        progress.cell(
            || format!("t_d={p} cache={cache} repeat={}/{repeats}", r + 1),
            || {
                let mut cfg = base_cfg(model, opts);
                cfg.seed = opts.seed + r as u64 * 1000;
                cfg.lambda = lambda;
                cfg.dissemination = Some(DisseminationKind::Periodic { period_s: p });
                cfg.decision_cache = cache;
                cfg.obs.telemetry = true;
                let t0 = std::time::Instant::now();
                let report = crate::engine::run(&cfg, SchemeKind::Scc);
                let wall_s = t0.elapsed().as_secs_f64();
                let counter = |key: &str| -> f64 {
                    report
                        .telemetry
                        .as_ref()
                        .and_then(|t| t.get("scheme"))
                        .and_then(|s| s.get(key))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(0.0)
                };
                let decides = counter("decides");
                let hits = counter("decision_cache_hits");
                let lookups = counter("decision_cache_lookups");
                (report, decides, hits, lookups, wall_s)
            },
        )
    });
    cells
        .into_iter()
        .zip(grouped)
        .map(|((t_d, cache), reps)| {
            let n = reps.len() as f64;
            let decides_sum: f64 = reps.iter().map(|r| r.1).sum();
            let hits_sum: f64 = reps.iter().map(|r| r.2).sum();
            let lookups_sum: f64 = reps.iter().map(|r| r.3).sum();
            let wall_sum: f64 = reps.iter().map(|r| r.4).sum();
            let report = mean_reports(reps.into_iter().map(|r| r.0).collect());
            DecideCacheRow {
                t_d,
                cache,
                report,
                hit_rate: if lookups_sum > 0.0 { hits_sum / lookups_sum } else { 0.0 },
                decides: decides_sum / n,
                decides_per_s: if wall_sum > 0.0 { decides_sum / wall_sum } else { 0.0 },
            }
        })
        .collect()
}

/// Render the decision-cache sweep: one line per `T_d`, cache off vs on
/// side by side (completion, p95, hit rate, decides/s).
pub fn render_decidecache(title: &str, rows: &[DecideCacheRow]) -> String {
    let mut out = format!(
        "== {title} ==\n{:>8}{:>12}{:>12}{:>12}{:>12}{:>10}{:>14}{:>14}\n",
        "T_d [s]",
        "compl off",
        "compl on",
        "p95 off",
        "p95 on",
        "hit rate",
        "decides/s off",
        "decides/s on",
    );
    let mut t_ds: Vec<f64> = Vec::new();
    for r in rows {
        if !t_ds.iter().any(|&t| t == r.t_d) {
            t_ds.push(r.t_d);
        }
    }
    for &t_d in &t_ds {
        let cell = |cache: bool| {
            rows.iter()
                .find(|r| r.t_d == t_d && r.cache == cache)
                .expect("missing decidecache row")
        };
        let (off, on) = (cell(false), cell(true));
        out.push_str(&format!(
            "{:>8}{:>12.4}{:>12.4}{:>12.1}{:>12.1}{:>10.3}{:>14.1}{:>14.1}\n",
            t_d,
            off.report.completion_rate(),
            on.report.completion_rate(),
            off.report.delay_p95_ms,
            on.report.delay_p95_ms,
            on.hit_rate,
            off.decides_per_s,
            on.decides_per_s,
        ));
    }
    out
}

/// The machine-readable `BENCH_decidecache.json` payload (per-cell
/// completion rate, p95 delay, hit rate, and decide throughput — see the
/// README's "Experiment cookbook" for the schema).
pub fn decidecache_json(
    model: DnnModel,
    lambda: f64,
    engine: EngineKind,
    quick: bool,
    rows: &[DecideCacheRow],
) -> Json {
    Json::obj(vec![
        ("bench", Json::Str("decidecache".into())),
        ("quick", Json::Bool(quick)),
        ("model", Json::Str(model.name().into())),
        ("engine", Json::Str(engine.name().into())),
        ("scheme", Json::Str("SCC".into())),
        ("lambda", Json::Num(lambda)),
        (
            "results",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("t_d_s", Json::Num(r.t_d)),
                            ("cache", Json::Bool(r.cache)),
                            (
                                "completion_rate",
                                Json::Num(r.report.completion_rate()),
                            ),
                            ("avg_delay_ms", Json::Num(r.report.avg_delay_ms)),
                            ("delay_p95_ms", Json::Num(r.report.delay_p95_ms)),
                            ("total_tasks", Json::Num(r.report.total_tasks as f64)),
                            ("hit_rate", Json::Num(r.hit_rate)),
                            ("decides", Json::Num(r.decides)),
                            ("decides_per_s", Json::Num(r.decides_per_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// One point of the topology sweep: a (topology, scheme) cell.
#[derive(Clone, Debug)]
pub struct TopologyRow {
    /// The constellation geometry this cell ran under.
    pub topology: TopologyKind,
    pub scheme: SchemeKind,
    pub report: Report,
}

/// The λ the topology sweep runs at by default: high enough that ISL hop
/// distances and the Walker-Star seam detour actually cost completions
/// and tail delay.
pub const TOPOLOGY_LAMBDA: f64 = 40.0;

/// Default topology grid for [`topology_sweep`]: the paper's N×N torus
/// plus a Walker-Delta (phasing 1) and a Walker-Star of the same
/// satellite count, so scheme comparisons stay capacity-fair and any
/// difference is pure geometry (the seam detour, the phasing offset).
pub fn topology_grid(n: usize) -> Vec<TopologyKind> {
    vec![
        TopologyKind::Torus { n },
        TopologyKind::WalkerDelta {
            planes: n,
            sats_per_plane: n,
            phasing: 1,
        },
        TopologyKind::WalkerStar {
            planes: n,
            sats_per_plane: n,
        },
    ]
}

/// Sweep completion rate & tail delay per scheme per constellation
/// topology on the engine selected by `opts.engine` (the CLI defaults
/// this to the event engine), averaged over `opts.repeats` seeds.
pub fn topology_sweep(
    model: DnnModel,
    lambda: f64,
    kinds: &[TopologyKind],
    opts: &SweepOpts,
) -> Vec<TopologyRow> {
    let cells: Vec<(TopologyKind, SchemeKind)> = kinds
        .iter()
        .flat_map(|kind| {
            SchemeKind::all()
                .into_iter()
                .map(move |s| (kind.clone(), s))
        })
        .collect();
    let reports = repeat_mean_cells(
        opts,
        cells.clone(),
        |(kind, scheme)| format!("topology={} scheme={}", kind.label(), scheme.name()),
        |(kind, scheme), seed| {
            let mut cfg = base_cfg(model, opts);
            cfg.seed = seed;
            cfg.lambda = lambda;
            cfg.topology = Some(kind.clone());
            crate::engine::run(&cfg, *scheme)
        },
    );
    cells
        .into_iter()
        .zip(reports)
        .map(|((kind, scheme), report)| TopologyRow {
            topology: kind,
            scheme,
            report,
        })
        .collect()
}

/// Render the topology sweep as two panels (completion rate and p95
/// delay, topology × scheme).
pub fn render_topology(title: &str, rows: &[TopologyRow]) -> String {
    let mut kinds: Vec<TopologyKind> = Vec::new();
    for r in rows {
        if !kinds.contains(&r.topology) {
            kinds.push(r.topology.clone());
        }
    }
    let schemes = SchemeKind::all();
    let mut out = format!("== {title} ==\n");
    for (panel, metric) in [
        ("(a) task completion rate", 0usize),
        ("(b) p95 total delay [ms]", 1),
    ] {
        out.push_str(&format!("-- {panel} --\n{:>22}", "topology"));
        for s in schemes {
            out.push_str(&format!("{:>14}", s.name()));
        }
        out.push('\n');
        for k in &kinds {
            out.push_str(&format!("{:>22}", k.label()));
            for s in schemes {
                let row = rows
                    .iter()
                    .find(|r| r.topology == *k && r.scheme == s)
                    .expect("missing topology row");
                let v = match metric {
                    0 => row.report.completion_rate(),
                    _ => row.report.delay_p95_ms,
                };
                match metric {
                    0 => out.push_str(&format!("{v:>14.4}")),
                    _ => out.push_str(&format!("{v:>14.1}")),
                }
            }
            out.push('\n');
        }
    }
    out
}

/// The machine-readable `BENCH_topology.json` payload (per-cell
/// completion rate, mean/p95 delay, and drop counts — see the README's
/// "Experiment cookbook" for the schema). `engine` records which clock
/// produced the rows.
pub fn topology_json(
    model: DnnModel,
    lambda: f64,
    engine: EngineKind,
    quick: bool,
    rows: &[TopologyRow],
) -> Json {
    Json::obj(vec![
        ("bench", Json::Str("topology".into())),
        ("quick", Json::Bool(quick)),
        ("model", Json::Str(model.name().into())),
        ("engine", Json::Str(engine.name().into())),
        ("lambda", Json::Num(lambda)),
        (
            "results",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("topology", Json::Str(r.topology.label())),
                            ("n_sats", Json::Num(r.topology.n_sats() as f64)),
                            ("scheme", Json::Str(r.scheme.name().into())),
                            (
                                "completion_rate",
                                Json::Num(r.report.completion_rate()),
                            ),
                            ("avg_delay_ms", Json::Num(r.report.avg_delay_ms)),
                            ("delay_p95_ms", Json::Num(r.report.delay_p95_ms)),
                            (
                                "total_tasks",
                                Json::Num(r.report.total_tasks as f64),
                            ),
                            (
                                "dropped_tasks",
                                Json::Num(r.report.dropped_tasks as f64),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// One cell of the LLM workload sweep: an autoregressive task-kind
/// variant crossed with an offloading scheme.
pub struct LlmRow {
    /// The autoregressive workload this cell ran under.
    pub kind: TaskKind,
    pub scheme: SchemeKind,
    pub report: Report,
}

/// The λ the LLM sweep runs at by default: moderate load so the decode
/// phase (not admission) dominates the round-delay signal.
pub const LLM_LAMBDA: f64 = 25.0;

/// Round counts swept by `experiment llm`.
pub fn llm_rounds(quick: bool) -> Vec<u32> {
    if quick {
        vec![2, 8]
    } else {
        vec![2, 8, 32]
    }
}

/// The task-kind grid for [`llm_sweep`]: one escalation-free
/// autoregressive variant per round count, plus a single escalating cell
/// (threshold at half the round deadline) on the mid round count so the
/// sticky-state migration path is exercised in every run.
pub fn llm_kind_grid(rounds: &[u32]) -> Vec<TaskKind> {
    let d = LlmConfig::default();
    let mut kinds: Vec<TaskKind> = rounds
        .iter()
        .map(|&r| TaskKind::Autoregressive {
            rounds: r,
            decode_flops: d.decode_flops,
            state_bytes: d.state_bytes,
            escalate: None,
        })
        .collect();
    let mid = rounds[rounds.len() / 2];
    kinds.push(TaskKind::Autoregressive {
        rounds: mid,
        decode_flops: d.decode_flops,
        state_bytes: d.state_bytes,
        escalate: Some(d.round_deadline_s * 0.5),
    });
    kinds
}

/// Sweep round-level delay metrics per scheme per autoregressive
/// workload variant on the engine selected by `opts.engine`, averaged
/// over `opts.repeats` seeds.
pub fn llm_sweep(
    model: DnnModel,
    lambda: f64,
    kinds: &[TaskKind],
    opts: &SweepOpts,
) -> Vec<LlmRow> {
    let cells: Vec<(TaskKind, SchemeKind)> = kinds
        .iter()
        .flat_map(|kind| SchemeKind::all().into_iter().map(move |s| (*kind, s)))
        .collect();
    let reports = repeat_mean_cells(
        opts,
        cells.clone(),
        |(kind, scheme)| format!("kind={} scheme={}", kind.label(), scheme.name()),
        |(kind, scheme), seed| {
            let mut cfg = base_cfg(model, opts);
            cfg.seed = seed;
            cfg.lambda = lambda;
            cfg.task_kind = Some(*kind);
            crate::engine::run(&cfg, *scheme)
        },
    );
    cells
        .into_iter()
        .zip(reports)
        .map(|((kind, scheme), report)| LlmRow {
            kind,
            scheme,
            report,
        })
        .collect()
}

/// Render the LLM sweep as three panels (completion rate, mean round
/// delay, time-to-last-round; workload × scheme).
pub fn render_llm(title: &str, rows: &[LlmRow]) -> String {
    let mut kinds: Vec<TaskKind> = Vec::new();
    for r in rows {
        if !kinds.contains(&r.kind) {
            kinds.push(r.kind);
        }
    }
    let schemes = SchemeKind::all();
    let mut out = format!("== {title} ==\n");
    for (panel, metric) in [
        ("(a) task completion rate", 0usize),
        ("(b) avg round delay [ms]", 1),
        ("(c) time to last round [ms]", 2),
    ] {
        out.push_str(&format!("-- {panel} --\n{:>26}", "workload"));
        for s in schemes {
            out.push_str(&format!("{:>14}", s.name()));
        }
        out.push('\n');
        for k in &kinds {
            out.push_str(&format!("{:>26}", k.label()));
            for s in schemes {
                let row = rows
                    .iter()
                    .find(|r| r.kind == *k && r.scheme == s)
                    .expect("missing llm row");
                let llm = row.report.llm.as_ref();
                let v = match metric {
                    0 => row.report.completion_rate(),
                    1 => llm.map(|l| l.avg_round_delay_ms).unwrap_or(0.0),
                    _ => llm.map(|l| l.time_to_last_round_ms).unwrap_or(0.0),
                };
                match metric {
                    0 => out.push_str(&format!("{v:>14.4}")),
                    _ => out.push_str(&format!("{v:>14.2}")),
                }
            }
            out.push('\n');
        }
    }
    out
}

/// The machine-readable `BENCH_llm.json` payload: per-cell workload
/// label, round count, scheme, headline completion/delay numbers, and
/// the flattened round-level block (see the README's "LLM workloads"
/// section for the schema). `engine` records which clock produced the
/// rows.
pub fn llm_json(
    model: DnnModel,
    lambda: f64,
    engine: EngineKind,
    quick: bool,
    rows: &[LlmRow],
) -> Json {
    Json::obj(vec![
        ("bench", Json::Str("llm".into())),
        ("quick", Json::Bool(quick)),
        ("model", Json::Str(model.name().into())),
        ("engine", Json::Str(engine.name().into())),
        ("lambda", Json::Num(lambda)),
        (
            "results",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        let rounds = match r.kind {
                            TaskKind::Autoregressive { rounds, .. } => rounds,
                            TaskKind::OneShot => 0,
                        };
                        let mut fields = vec![
                            ("workload", Json::Str(r.kind.label())),
                            ("rounds", Json::Num(rounds as f64)),
                            ("scheme", Json::Str(r.scheme.name().into())),
                            (
                                "completion_rate",
                                Json::Num(r.report.completion_rate()),
                            ),
                            ("avg_delay_ms", Json::Num(r.report.avg_delay_ms)),
                            ("delay_p95_ms", Json::Num(r.report.delay_p95_ms)),
                            (
                                "total_tasks",
                                Json::Num(r.report.total_tasks as f64),
                            ),
                            (
                                "dropped_tasks",
                                Json::Num(r.report.dropped_tasks as f64),
                            ),
                        ];
                        if let Some(l) = &r.report.llm {
                            fields.push((
                                "decode_tasks",
                                Json::Num(l.decode_tasks as f64),
                            ));
                            fields.push((
                                "rounds_completed",
                                Json::Num(l.rounds_completed as f64),
                            ));
                            fields.push((
                                "rounds_dropped",
                                Json::Num(l.rounds_dropped as f64),
                            ));
                            fields.push((
                                "avg_round_delay_ms",
                                Json::Num(l.avg_round_delay_ms),
                            ));
                            fields.push((
                                "time_to_first_round_ms",
                                Json::Num(l.time_to_first_round_ms),
                            ));
                            fields.push((
                                "time_to_last_round_ms",
                                Json::Num(l.time_to_last_round_ms),
                            ));
                        }
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
    ])
}

/// One cell of the resilience sweep: a (fault rate, recovery on/off,
/// scheme) cell.
#[derive(Clone, Debug)]
pub struct ResilienceRow {
    /// Per-tick satellite failure probability this cell ran under.
    pub p_fail: f64,
    /// Whether `--recovery reoffload:2` was on for this cell (off =
    /// the legacy `drop` policy).
    pub recovery: bool,
    pub scheme: SchemeKind,
    pub report: Report,
}

/// The λ the resilience sweep runs at by default: loaded enough that a
/// lost chain actually costs completions, light enough that recovery
/// still finds spare capacity to land retries on.
pub const RESILIENCE_LAMBDA: f64 = 40.0;

/// Fault-rate grid for `experiment resilience`; `quick` trims it to two
/// points for the CI smoke run.
pub fn resilience_rates(quick: bool) -> Vec<f64> {
    if quick {
        vec![0.02, 0.08]
    } else {
        vec![0.0, 0.02, 0.05, 0.08, 0.12]
    }
}

/// Sweep completion rate & tail delay vs satellite fault rate, recovery
/// off (`drop`, the paper's behaviour) vs on (`reoffload:2`) per scheme,
/// on the engine selected by `opts.engine` (the CLI defaults this to the
/// event engine, whose mid-chain faults make recovery bite), averaged
/// over `opts.repeats` seeds. The recovery probability is pinned at 0.5
/// so the fault rate is the only moving axis.
pub fn resilience_sweep(
    model: DnnModel,
    lambda: f64,
    rates: &[f64],
    opts: &SweepOpts,
) -> Vec<ResilienceRow> {
    let cells: Vec<(f64, bool, SchemeKind)> = rates
        .iter()
        .flat_map(|&p| {
            [false, true].into_iter().flat_map(move |rec| {
                SchemeKind::all().into_iter().map(move |s| (p, rec, s))
            })
        })
        .collect();
    let reports = repeat_mean_cells(
        opts,
        cells.clone(),
        |(p, rec, scheme)| {
            format!(
                "p_fail={p} recovery={} scheme={}",
                if *rec { "reoffload" } else { "drop" },
                scheme.name()
            )
        },
        |&(p, rec, scheme), seed| {
            let mut cfg = base_cfg(model, opts);
            cfg.seed = seed;
            cfg.lambda = lambda;
            cfg.resilience.p_fail = p;
            cfg.resilience.p_recover = 0.5;
            if rec {
                cfg.resilience.recovery = RecoveryPolicy::Reoffload { max_retries: 2 };
            }
            crate::engine::run(&cfg, scheme)
        },
    );
    cells
        .into_iter()
        .zip(reports)
        .map(|((p_fail, recovery, scheme), report)| ResilienceRow {
            p_fail,
            recovery,
            scheme,
            report,
        })
        .collect()
}

/// Render the resilience sweep as two panels (completion rate and p95
/// delay; fault rate × policy rows, scheme columns).
pub fn render_resilience(title: &str, rows: &[ResilienceRow]) -> String {
    let mut rates: Vec<f64> = Vec::new();
    for r in rows {
        if !rates.iter().any(|&p| p == r.p_fail) {
            rates.push(r.p_fail);
        }
    }
    let schemes = SchemeKind::all();
    let mut out = format!("== {title} ==\n");
    for (panel, metric) in [
        ("(a) task completion rate", 0usize),
        ("(b) p95 total delay [ms]", 1),
    ] {
        out.push_str(&format!("-- {panel} --\n{:>22}", "p_fail / recovery"));
        for s in schemes {
            out.push_str(&format!("{:>14}", s.name()));
        }
        out.push('\n');
        for &p in &rates {
            for rec in [false, true] {
                let label =
                    format!("{p} / {}", if rec { "reoffload" } else { "drop" });
                out.push_str(&format!("{label:>22}"));
                for s in schemes {
                    let row = rows
                        .iter()
                        .find(|r| {
                            r.p_fail == p && r.recovery == rec && r.scheme == s
                        })
                        .expect("missing resilience row");
                    let v = match metric {
                        0 => row.report.completion_rate(),
                        _ => row.report.delay_p95_ms,
                    };
                    match metric {
                        0 => out.push_str(&format!("{v:>14.4}")),
                        _ => out.push_str(&format!("{v:>14.1}")),
                    }
                }
                out.push('\n');
            }
        }
    }
    out
}

/// The machine-readable `BENCH_resilience.json` payload: per-cell fault
/// rate, policy, scheme, headline completion/delay numbers, and the
/// flattened recovery block when the cell produced one (see the README's
/// "Experiment cookbook" for the schema). `engine` records which clock
/// produced the rows.
pub fn resilience_json(
    model: DnnModel,
    lambda: f64,
    engine: EngineKind,
    quick: bool,
    rows: &[ResilienceRow],
) -> Json {
    Json::obj(vec![
        ("bench", Json::Str("resilience".into())),
        ("quick", Json::Bool(quick)),
        ("model", Json::Str(model.name().into())),
        ("engine", Json::Str(engine.name().into())),
        ("lambda", Json::Num(lambda)),
        (
            "results",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        let mut fields = vec![
                            ("p_fail", Json::Num(r.p_fail)),
                            (
                                "recovery",
                                Json::Str(
                                    if r.recovery { "reoffload" } else { "drop" }
                                        .into(),
                                ),
                            ),
                            ("scheme", Json::Str(r.scheme.name().into())),
                            (
                                "completion_rate",
                                Json::Num(r.report.completion_rate()),
                            ),
                            ("avg_delay_ms", Json::Num(r.report.avg_delay_ms)),
                            ("delay_p95_ms", Json::Num(r.report.delay_p95_ms)),
                            (
                                "total_tasks",
                                Json::Num(r.report.total_tasks as f64),
                            ),
                            (
                                "dropped_tasks",
                                Json::Num(r.report.dropped_tasks as f64),
                            ),
                        ];
                        if let Some(res) = &r.report.resilience {
                            fields.push((
                                "recovered_tasks",
                                Json::Num(res.recovered_tasks as f64),
                            ));
                            fields.push(("retries", Json::Num(res.retries as f64)));
                            fields
                                .push(("reroutes", Json::Num(res.reroutes as f64)));
                            fields
                                .push(("give_ups", Json::Num(res.give_ups as f64)));
                            fields.push((
                                "rework_mflops",
                                Json::Num(res.rework_mflops),
                            ));
                            fields.push((
                                "mean_time_to_recover_ms",
                                Json::Num(res.mean_time_to_recover_ms),
                            ));
                        }
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
    ])
}

/// λ-sweep over all four schemes (the engine behind Figs. 2 & 3), every
/// (cell, repeat) fanned across cores with deterministic row order.
pub fn lambda_sweep(model: DnnModel, lambdas: &[f64], opts: &SweepOpts) -> Vec<Row> {
    let cells: Vec<(f64, SchemeKind)> = lambdas
        .iter()
        .flat_map(|&lambda| SchemeKind::all().into_iter().map(move |s| (lambda, s)))
        .collect();
    let reports = repeat_mean_cells(
        opts,
        cells.clone(),
        |(lambda, scheme)| format!("lambda={lambda} scheme={}", scheme.name()),
        |&(lambda, scheme), seed| {
            let mut cfg = base_cfg(model, opts);
            cfg.seed = seed;
            cfg.lambda = lambda;
            crate::engine::run(&cfg, scheme)
        },
    );
    cells
        .into_iter()
        .zip(reports)
        .map(|((lambda, scheme), report)| Row {
            x: lambda,
            scheme,
            report,
        })
        .collect()
}

/// Paper default λ grid (§V-A: λ ∈ 4–70).
pub fn default_lambdas() -> Vec<f64> {
    vec![4.0, 10.0, 25.0, 40.0, 55.0, 70.0]
}

/// Fig. 2 (ResNet101, L=4, D_M=3): all three panels.
pub fn fig2(opts: &SweepOpts) -> Vec<Row> {
    lambda_sweep(DnnModel::Resnet101, &default_lambdas(), opts)
}

/// Fig. 3 (VGG19, L=3, D_M=2): all three panels.
pub fn fig3(opts: &SweepOpts) -> Vec<Row> {
    lambda_sweep(DnnModel::Vgg19, &default_lambdas(), opts)
}

/// §V-B network-scale study: completion rate vs N at fixed λ = 25, every
/// (cell, repeat) fanned across cores.
pub fn scale(ns: &[usize], opts: &SweepOpts) -> Vec<Row> {
    let cells: Vec<(usize, SchemeKind)> = ns
        .iter()
        .flat_map(|&n| SchemeKind::all().into_iter().map(move |s| (n, s)))
        .collect();
    let reports = repeat_mean_cells(
        opts,
        cells.clone(),
        |(n, scheme)| format!("n={n} scheme={}", scheme.name()),
        |&(n, scheme), seed| {
            let mut cfg = base_cfg(DnnModel::Vgg19, opts);
            cfg.seed = seed;
            cfg.n = n;
            // the sweep coordinate IS the torus size: a --topology
            // override would pin the geometry and turn the N-axis
            // into a lie, so it is cleared per cell
            cfg.topology = None;
            cfg.lambda = 25.0;
            crate::engine::run(&cfg, scheme)
        },
    );
    cells
        .into_iter()
        .zip(reports)
        .map(|((n, scheme), report)| Row {
            x: n as f64,
            scheme,
            report,
        })
        .collect()
}

/// Default N grid for the scale study (paper: 4 – 32).
pub fn default_ns() -> Vec<usize> {
    vec![4, 8, 16, 24, 32]
}

/// Ablation: Alg. 1 balanced splitting vs naive equal-layer cuts (SCC).
pub fn ablation_split(model: DnnModel, lambdas: &[f64], opts: &SweepOpts) -> Vec<(f64, Report, Report)> {
    lambdas
        .iter()
        .map(|&lambda| {
            let mut cfg = base_cfg(model, opts);
            cfg.lambda = lambda;
            let bal = Simulation::new(&cfg, SchemeKind::Scc)
                .with_split_policy(SplitPolicy::Balanced)
                .run();
            let naive = Simulation::new(&cfg, SchemeKind::Scc)
                .with_split_policy(SplitPolicy::NaiveEqualLayers)
                .run();
            (lambda, bal, naive)
        })
        .collect()
}

/// Ablation: GA quality vs iteration budget (N_iter sweep, fixed workload).
pub fn ablation_ga(iters: &[usize], opts: &SweepOpts) -> Vec<(usize, Report)> {
    iters
        .iter()
        .map(|&it| {
            let mut cfg = base_cfg(DnnModel::Vgg19, opts);
            cfg.lambda = 40.0;
            cfg.ga.n_iter = it;
            (it, Simulation::new(&cfg, SchemeKind::Scc).run())
        })
        .collect()
}

/// Render rows as the three paper panels plus ASCII charts.
pub fn render_panels_with_charts(title: &str, rows: &[Row], x_name: &str) -> String {
    let mut out = render_panels(title, rows, x_name);
    out.push('\n');
    out.push_str(&plot::ascii_chart(
        "completion rate",
        &plot::series_from_rows(rows, |r| r.completion_rate()),
        60,
        12,
    ));
    out.push_str(&plot::ascii_chart(
        "total average delay [ms]",
        &plot::series_from_rows(rows, |r| r.avg_delay_ms),
        60,
        12,
    ));
    out
}

/// Render rows as the three paper panels (completion / delay / variance).
pub fn render_panels(title: &str, rows: &[Row], x_name: &str) -> String {
    let mut xs: Vec<f64> = rows.iter().map(|r| r.x).collect();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs.dedup();
    let schemes = SchemeKind::all();
    let mut out = format!("== {title} ==\n");
    for (panel, metric) in [
        ("(a) task completion rate", 0usize),
        ("(b) total average delay [ms]", 1),
        ("(c) satellite workload variance [MFLOP^2]", 2),
    ] {
        out.push_str(&format!("-- {panel} --\n{x_name:>8}"));
        for s in schemes {
            out.push_str(&format!("{:>14}", s.name()));
        }
        out.push('\n');
        for &x in &xs {
            out.push_str(&format!("{x:>8.0}"));
            for s in schemes {
                let row = rows
                    .iter()
                    .find(|r| r.x == x && r.scheme == s)
                    .expect("missing row");
                let v = match metric {
                    0 => row.report.completion_rate(),
                    1 => row.report.avg_delay_ms,
                    _ => row.report.workload_variance,
                };
                match metric {
                    0 => out.push_str(&format!("{v:>14.4}")),
                    1 => out.push_str(&format!("{v:>14.1}")),
                    _ => out.push_str(&format!("{v:>14.3e}")),
                }
            }
            out.push('\n');
        }
    }
    out
}

/// Export rows as JSON (one object per point) for external plotting.
pub fn rows_to_json(rows: &[Row]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                let mut obj = match r.report.to_json() {
                    Json::Obj(m) => m,
                    _ => unreachable!(),
                };
                obj.insert("x".into(), Json::Num(r.x));
                obj.insert("scheme".into(), Json::Str(r.scheme.name().into()));
                Json::Obj(obj)
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_lambda_sweep_has_all_cells() {
        let opts = SweepOpts::quick();
        let rows = lambda_sweep(DnnModel::Vgg19, &[4.0, 25.0], &opts);
        assert_eq!(rows.len(), 2 * 4);
        for r in &rows {
            assert!(r.report.total_tasks > 0);
        }
    }

    #[test]
    fn render_produces_all_panels() {
        let opts = SweepOpts::quick();
        let rows = lambda_sweep(DnnModel::Vgg19, &[10.0], &opts);
        let s = render_panels("Fig test", &rows, "lambda");
        assert!(s.contains("(a) task completion rate"));
        assert!(s.contains("(b) total average delay"));
        assert!(s.contains("(c) satellite workload variance"));
        assert!(s.contains("SCC"));
    }

    #[test]
    fn json_export_parses() {
        let opts = SweepOpts::quick();
        let rows = lambda_sweep(DnnModel::Vgg19, &[10.0], &opts);
        let j = rows_to_json(&rows).to_string();
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 4);
    }

    #[test]
    fn run_cells_repeated_groups_in_order() {
        // grouped per cell in input order, repeats in repeat order —
        // regardless of worker count
        for threads in [1usize, 3] {
            let groups =
                run_cells_repeated(threads, 3, vec![10usize, 20, 30], |&x, r| x + r);
            assert_eq!(
                groups,
                vec![vec![10, 11, 12], vec![20, 21, 22], vec![30, 31, 32]]
            );
        }
        // repeats = 0 clamps to one run per cell
        let groups = run_cells_repeated(1, 0, vec![5usize], |&x, r| (x, r));
        assert_eq!(groups, vec![vec![(5, 0)]]);
    }

    #[test]
    fn repeats_average() {
        let mut opts = SweepOpts::quick();
        opts.repeats = 2;
        let r = run_point(DnnModel::Vgg19, 10.0, SchemeKind::Random, &opts);
        assert!(r.total_tasks > 0);
    }

    #[test]
    fn ablation_split_runs() {
        let opts = SweepOpts::quick();
        let rows = ablation_split(DnnModel::Vgg19, &[10.0], &opts);
        assert_eq!(rows.len(), 1);
    }

    #[test]
    fn staleness_sweep_covers_all_cells_and_serializes() {
        let mut opts = SweepOpts::quick();
        opts.engine = EngineKind::Event;
        let rows = staleness_sweep(DnnModel::Vgg19, 10.0, &[1.0], &opts);
        // instant + periodic:1 + gossip, each × 4 schemes
        assert_eq!(rows.len(), 3 * 4);
        for r in &rows {
            assert!(r.report.total_tasks > 0, "{:?}", r.dissemination);
        }
        assert!((rows[0].t_d - 0.0).abs() < 1e-12, "instant first");
        let s = render_staleness("staleness", &rows);
        assert!(s.contains("(a) task completion rate"));
        assert!(s.contains("p95 total delay"));
        assert!(s.contains("instant"));
        assert!(s.contains("periodic:1"));
        let j =
            staleness_json(DnnModel::Vgg19, 10.0, EngineKind::Event, true, &rows).to_string();
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(
            parsed.get("bench").unwrap().as_str(),
            Some("staleness")
        );
        assert_eq!(parsed.get("engine").unwrap().as_str(), Some("event"));
        assert_eq!(
            parsed.get("results").unwrap().as_arr().unwrap().len(),
            rows.len()
        );
    }

    #[test]
    fn decidecache_sweep_covers_all_cells_and_serializes() {
        let mut opts = SweepOpts::quick();
        opts.engine = EngineKind::Event;
        let rows = decidecache_sweep(DnnModel::Vgg19, 10.0, &[1.0], &opts);
        // periodic:1 × {off, on}
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.report.total_tasks > 0, "t_d={} cache={}", r.t_d, r.cache);
            assert!(r.decides > 0.0, "telemetry decides counter wired");
        }
        let off = rows.iter().find(|r| !r.cache).unwrap();
        let on = rows.iter().find(|r| r.cache).unwrap();
        // off never consults the cache; on at least records its lookups
        assert_eq!(off.hit_rate, 0.0);
        assert!(on.hit_rate >= 0.0 && on.hit_rate <= 1.0);
        let s = render_decidecache("decidecache", &rows);
        assert!(s.contains("hit rate"));
        assert!(s.contains("decides/s"));
        let j =
            decidecache_json(DnnModel::Vgg19, 10.0, EngineKind::Event, true, &rows).to_string();
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("decidecache"));
        assert_eq!(parsed.get("engine").unwrap().as_str(), Some("event"));
        assert_eq!(
            parsed.get("results").unwrap().as_arr().unwrap().len(),
            rows.len()
        );
        let first = &parsed.get("results").unwrap().as_arr().unwrap()[0];
        assert!(first.get("hit_rate").is_some());
        assert!(first.get("decides_per_s").is_some());
    }

    #[test]
    fn llm_sweep_covers_all_cells_and_serializes() {
        let mut opts = SweepOpts::quick();
        opts.engine = EngineKind::Event;
        let kinds = llm_kind_grid(&[2]);
        // one escalation-free cell + the escalating cell, each × 4 schemes
        assert_eq!(kinds.len(), 2);
        let rows = llm_sweep(DnnModel::Vgg19, 10.0, &kinds, &opts);
        assert_eq!(rows.len(), 2 * 4);
        for r in &rows {
            assert!(r.report.total_tasks > 0, "{:?}", r.kind);
            let l = r.report.llm.as_ref().expect("autoregressive cell has llm block");
            // every decoded task contributes exactly `rounds` rounds
            assert_eq!(
                l.rounds_completed + l.rounds_dropped,
                l.decode_tasks * 2,
                "{:?}",
                r.kind
            );
        }
        let s = render_llm("llm", &rows);
        assert!(s.contains("(a) task completion rate"));
        assert!(s.contains("avg round delay"));
        assert!(s.contains("time to last round"));
        let j = llm_json(DnnModel::Vgg19, 10.0, EngineKind::Event, true, &rows).to_string();
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("llm"));
        assert_eq!(parsed.get("engine").unwrap().as_str(), Some("event"));
        let results = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), rows.len());
        assert!(results[0].get("rounds_completed").is_some());
    }

    #[test]
    fn resilience_sweep_covers_all_cells_and_serializes() {
        let mut opts = SweepOpts::quick();
        opts.engine = EngineKind::Event;
        let rows = resilience_sweep(DnnModel::Vgg19, 10.0, &[0.08], &opts);
        // one rate × {drop, reoffload} × 4 schemes
        assert_eq!(rows.len(), 2 * 4);
        for r in &rows {
            assert!(
                r.report.total_tasks > 0,
                "p={} rec={}",
                r.p_fail,
                r.recovery
            );
        }
        let s = render_resilience("resilience", &rows);
        assert!(s.contains("(a) task completion rate"));
        assert!(s.contains("p95 total delay"));
        assert!(s.contains("reoffload"));
        assert!(s.contains("drop"));
        let j = resilience_json(DnnModel::Vgg19, 10.0, EngineKind::Event, true, &rows)
            .to_string();
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("resilience"));
        assert_eq!(parsed.get("engine").unwrap().as_str(), Some("event"));
        let results = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), rows.len());
        assert!(results[0].get("p_fail").is_some());
        assert!(results[0].get("recovery").is_some());
    }

    #[test]
    fn scale_ignores_topology_override() {
        // the N-sweep varies the torus size; a --topology override in the
        // opts must not pin every cell to one fixed geometry
        let plain = SweepOpts::quick();
        let mut pinned = SweepOpts::quick();
        pinned.topology = Some(crate::topology::TopologyKind::WalkerDelta {
            planes: 6,
            sats_per_plane: 6,
            phasing: 1,
        });
        let a = scale(&[4, 6], &plain);
        let b = scale(&[4, 6], &pinned);
        assert_eq!(a.len(), b.len());
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.report.total_tasks, rb.report.total_tasks);
            assert_eq!(
                ra.report.avg_delay_ms.to_bits(),
                rb.report.avg_delay_ms.to_bits(),
                "scale cell (x={}, {:?}) depended on the topology override",
                ra.x,
                ra.scheme
            );
        }
    }

    #[test]
    fn topology_sweep_covers_all_cells_and_serializes() {
        let mut opts = SweepOpts::quick();
        opts.engine = EngineKind::Event;
        let kinds = topology_grid(6);
        assert_eq!(kinds.len(), 3);
        let rows = topology_sweep(DnnModel::Vgg19, 8.0, &kinds, &opts);
        // torus + walker-delta + walker-star, each × 4 schemes
        assert_eq!(rows.len(), 3 * 4);
        for r in &rows {
            assert!(r.report.total_tasks > 0, "{:?}", r.topology);
        }
        let s = render_topology("topology", &rows);
        assert!(s.contains("(a) task completion rate"));
        assert!(s.contains("p95 total delay"));
        assert!(s.contains("torus:6"));
        assert!(s.contains("walker-delta:6x6:1"));
        assert!(s.contains("walker-star:6x6"));
        let j =
            topology_json(DnnModel::Vgg19, 8.0, EngineKind::Event, true, &rows).to_string();
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("topology"));
        assert_eq!(
            parsed.get("results").unwrap().as_arr().unwrap().len(),
            rows.len()
        );
    }

    #[test]
    fn eventsim_sweep_quick_has_all_cells() {
        let opts = SweepOpts::quick();
        let lambdas = eventsim_lambdas(true);
        assert_eq!(lambdas.len(), 2);
        let rows =
            eventsim_sweep(DnnModel::Vgg19, &lambdas, ScenarioKind::Poisson, &opts);
        assert_eq!(rows.len(), 2 * 4);
        for r in &rows {
            assert!(r.report.total_tasks > 0);
            assert!(r.report.horizon_s > 0.0);
        }
        // the full grid is the paper's λ range
        assert_eq!(eventsim_lambdas(false), default_lambdas());
    }
}
