//! Slotted constellation simulator (§III): drives arrivals → splitting
//! (Alg. 1) → offloading (a [`crate::offload::OffloadScheme`]) → execution
//! with Eq. 4 admission, accumulating the Eq. 5–9 metrics that Figs. 2–3
//! plot.
//!
//! Per slot τ:
//! 1. every decision-making satellite receives Poisson(λ) tasks from its
//!    gateway (uplink delay sampled from Eq. 1);
//! 2. each task is split into L segments by Alg. 1;
//! 3. the scheme picks the processing sequence (c_1..c_L) within A_x,
//!    deciding on the origin's disseminated [`crate::state::StateView`]
//!    (default: the slot-start snapshot, `T_d` = 1 slot);
//! 4. segments are loaded in order (Eq. 4) — the first rejection drops
//!    the task at dp = k; accepted segments accrue computation delay
//!    q_k/C (Eq. 5) and transmission delay MH·q_k·κ (Eq. 7);
//! 5. all satellites service one slot of backlog at C_x.
//!
//! Resilience ([`crate::resilience`]): under `--recovery reoffload` an
//! Eq. 4 rejection re-offloads the surviving tail (minus the rejecting
//! satellite) instead of dropping, charging the corrective re-ship of the
//! boundary activation; with link faults on, ISL transfers are priced
//! over the outage-masked alive topology and a severed chain gives up.
//! Both knobs default off and leave default runs bit-for-bit identical.

pub mod dynamics;

use crate::comm::{GatewayChannel, IslLink};
use crate::config::{EngineKind, SimConfig};
use crate::metrics::{MetricsCollector, Report, TaskOutcome};
use crate::obs::{InstantKind, Obs, SpanKind};
use crate::offload::{make_scheme_with, MigrationCost, OffloadContext, OffloadScheme, SchemeKind};
use crate::resilience::{LinkFaultInjector, OutageMap, RecoveryPolicy};
use crate::satellite::{Admission, Satellite};
use crate::splitting::balanced_split;
use crate::state::ViewTracker;
use crate::tasks::{decision_satellites, TaskGenerator, TaskKind};
use crate::topology::{Constellation, SatId};
use crate::util::rng::Pcg64;

/// How tasks are split before offloading (the ablation knob).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SplitPolicy {
    /// Alg. 1 workload-balanced binary search (the paper's scheme).
    Balanced,
    /// Naive equal-layer-count cut (ablation baseline).
    NaiveEqualLayers,
}

/// Calibrate the Eq. 7 transfer coefficient κ [s per MFLOP·hop] for a
/// configuration. Eq. 7 charges transmission as κ·q_k·MH: the workload q_k
/// is the paper's proxy for the tensor shipped at the cut. κ is calibrated
/// so κ·q̄ equals the time to push the MEAN CUT ACTIVATION over one ISL hop
/// (DESIGN.md §6) — the physical quantity is the activation at the
/// partition boundary, not the sum of all intermediate tensors. Shared by
/// the slotted and event-driven engines so their delay models agree.
pub fn calibrate_kappa(cfg: &SimConfig) -> f64 {
    calibrate_kappa_with(cfg, &IslLink::new(cfg.comm.clone()))
}

/// [`calibrate_kappa`] against a caller-supplied ISL handle: engine
/// constructors precompute one [`IslLink`] per engine and reuse it here
/// and for the autoregressive state-migration cost, instead of cloning
/// `CommConfig` (and re-deriving the Eq. 2 rate) once per derived
/// quantity.
pub fn calibrate_kappa_with(cfg: &SimConfig, isl: &IslLink) -> f64 {
    let profile = cfg.model.profile();
    let l_eff = cfg.effective_l();
    let cuts = crate::splitting::balanced_split(
        &profile.workloads(),
        l_eff,
        cfg.ga.epsilon,
    );
    let mean_cut_bytes: f64 = {
        let b: Vec<f64> = cuts
            .blocks
            .iter()
            .take(l_eff.saturating_sub(1))
            .filter(|blk| !blk.is_empty())
            .map(|blk| profile.cut_bytes(blk.end - 1))
            .collect();
        if b.is_empty() {
            profile.layers[0].output_bytes
        } else {
            b.iter().sum::<f64>() / b.len() as f64
        }
    };
    let mean_seg_mflops = profile.total_mflops() / l_eff as f64;
    isl.hop_secs(mean_cut_bytes) / mean_seg_mflops.max(1e-9)
}

/// Split a task's workload vector into L segment workloads under
/// `policy`, memoized on `scale_key` (jitter-free runs split once), and
/// write them into `out` (a caller-recycled buffer — the per-task hot
/// path copies from the cache instead of allocating). `workloads` is lazy
/// so the cache-hit path skips materializing the layer vector entirely.
/// Shared by the slotted and event-driven engines so their splitting
/// semantics can never diverge.
pub(crate) fn split_segments_cached<F>(
    policy: SplitPolicy,
    cache: &mut Option<(u64, Vec<f64>)>,
    l: usize,
    epsilon: f64,
    scale_key: u64,
    workloads: F,
    out: &mut Vec<f64>,
) where
    F: FnOnce() -> Vec<f64>,
{
    if let Some((key, cached)) = cache {
        if *key == scale_key {
            out.clear();
            out.extend_from_slice(cached);
            return;
        }
    }
    let w = workloads();
    let segs = match policy {
        SplitPolicy::Balanced => balanced_split(&w, l, epsilon).segment_workloads(),
        SplitPolicy::NaiveEqualLayers => {
            crate::splitting::naive_equal_layers(&w, l).segment_workloads()
        }
    };
    out.clear();
    out.extend_from_slice(&segs);
    *cache = Some((scale_key, segs));
}

/// A ready-to-run simulation instance.
pub struct Simulation {
    cfg: SimConfig,
    topo: Constellation,
    satellites: Vec<Satellite>,
    decision_sats: Vec<SatId>,
    scheme: Box<dyn OffloadScheme>,
    gen: TaskGenerator,
    gateway: GatewayChannel,
    kappa: f64,
    rng: Pcg64,
    /// Workload class (`cfg.effective_task_kind()`); `OneShot` leaves
    /// every pre-LLM code path untouched.
    task_kind: TaskKind,
    /// ISL seconds per hop to ship one task's KV-cache state
    /// (`IslLink::hop_secs(state_bytes)`; 0 for one-shot runs).
    state_hop_secs: f64,
    pub split_policy: SplitPolicy,
    /// Cached split (per-task splits are identical when scale jitter = 0).
    split_cache: Option<(u64, Vec<f64>)>,
    /// Optional orbital handover of the gateway link (§III-A).
    handover: Option<dynamics::Handover>,
    /// Optional transient-outage fault injection.
    faults: Option<dynamics::FaultInjector>,
    /// Optional per-ISL-link outage injection (`[resilience]` link knobs).
    link_faults: Option<LinkFaultInjector>,
    /// Outage-masked all-pairs hop table; rebuilt whenever the link
    /// injector flips any link. Never consulted without link faults.
    outages: OutageMap,
    /// Early-exit mode (§VI future work): tasks exit at the cheapest
    /// branch meeting this accuracy floor; the truncated layer vector is
    /// what gets split and offloaded.
    early_exit_workloads: Option<Vec<f64>>,
    /// Accuracy delivered under the early-exit policy (1.0 without it).
    pub delivered_accuracy: f64,
}

impl Simulation {
    pub fn new(cfg: &SimConfig, kind: SchemeKind) -> Simulation {
        cfg.validate().expect("invalid SimConfig");
        let topo = cfg.build_topology();
        let satellites: Vec<Satellite> = (0..topo.len())
            .map(|i| {
                Satellite::new(
                    i,
                    cfg.satellite.capacity_mflops,
                    cfg.satellite.max_workload_mflops,
                )
            })
            .collect();
        let decision_sats =
            decision_satellites(topo.len(), cfg.decision_fraction, cfg.seed);
        let n_areas = decision_sats.len();
        // One precomputed comm handle per engine: κ calibration and the
        // autoregressive state-migration cost share it instead of cloning
        // `CommConfig` per derived quantity.
        let isl = IslLink::new(cfg.comm.clone());
        let kappa = calibrate_kappa_with(cfg, &isl);
        let task_kind = cfg.effective_task_kind();
        let state_hop_secs = match task_kind {
            TaskKind::Autoregressive { state_bytes, .. } => isl.hop_secs(state_bytes),
            TaskKind::OneShot => 0.0,
        };
        let sim = Simulation {
            topo,
            satellites,
            decision_sats,
            scheme: make_scheme_with(
                kind,
                cfg.seed ^ 0x5EED,
                cfg.decide_threads,
                cfg.decision_cache,
            ),
            // Table I gives ONE "generated task incidence" λ for the
            // system: arrivals are Poisson(λ) network-wide, spread across
            // the gateway areas (each area draws Poisson(λ/#areas)).
            gen: TaskGenerator::new(
                cfg.seed,
                cfg.lambda / n_areas.max(1) as f64,
                cfg.model,
            ),
            gateway: GatewayChannel::new(cfg.comm.clone()),
            kappa,
            rng: Pcg64::new(cfg.seed, 0x5131),
            task_kind,
            state_hop_secs,
            split_policy: SplitPolicy::Balanced,
            split_cache: None,
            handover: None,
            faults: None,
            link_faults: None,
            outages: OutageMap::new(),
            early_exit_workloads: None,
            delivered_accuracy: 1.0,
            cfg: cfg.clone(),
        };
        // `[resilience]` wiring: route the config knobs through the same
        // builders the tests drive directly, so a config-selected run is
        // byte-identical to the equivalent builder-selected one
        // (tests/prop_resilience.rs).
        let mut sim = sim;
        if cfg.resilience.sat_faults_active() {
            sim = sim.with_faults(cfg.resilience.p_fail, cfg.resilience.p_recover);
            if let Some(tr) = &cfg.resilience.fault_trace {
                sim.faults
                    .as_mut()
                    .expect("installed by with_faults")
                    .set_trace(tr.clone());
            }
        }
        if cfg.resilience.link_faults_active() {
            sim = sim.with_link_faults();
        }
        sim
    }

    /// Builder: enable the early-exit extension (DESIGN.md: the paper's
    /// §VI future work). Tasks take the cheapest exit branch meeting
    /// `min_accuracy`; returns self with the truncated workload vector
    /// installed and `delivered_accuracy` recording the trade-off.
    pub fn with_early_exit(mut self, min_accuracy: f64) -> Simulation {
        let (accuracy, workloads) =
            crate::dnn::EarlyExitProfile::plan(self.cfg.model, min_accuracy);
        self.delivered_accuracy = accuracy;
        self.early_exit_workloads = Some(workloads);
        self.split_cache = None;
        self
    }

    /// Builder: enable orbital gateway handover.
    pub fn with_handover(mut self, h: dynamics::Handover) -> Simulation {
        self.handover = Some(h);
        self
    }

    /// Builder: enable transient satellite outages (queued work lost on
    /// failure; failed satellites are avoided by the schemes).
    pub fn with_faults(mut self, p_fail: f64, p_recover: f64) -> Simulation {
        self.faults = Some(dynamics::FaultInjector::new(
            self.topo.len(),
            p_fail,
            p_recover,
            self.cfg.seed ^ 0xFA17,
        ));
        self
    }

    /// Builder: enable per-ISL-link outages from the `[resilience]` link
    /// knobs. The outage table starts from the healthy topology; every
    /// slot advances the per-link Bernoulli chain (and scripted `link:`
    /// windows) and rebuilds the table on any flip.
    pub fn with_link_faults(mut self) -> Simulation {
        let r = &self.cfg.resilience;
        let inj = LinkFaultInjector::new(
            &self.topo,
            r.link_p_fail,
            r.link_p_recover,
            r.seam_only,
            self.cfg.seed ^ 0x11FA,
        );
        self.outages.rebuild_with(&self.topo, |a, b| inj.link_down(a, b));
        self.link_faults = Some(inj);
        self
    }

    /// Builder: enable workload jitter (varied task sizes).
    pub fn with_jitter(mut self, jitter: f64) -> Simulation {
        self.gen = TaskGenerator::new(
            self.cfg.seed,
            self.cfg.lambda / self.decision_sats.len().max(1) as f64,
            self.cfg.model,
        )
        .with_jitter(jitter);
        self.split_cache = None;
        self
    }

    /// Builder: switch the splitting policy (ablation).
    pub fn with_split_policy(mut self, p: SplitPolicy) -> Simulation {
        self.split_policy = p;
        self
    }

    /// Sticky-state surcharge the placement decision must see (see
    /// [`crate::eventsim::EventSim`]'s analogue): only autoregressive
    /// tasks under the escalation policy, whose KV-cache starts on the
    /// origin, can pay a state ship toward the chain's end.
    fn migration_cost(&self, origin: SatId) -> Option<MigrationCost> {
        match self.task_kind {
            TaskKind::Autoregressive {
                escalate: Some(_), ..
            } => Some(MigrationCost {
                from: origin,
                secs_per_hop: self.state_hop_secs,
            }),
            _ => None,
        }
    }

    /// Run the full Γ-slot simulation and produce the report.
    pub fn run(mut self) -> Report {
        let mut metrics =
            MetricsCollector::new(self.satellites.len()).retaining(self.cfg.retain_outcomes);
        let l = self.cfg.effective_l();
        let d_max = self.cfg.effective_d_max();
        let slots = self.cfg.slots;
        // Constraint 11c is a property of the NETWORK (ISL reachability
        // within D_M), so every scheme draws candidates from the same
        // decision space A_x — the comparison stays capacity-fair.
        let spaces: Vec<(SatId, Vec<SatId>)> = self
            .decision_sats
            .iter()
            .map(|&x| (x, self.topo.decision_space(x, d_max)))
            .collect();

        // Local-observation decision model (§I: "each terminal
        // independently determines offloading decisions based on its local
        // observations"): decisions consume a disseminated StateView
        // rather than live state. The default (periodic, T_d = 1 slot) is
        // the classic slot-start snapshot plus ONLY the origin's own
        // placements — what makes §V-B's herding observable: multiple
        // decision satellites pick the same "fittest" satellite before
        // its load updates. `--dissemination` swaps the staleness model.
        let mut tracker = ViewTracker::new(
            self.cfg.effective_dissemination_for(EngineKind::Slotted),
            self.satellites.len(),
            spaces.len(),
            d_max,
        );
        let mut faults = self.faults.take();
        let mut link_faults = self.link_faults.take();
        // Telemetry sink ([`crate::obs`]): every hook is a single branch on
        // its `enabled` flag, so default runs stay bit-for-bit identical
        // (`tests/prop_telemetry.rs`). The slotted clock has no event
        // queue, so spans are reconstructed from the same analytic Eq. 5/7
        // offsets that define `finish_time_s`.
        let mut obs = Obs::from_config(&self.cfg.obs);
        // Per-task scratch, reused across every task of the run (the
        // decision hot path allocates nothing in steady state).
        let mut seg_buf: Vec<f64> = Vec::new();
        let mut chrom: Vec<SatId> = Vec::new();
        // Recovery scratch: the re-decided tail chain (`--recovery
        // reoffload`), recycled like `chrom`.
        let mut retry_buf: Vec<SatId> = Vec::new();
        for slot in 0..slots {
            let t_slot = slot as f64;
            // fault injection: newly failed satellites lose queued work
            if let Some(f) = faults.as_mut() {
                let newly = f.step_at(t_slot);
                if !newly.is_empty() {
                    obs.instant(InstantKind::Fault, t_slot, newly.len());
                    // capacities vanished: cached placements must not
                    // survive the shock (counter only — no legacy path
                    // reads it, so default runs are unchanged)
                    tracker.bump_epoch();
                }
                for id in newly {
                    self.satellites[id].reset();
                }
            }
            // link outages: advance the per-link Bernoulli chain (and
            // scripted `link:` windows); any flip rebuilds the
            // outage-masked hop table and invalidates cached placements
            if let Some(lf) = link_faults.as_mut() {
                if lf.step_at(t_slot, self.cfg.resilience.fault_trace.as_ref()) {
                    self.outages
                        .rebuild_with(&self.topo, |a, b| lf.link_down(a, b));
                    tracker.bump_epoch();
                }
            }
            obs.maybe_sample(t_slot, &self.satellites);
            if let Some(h) = &self.handover {
                let dwell = h.dwell_secs() as usize;
                if slot > 0 && slot % dwell == 0 {
                    obs.instant(InstantKind::Handover, t_slot, slot / dwell);
                    // serving satellites (and decision spaces) just
                    // drifted: invalidate cached placements
                    tracker.bump_epoch();
                }
            }
            let bc_before = tracker.broadcasts();
            // gossip disseminates at slot granularity here: one snapshot
            // per slot start, before any origin acts, so a peer's state is
            // MH hops × 1 slot old in every origin's view
            if tracker.is_gossip() {
                let serving: Vec<SatId> = spaces
                    .iter()
                    .map(|(o, _)| match &self.handover {
                        Some(h) => h.serving_at(&self.topo, *o, slot),
                        None => *o,
                    })
                    .collect();
                tracker.broadcast_now(t_slot, &self.satellites, &self.topo, &serving);
            }
            tracker.advance_to(t_slot);
            if tracker.broadcasts() != bc_before {
                obs.instant(InstantKind::Broadcast, t_slot, spaces.len());
            }
            for (area, (origin0, candidates0)) in spaces.iter().enumerate() {
                // orbital handover: the serving satellite (and with it the
                // decision space) drifts along the orbit
                let (origin, candidates_owned);
                match &self.handover {
                    Some(h) => {
                        origin = h.serving_at(&self.topo, *origin0, slot);
                        candidates_owned =
                            self.topo.decision_space(origin, d_max);
                    }
                    None => {
                        origin = *origin0;
                        candidates_owned = candidates0.clone();
                    }
                }
                // outage avoidance: schemes only see healthy candidates
                let candidates: Vec<usize> = match &faults {
                    Some(f) => f.healthy(&candidates_owned),
                    None => candidates_owned,
                };
                let candidates = &candidates;
                // this origin's view resyncs only when a new broadcast
                // window opened (every batch at the default T_d = 1 slot)
                tracker.sync_batch(area, &self.satellites);
                let arrivals = self.gen.arrivals(origin, slot);
                for task in arrivals {
                    let scale_key = (task.scale * 1e6) as u64;
                    let early_exit = &self.early_exit_workloads;
                    split_segments_cached(
                        self.split_policy,
                        &mut self.split_cache,
                        l,
                        self.cfg.ga.epsilon,
                        scale_key,
                        || match early_exit {
                            Some(w) => w.iter().map(|x| x * task.scale).collect(),
                            None => task.layer_workloads(),
                        },
                        &mut seg_buf,
                    );
                    let segments = &seg_buf;
                    // scheme decision under the origin's disseminated view
                    {
                        let ctx = OffloadContext {
                            topo: &self.topo,
                            view: tracker.view(area, &self.satellites),
                            origin,
                            candidates,
                            segments,
                            kappa: self.kappa,
                            ga: &self.cfg.ga,
                            migration: self.migration_cost(origin),
                            outages: match &link_faults {
                                Some(_) => Some(&self.outages),
                                None => None,
                            },
                        };
                        self.scheme.decide_into(&ctx, &mut chrom);
                    }
                    obs.instant(InstantKind::Decide, task.arrival_time_s, origin);
                    // the origin tracks its own placements in its view
                    for (&c, &q) in chrom.iter().zip(segments) {
                        tracker.record_local(area, c, q, t_slot, &self.satellites);
                    }
                    debug_assert_eq!(chrom.len(), segments.len());

                    // execute: walk segments, Eq. 4 admission, Eq. 5/7 delays
                    let uplink = self.gateway.upload_secs(602_112.0 * task.scale, &mut self.rng);
                    obs.seg_span(
                        SpanKind::Uplink,
                        task.arrival_time_s,
                        task.arrival_time_s + uplink,
                        origin,
                        task.id,
                        0,
                    );
                    let mut comp = 0.0f64;
                    let mut tran = 0.0f64;
                    let mut drop_point = l + 1; // completed
                    let mut dropped_at = None;
                    // satellite executing the last admitted segment (the
                    // chain's end — where decode rounds run by default)
                    let mut last_exec_sat = origin;
                    // Trace cursor: the analytic offsets Eq. 5/7 charge
                    // against the arrival, laid out back-to-back exactly
                    // as `finish_time_s` accumulates them.
                    let mut cursor = task.arrival_time_s;
                    // Per-task recovery budget (`--recovery reoffload`):
                    // the walk is a `while` so a retry can re-attempt the
                    // same index on a freshly spliced chain.
                    let mut retries = 0u32;
                    let mut recovered = false;
                    let mut k = 0usize;
                    while k < chrom.len() {
                        let c = chrom[k];
                        let q = segments[k];
                        if q == 0.0 {
                            k += 1;
                            continue; // padded empty block
                        }
                        match self.satellites[c].try_load(q) {
                            Admission::Accepted => {
                                last_exec_sat = c;
                                let dt = self.satellites[c].service_secs_with_queue(q);
                                comp += dt;
                                metrics.sat(c).comp_delay_s += dt;
                                metrics.sat(c).assigned_mflops += q;
                                metrics.sat(c).segments_executed += 1;
                                obs.seg_span(
                                    SpanKind::Exec,
                                    cursor,
                                    cursor + dt,
                                    c,
                                    task.id,
                                    k,
                                );
                                cursor += dt;
                                if k + 1 < chrom.len() {
                                    // link faults on: price the transfer
                                    // over the alive topology (detours
                                    // cost extra hops; a severed next hop
                                    // strands the chain)
                                    let planned = self.topo.hops(c, chrom[k + 1]);
                                    let alive = match &link_faults {
                                        Some(_) => self.outages.hops(c, chrom[k + 1]),
                                        None => Some(planned),
                                    };
                                    let hops = match alive {
                                        Some(h) => {
                                            if h > planned {
                                                metrics.reroute();
                                                obs.instant(
                                                    InstantKind::Reroute,
                                                    cursor,
                                                    c,
                                                );
                                            }
                                            h as f64
                                        }
                                        None => {
                                            metrics.recovery_giveup();
                                            drop_point = k + 2; // next seg unreachable
                                            dropped_at = Some(k + 1);
                                            break;
                                        }
                                    };
                                    let tt = hops * q * self.kappa;
                                    tran += tt;
                                    metrics.sat(c).tran_delay_s += tt;
                                    obs.seg_span(
                                        SpanKind::Isl,
                                        cursor,
                                        cursor + tt,
                                        c,
                                        task.id,
                                        k + 1,
                                    );
                                    cursor += tt;
                                }
                                k += 1;
                            }
                            Admission::Rejected => {
                                // --recovery reoffload: instead of
                                // dropping, re-run the offload decision
                                // for the surviving tail segments[k..]
                                // over the healthy candidates minus the
                                // rejecting satellite, splice the new
                                // tail into the chain, charge the
                                // corrective re-ship of the boundary
                                // activation, and re-attempt index k.
                                if let RecoveryPolicy::Reoffload { max_retries } =
                                    self.cfg.resilience.recovery
                                {
                                    metrics.sat(c).segments_rejected += 1;
                                    let within_deadline = cursor - task.arrival_time_s
                                        <= self.cfg.resilience.deadline_s;
                                    let retry_cands: Vec<SatId> = candidates
                                        .iter()
                                        .copied()
                                        .filter(|&x| x != c)
                                        .collect();
                                    if retries < max_retries
                                        && within_deadline
                                        && !retry_cands.is_empty()
                                    {
                                        {
                                            let ctx = OffloadContext {
                                                topo: &self.topo,
                                                view: tracker
                                                    .view(area, &self.satellites),
                                                origin,
                                                candidates: &retry_cands,
                                                segments: &segments[k..],
                                                kappa: self.kappa,
                                                ga: &self.cfg.ga,
                                                migration: self.migration_cost(origin),
                                                outages: match &link_faults {
                                                    Some(_) => Some(&self.outages),
                                                    None => None,
                                                },
                                            };
                                            self.scheme
                                                .decide_into(&ctx, &mut retry_buf);
                                        }
                                        // re-ship the k-1 activation from
                                        // the chain's live end to the new
                                        // placement (Eq. 7 pricing)
                                        let from =
                                            if k > 0 { chrom[k - 1] } else { origin };
                                        let q_in = segments[k.saturating_sub(1)];
                                        let re_tt = self.topo.hops(from, retry_buf[0])
                                            as f64
                                            * q_in
                                            * self.kappa;
                                        chrom.truncate(k);
                                        chrom.extend_from_slice(&retry_buf);
                                        tran += re_tt;
                                        metrics.sat(from).tran_delay_s += re_tt;
                                        // rejection recovery re-ships but
                                        // never re-executes: rework is 0
                                        metrics.recovery_retry(0.0, re_tt);
                                        obs.instant(
                                            InstantKind::Recover,
                                            cursor,
                                            origin,
                                        );
                                        cursor += re_tt;
                                        retries += 1;
                                        recovered = true;
                                        continue;
                                    }
                                    metrics.recovery_giveup();
                                    drop_point = k + 1;
                                    dropped_at = Some(k);
                                    break;
                                }
                                metrics.sat(c).segments_rejected += 1;
                                drop_point = k + 1; // dp ∈ {1..L} (11d)
                                dropped_at = Some(k);
                                break;
                            }
                        }
                    }
                    // learning hook (DQN; skipped — context and all — for
                    // schemes whose observe is a no-op)
                    if self.scheme.learns() {
                        let ctx = OffloadContext {
                            topo: &self.topo,
                            view: tracker.view(area, &self.satellites),
                            origin,
                            candidates,
                            segments,
                            kappa: self.kappa,
                            ga: &self.cfg.ga,
                            migration: self.migration_cost(origin),
                            outages: match &link_faults {
                                Some(_) => Some(&self.outages),
                                None => None,
                            },
                        };
                        self.scheme
                            .observe(&ctx, &chrom, dropped_at, comp + tran);
                    }
                    // Decode phase (autoregressive tasks whose prefill
                    // chain was fully admitted): the slotted analogue of
                    // the event engine's RoundDone/Escalate flow. Rounds
                    // skip Eq. 4 admission and are charged analytically —
                    // backlog wait plus service, `(loaded + flops)/C` —
                    // the slot-quantized stand-in for the FIFO wait.
                    if drop_point > l {
                        if let TaskKind::Autoregressive {
                            rounds,
                            decode_flops,
                            escalate,
                            ..
                        } = self.task_kind
                        {
                            metrics.decode_started();
                            let deadline = self.cfg.llm.round_deadline_s;
                            let small = self.cfg.llm.small_model_factor;
                            let mut decode_sat = if escalate.is_some() {
                                origin
                            } else {
                                last_exec_sat
                            };
                            let mut escalated = false;
                            let mut deficit = 0.0f64;
                            let mut first_round_end = cursor;
                            for round in 1..=rounds {
                                let flops = if escalate.is_some() && !escalated {
                                    decode_flops * small
                                } else {
                                    decode_flops
                                };
                                let s = &self.satellites[decode_sat];
                                let dt = (s.loaded() + flops) / s.capacity_mflops;
                                if dt > deadline {
                                    // this round and everything behind it
                                    // miss the per-round deadline
                                    metrics.rounds_dropped((rounds - (round - 1)) as u64);
                                    drop_point = l;
                                    break;
                                }
                                comp += dt;
                                metrics.sat(decode_sat).comp_delay_s += dt;
                                metrics.sat(decode_sat).assigned_mflops += flops;
                                metrics.round_done(dt);
                                cursor += dt;
                                if round == 1 {
                                    first_round_end = cursor;
                                }
                                if round == rounds {
                                    metrics.decode_finished(
                                        first_round_end - task.arrival_time_s,
                                        cursor - task.arrival_time_s,
                                    );
                                } else if let Some(thresh) = escalate {
                                    deficit += dt;
                                    if !escalated && deficit > thresh {
                                        // ship the KV-cache to the chain's
                                        // end and decode on the large model
                                        escalated = true;
                                        let to = last_exec_sat;
                                        let mig = self.state_hop_secs
                                            * self.topo.hops(decode_sat, to) as f64;
                                        tran += mig;
                                        metrics.sat(decode_sat).tran_delay_s += mig;
                                        cursor += mig;
                                        decode_sat = to;
                                    }
                                }
                            }
                        }
                    }
                    obs.task_span(
                        task.arrival_time_s,
                        task.arrival_time_s + comp + tran,
                        origin,
                        task.id,
                        drop_point > l,
                    );
                    // a retried chain that still completed is a recovery
                    if recovered && drop_point > l {
                        metrics.task_recovered();
                    }
                    metrics.record(TaskOutcome {
                        task_id: task.id,
                        origin,
                        drop_point,
                        l,
                        comp_delay_s: comp,
                        tran_delay_s: tran,
                        uplink_delay_s: uplink,
                        // slotted clock: the slot boundary plus the
                        // analytic delays stands in for the event instant
                        finish_time_s: task.arrival_time_s + comp + tran,
                    });
                }
            }
            // all satellites service one slot
            for s in &mut self.satellites {
                s.service_slot();
            }
        }
        obs.write_trace();
        let mut report = metrics.finish(slots);
        if obs.enabled() {
            report.telemetry = Some(obs.telemetry_json(
                "slotted",
                tracker.broadcasts(),
                self.scheme.telemetry(),
            ));
        }
        report
    }

    /// Access to the per-satellite end state (used by tests/examples).
    pub fn satellites(&self) -> &[Satellite] {
        &self.satellites
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::DnnModel;

    fn small_cfg(kind_model: DnnModel, lambda: f64) -> SimConfig {
        SimConfig {
            n: 6,
            slots: 10,
            lambda,
            model: kind_model,
            seed: 7,
            ..SimConfig::default()
        }
    }

    #[test]
    fn runs_and_produces_tasks() {
        let cfg = small_cfg(DnnModel::Vgg19, 5.0);
        let r = Simulation::new(&cfg, SchemeKind::Random).run();
        assert!(r.total_tasks > 0);
        assert_eq!(r.total_tasks, r.completed_tasks + r.dropped_tasks);
        assert!(r.completion_rate() > 0.0);
        assert!(r.slots_run == 10);
        // off-is-free: default runs never allocate the resilience block
        assert!(r.resilience.is_none());
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = small_cfg(DnnModel::Vgg19, 8.0);
        let a = Simulation::new(&cfg, SchemeKind::Scc).run();
        let b = Simulation::new(&cfg, SchemeKind::Scc).run();
        assert_eq!(a.total_tasks, b.total_tasks);
        assert_eq!(a.completed_tasks, b.completed_tasks);
        assert!((a.avg_delay_ms - b.avg_delay_ms).abs() < 1e-9);
    }

    #[test]
    fn different_seeds_differ() {
        let mut cfg = small_cfg(DnnModel::Vgg19, 8.0);
        let a = Simulation::new(&cfg, SchemeKind::Random).run();
        cfg.seed = 99;
        let b = Simulation::new(&cfg, SchemeKind::Random).run();
        assert_ne!(a.total_tasks, b.total_tasks);
    }

    #[test]
    fn all_schemes_run_both_models() {
        for model in [DnnModel::Vgg19, DnnModel::Resnet101] {
            for kind in SchemeKind::all() {
                let cfg = small_cfg(model, 3.0);
                let r = Simulation::new(&cfg, kind).run();
                assert!(r.total_tasks > 0, "{kind:?}/{model:?}");
            }
        }
    }

    #[test]
    fn overload_causes_drops() {
        // tiny capacity + heavy arrivals: drops must appear
        let mut cfg = small_cfg(DnnModel::Vgg19, 40.0);
        cfg.satellite.max_workload_mflops = 20_000.0;
        cfg.slots = 12;
        let r = Simulation::new(&cfg, SchemeKind::Random).run();
        assert!(r.dropped_tasks > 0, "expected drops: {r:?}");
        assert!(r.completion_rate() < 1.0);
    }

    #[test]
    fn light_load_mostly_completes() {
        let mut cfg = small_cfg(DnnModel::Vgg19, 0.5);
        cfg.satellite.max_workload_mflops = 400_000.0;
        let r = Simulation::new(&cfg, SchemeKind::Scc).run();
        assert!(
            r.completion_rate() > 0.95,
            "rate = {}",
            r.completion_rate()
        );
    }

    #[test]
    fn scc_beats_random_under_pressure() {
        let mut cfg = small_cfg(DnnModel::Vgg19, 20.0);
        cfg.slots = 15;
        cfg.satellite.max_workload_mflops = 60_000.0;
        let scc = Simulation::new(&cfg, SchemeKind::Scc).run();
        let rnd = Simulation::new(&cfg, SchemeKind::Random).run();
        assert!(
            scc.completion_rate() >= rnd.completion_rate() - 0.02,
            "SCC {} vs Random {}",
            scc.completion_rate(),
            rnd.completion_rate()
        );
    }

    #[test]
    fn delays_positive_when_tasks_complete() {
        let cfg = small_cfg(DnnModel::Resnet101, 2.0);
        let r = Simulation::new(&cfg, SchemeKind::Rrp).run();
        if r.completed_tasks > 0 {
            assert!(r.avg_delay_ms > 0.0);
            assert!(r.avg_comp_ms > 0.0);
        }
    }

    #[test]
    fn naive_split_policy_runs() {
        let cfg = small_cfg(DnnModel::Vgg19, 5.0);
        let r = Simulation::new(&cfg, SchemeKind::Scc)
            .with_split_policy(SplitPolicy::NaiveEqualLayers)
            .run();
        assert!(r.total_tasks > 0);
    }

    #[test]
    fn handover_shifts_decision_satellites() {
        let cfg = small_cfg(DnnModel::Vgg19, 5.0);
        let r = Simulation::new(&cfg, SchemeKind::Scc)
            .with_handover(dynamics::Handover {
                dwell_slots: 2,
                direction: 1,
            })
            .run();
        assert!(r.total_tasks > 0);
    }

    #[test]
    fn faults_reduce_completion_under_load() {
        let mut cfg = small_cfg(DnnModel::Vgg19, 30.0);
        cfg.slots = 12;
        let clean = Simulation::new(&cfg, SchemeKind::Random).run();
        let faulty = Simulation::new(&cfg, SchemeKind::Random)
            .with_faults(0.10, 0.3)
            .run();
        assert!(faulty.total_tasks > 0);
        assert!(
            faulty.completion_rate() <= clean.completion_rate() + 0.05,
            "faults should not improve completion: {} vs {}",
            faulty.completion_rate(),
            clean.completion_rate()
        );
    }

    #[test]
    fn config_driven_faults_match_builder_slotted() {
        let mut cfg = small_cfg(DnnModel::Vgg19, 12.0);
        cfg.slots = 12;
        cfg.resilience.p_fail = 0.08;
        cfg.resilience.p_recover = 0.4;
        let via_cfg = Simulation::new(&cfg, SchemeKind::Scc).run();
        let mut legacy = cfg.clone();
        legacy.resilience = Default::default();
        let via_builder = Simulation::new(&legacy, SchemeKind::Scc)
            .with_faults(0.08, 0.4)
            .run();
        assert_eq!(
            via_cfg.to_json().to_string(),
            via_builder.to_json().to_string()
        );
    }

    #[test]
    fn reoffload_retries_rejections_slotted() {
        let mut cfg = small_cfg(DnnModel::Vgg19, 40.0);
        cfg.slots = 12;
        cfg.satellite.max_workload_mflops = 20_000.0;
        cfg.resilience.recovery = RecoveryPolicy::Reoffload { max_retries: 2 };
        let r = Simulation::new(&cfg, SchemeKind::Random).run();
        assert!(r.total_tasks > 0);
        assert_eq!(r.total_tasks, r.completed_tasks + r.dropped_tasks);
        let res = r.resilience.as_ref().expect("resilience block present");
        assert!(res.retries > 0, "overload must trigger retries: {res:?}");
        assert!(res.retries >= res.recovered_tasks);
    }

    #[test]
    fn link_outages_slotted_run_and_conserve() {
        let mut cfg = small_cfg(DnnModel::Vgg19, 8.0);
        cfg.slots = 12;
        cfg.resilience.link_p_fail = 0.25;
        cfg.resilience.link_p_recover = 0.2;
        let r = Simulation::new(&cfg, SchemeKind::Scc).run();
        assert!(r.total_tasks > 0);
        assert_eq!(r.total_tasks, r.completed_tasks + r.dropped_tasks);
    }

    #[test]
    fn scripted_trace_slotted_runs() {
        let mut cfg = small_cfg(DnnModel::Vgg19, 6.0);
        cfg.resilience.fault_trace = Some(
            crate::resilience::FaultTrace::parse_str("1 4 sat:2\n2 6 link:0-1\n")
                .unwrap(),
        );
        let r = Simulation::new(&cfg, SchemeKind::Random).run();
        assert!(r.total_tasks > 0);
        assert_eq!(r.total_tasks, r.completed_tasks + r.dropped_tasks);
    }

    #[test]
    fn early_exit_cuts_delay_at_accuracy_cost() {
        let mut cfg = small_cfg(DnnModel::Vgg19, 10.0);
        cfg.slots = 8;
        let full = Simulation::new(&cfg, SchemeKind::Scc).run();
        let sim = Simulation::new(&cfg, SchemeKind::Scc).with_early_exit(0.80);
        let acc = sim.delivered_accuracy;
        let exited = sim.run();
        assert!(acc < 1.0, "an exit should have been taken");
        if full.completed_tasks > 0 && exited.completed_tasks > 0 {
            assert!(
                exited.avg_delay_ms < full.avg_delay_ms,
                "early exit must cut delay: {} vs {}",
                exited.avg_delay_ms,
                full.avg_delay_ms
            );
        }
    }

    #[test]
    fn autoregressive_rounds_conserve_slotted() {
        let mut cfg = small_cfg(DnnModel::Vgg19, 3.0);
        cfg.task_kind = Some(TaskKind::Autoregressive {
            rounds: 4,
            decode_flops: 150.0,
            state_bytes: 1e5,
            escalate: None,
        });
        let r = Simulation::new(&cfg, SchemeKind::Scc).run();
        assert!(r.total_tasks > 0);
        assert_eq!(r.total_tasks, r.completed_tasks + r.dropped_tasks);
        let l = r.llm.as_ref().expect("llm block present");
        assert!(l.decode_tasks > 0);
        assert_eq!(l.rounds_completed + l.rounds_dropped, l.decode_tasks * 4);
        assert!(l.time_to_last_round_ms >= l.time_to_first_round_ms);
    }

    #[test]
    fn escalation_and_deadline_run_slotted() {
        let mut cfg = small_cfg(DnnModel::Vgg19, 3.0);
        cfg.task_kind = Some(TaskKind::Autoregressive {
            rounds: 6,
            decode_flops: 150.0,
            state_bytes: 1e6,
            escalate: Some(0.0),
        });
        let r = Simulation::new(&cfg, SchemeKind::Scc).run();
        let l = r.llm.as_ref().expect("llm block present");
        assert_eq!(l.rounds_completed + l.rounds_dropped, l.decode_tasks * 6);
        // an impossibly tight deadline drops every decoding task
        cfg.llm.round_deadline_s = 1e-9;
        let r2 = Simulation::new(&cfg, SchemeKind::Scc).run();
        let l2 = r2.llm.as_ref().expect("llm block present");
        assert_eq!(l2.rounds_completed, 0);
        assert_eq!(r2.completed_tasks, 0);
    }

    #[test]
    fn oneshot_report_has_no_llm_block_slotted() {
        let mut cfg = small_cfg(DnnModel::Vgg19, 3.0);
        cfg.task_kind = Some(TaskKind::OneShot);
        let r = Simulation::new(&cfg, SchemeKind::Scc).run();
        assert!(r.llm.is_none());
    }

    #[test]
    fn jitter_varies_task_scale() {
        let cfg = small_cfg(DnnModel::Vgg19, 5.0);
        let r = Simulation::new(&cfg, SchemeKind::Random)
            .with_jitter(0.2)
            .run();
        assert!(r.total_tasks > 0);
    }

    #[test]
    fn walker_topologies_run_all_schemes() {
        use crate::topology::TopologyKind;
        for topo in [
            TopologyKind::WalkerDelta {
                planes: 6,
                sats_per_plane: 6,
                phasing: 1,
            },
            TopologyKind::WalkerStar {
                planes: 6,
                sats_per_plane: 6,
            },
        ] {
            for kind in SchemeKind::all() {
                let mut cfg = small_cfg(DnnModel::Vgg19, 4.0);
                cfg.topology = Some(topo.clone());
                let r = Simulation::new(&cfg, kind).run();
                assert!(r.total_tasks > 0, "{kind:?}/{topo:?}");
                assert_eq!(r.total_tasks, r.completed_tasks + r.dropped_tasks);
            }
        }
    }

    #[test]
    fn walker_handover_runs() {
        use crate::topology::TopologyKind;
        let mut cfg = small_cfg(DnnModel::Vgg19, 5.0);
        cfg.topology = Some(TopologyKind::WalkerStar {
            planes: 6,
            sats_per_plane: 6,
        });
        let r = Simulation::new(&cfg, SchemeKind::Scc)
            .with_handover(dynamics::Handover {
                dwell_slots: 2,
                direction: -1,
            })
            .run();
        assert!(r.total_tasks > 0);
    }
}
