//! Constellation dynamics extensions (§III-A: "each satellite orbits the
//! Earth periodically to enable the establishment of satellite-ground
//! connections"): gateway→satellite handover as the constellation drifts
//! overhead, and fault injection (transient satellite outages) for
//! robustness evaluation.

use crate::resilience::FaultTrace;
use crate::topology::{Constellation, SatId};
use crate::util::rng::Pcg64;

/// Orbital handover model: a ground area's serving (decision) satellite
/// advances along its orbit every `dwell_slots` slots — the in-orbit
/// neighbour takes over the gateway link, inheriting the decision role.
#[derive(Clone, Debug)]
pub struct Handover {
    /// Slots a satellite stays overhead before handing the gateway over.
    pub dwell_slots: usize,
    /// +1 / -1: direction of apparent ground-track motion along the orbit.
    pub direction: isize,
}

impl Default for Handover {
    fn default() -> Self {
        // LEO pass ≈ 8 min over a gateway; at 1 s slots the dwell is long
        // relative to experiment horizons, so the default keeps handover
        // observable but not dominant.
        Handover {
            dwell_slots: 10,
            direction: 1,
        }
    }
}

impl Handover {
    /// Effective dwell (clamped to ≥ 1 slot) — the single place the
    /// `dwell_slots` floor is applied.
    fn dwell(&self) -> usize {
        self.dwell_slots.max(1)
    }

    /// The decision satellite serving an area at `slot`, given the area's
    /// initial serving satellite. Motion is along the satellite's own
    /// orbital plane.
    pub fn serving_at(&self, topo: &Constellation, initial: SatId, slot: usize) -> SatId {
        self.serving_after(topo, initial, slot / self.dwell())
    }

    /// The serving satellite after `steps` completed handovers (the event
    /// engine advances this one step per scheduled `Handover` event). The
    /// gateway link advances along the actual orbital plane of the
    /// topology — the in-orbit ring on the torus, the plane ring on a
    /// Walker — never across planes.
    pub fn serving_after(&self, topo: &Constellation, initial: SatId, steps: usize) -> SatId {
        topo.advance_in_plane(initial, steps as isize * self.direction)
    }

    /// Seconds between handovers on the continuous clock (1 slot = 1 s).
    pub fn dwell_secs(&self) -> f64 {
        self.dwell() as f64
    }
}

/// Transient-outage fault injector: each slot, a healthy satellite fails
/// with `p_fail` (losing its queued work — a radiation upset / safe-mode
/// event), and a failed one recovers with `p_recover`.
#[derive(Clone, Debug)]
pub struct FaultInjector {
    pub p_fail: f64,
    pub p_recover: f64,
    down: Vec<bool>,
    /// Scripted-trace overlay: a satellite is effectively down when
    /// `down[s] || forced[s]`. Empty (all-false) without a trace, so
    /// trace-free runs behave exactly as before.
    forced: Vec<bool>,
    trace: Option<FaultTrace>,
    rng: Pcg64,
    /// Cumulative outage events (diagnostics).
    pub failures: u64,
}

impl FaultInjector {
    /// Period of the fault chain on the continuous clock. Both engines
    /// advance the same per-second Bernoulli process: the slotted engine
    /// calls [`FaultInjector::step`] once per slot, the event engine
    /// schedules a `Fault` event every `TICK_SECS`.
    pub const TICK_SECS: f64 = 1.0;

    pub fn new(n_sats: usize, p_fail: f64, p_recover: f64, seed: u64) -> FaultInjector {
        assert!((0.0..=1.0).contains(&p_fail) && (0.0..=1.0).contains(&p_recover));
        FaultInjector {
            p_fail,
            p_recover,
            down: vec![false; n_sats],
            forced: vec![false; n_sats],
            trace: None,
            rng: Pcg64::new(seed, 0xFA11),
            failures: 0,
        }
    }

    /// Attach a scripted fault trace: its `sat:` windows force outages
    /// on top of the Bernoulli process at every [`FaultInjector::step_at`].
    pub fn set_trace(&mut self, trace: FaultTrace) {
        self.trace = Some(trace);
    }

    /// Advance one slot; returns the ids that newly failed (their queued
    /// work is lost — the caller resets those satellites).
    pub fn step(&mut self) -> Vec<SatId> {
        let mut newly_failed = Vec::new();
        for (id, d) in self.down.iter_mut().enumerate() {
            if *d {
                if self.rng.bool(self.p_recover) {
                    *d = false;
                }
            } else if self.rng.bool(self.p_fail) {
                *d = true;
                self.failures += 1;
                newly_failed.push(id);
            }
        }
        newly_failed
    }

    /// Advance one slot at simulation time `t`: the Bernoulli
    /// [`FaultInjector::step`] (identical draw order), then the scripted
    /// trace overlay. Returns ids whose *effective* state newly flipped
    /// to down. Without a trace this is bit-for-bit `step()`.
    pub fn step_at(&mut self, t: f64) -> Vec<SatId> {
        let trace = match self.trace.take() {
            None => return self.step(),
            Some(tr) => tr,
        };
        let before: Vec<bool> = (0..self.down.len()).map(|s| self.is_down(s)).collect();
        self.step();
        for s in 0..self.forced.len() {
            self.forced[s] = trace.sat_down_at(s, t);
        }
        self.trace = Some(trace);
        (0..self.down.len())
            .filter(|&s| self.is_down(s) && !before[s])
            .collect()
    }

    /// Is the fault process live at time `t`? False when no Bernoulli
    /// failures can occur, nothing is currently down, and no trace
    /// window can still open — the event engine stops scheduling `Fault`
    /// ticks then.
    pub fn active_after(&self, t: f64) -> bool {
        self.p_fail > 0.0
            || self.down_count() > 0
            || self.trace.as_ref().is_some_and(|tr| tr.last_end() > t)
    }

    pub fn is_down(&self, s: SatId) -> bool {
        self.down[s] || self.forced[s]
    }

    /// Currently-down count (Bernoulli ∪ scripted).
    pub fn down_count(&self) -> usize {
        (0..self.down.len()).filter(|&s| self.is_down(s)).count()
    }

    /// Filter a candidate list to healthy satellites (never empties the
    /// list: if all candidates are down, the original is returned so the
    /// admission check produces the drop).
    pub fn healthy<'a>(&self, candidates: &'a [SatId]) -> Vec<SatId> {
        let up: Vec<SatId> = candidates
            .iter()
            .copied()
            .filter(|&c| !self.is_down(c))
            .collect();
        if up.is_empty() {
            candidates.to_vec()
        } else {
            up
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handover_advances_along_orbit() {
        let t = Constellation::torus(8);
        let h = Handover {
            dwell_slots: 5,
            direction: 1,
        };
        let s0 = 3 * 8 + 2; // plane 3, slot 2
        assert_eq!(h.serving_at(&t, s0, 0), s0);
        assert_eq!(h.serving_at(&t, s0, 4), s0);
        assert_eq!(h.serving_at(&t, s0, 5), s0 + 1);
        assert_eq!(h.serving_at(&t, s0, 10), s0 + 2);
        // wraps around the ring
        assert_eq!(h.serving_at(&t, s0, 5 * 8), s0);
    }

    #[test]
    fn handover_stays_in_same_orbit() {
        for t in [
            Constellation::torus(6),
            Constellation::walker_delta(6, 6, 2),
            Constellation::walker_star(6, 6),
        ] {
            let h = Handover::default();
            let s0 = 2 * 6; // plane 2, slot 0
            for slot in 0..100 {
                let (o, _) = t.coords(h.serving_at(&t, s0, slot));
                assert_eq!(o, 2);
            }
        }
    }

    #[test]
    fn serving_after_matches_slot_view() {
        let t = Constellation::torus(8);
        let h = Handover {
            dwell_slots: 4,
            direction: -1,
        };
        let s0 = 8 + 6; // plane 1, slot 6
        for slot in 0..40 {
            assert_eq!(
                h.serving_at(&t, s0, slot),
                h.serving_after(&t, s0, slot / 4)
            );
        }
        assert!((h.dwell_secs() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn handover_wraps_within_a_walker_plane() {
        // a 3x4 star: plane 1 is slots 4..8; backwards motion wraps in it
        let t = Constellation::walker_star(3, 4);
        let h = Handover {
            dwell_slots: 1,
            direction: -1,
        };
        let s0 = 4; // plane 1, slot 0
        assert_eq!(h.serving_at(&t, s0, 1), 7);
        assert_eq!(h.serving_at(&t, s0, 4), s0);
    }

    #[test]
    fn faults_fail_and_recover() {
        let mut f = FaultInjector::new(50, 0.3, 0.5, 1);
        let mut saw_fail = false;
        let mut saw_recover = false;
        let mut prev_down = 0;
        for _ in 0..60 {
            let newly = f.step();
            saw_fail |= !newly.is_empty();
            let now_down = f.down_count();
            saw_recover |= now_down < prev_down + newly.len();
            prev_down = now_down;
        }
        assert!(saw_fail);
        assert!(saw_recover);
        assert!(f.failures > 0);
    }

    #[test]
    fn zero_rates_are_inert() {
        let mut f = FaultInjector::new(10, 0.0, 1.0, 2);
        for _ in 0..20 {
            assert!(f.step().is_empty());
        }
        assert_eq!(f.down_count(), 0);
    }

    #[test]
    fn healthy_filter_never_empty() {
        let mut f = FaultInjector::new(4, 1.0, 0.0, 3);
        f.step(); // everything fails
        assert_eq!(f.down_count(), 4);
        let cands = vec![0, 1, 2, 3];
        assert_eq!(f.healthy(&cands), cands);
        let mut g = FaultInjector::new(4, 0.0, 1.0, 4);
        g.step();
        assert_eq!(g.healthy(&cands).len(), 4);
    }

    #[test]
    fn step_at_without_trace_is_step() {
        let mut a = FaultInjector::new(20, 0.25, 0.4, 9);
        let mut b = FaultInjector::new(20, 0.25, 0.4, 9);
        for t in 0..50 {
            assert_eq!(a.step(), b.step_at(t as f64));
            for s in 0..20 {
                assert_eq!(a.is_down(s), b.is_down(s));
            }
        }
    }

    #[test]
    fn scripted_trace_forces_and_releases() {
        let mut f = FaultInjector::new(8, 0.0, 1.0, 5);
        f.set_trace(FaultTrace::parse_str("2 4 sat:3\n").unwrap());
        assert_eq!(f.step_at(0.0), Vec::<SatId>::new());
        assert!(!f.is_down(3));
        assert_eq!(f.step_at(2.0), vec![3]);
        assert!(f.is_down(3));
        assert_eq!(f.step_at(3.0), Vec::<SatId>::new()); // still down, not newly
        assert_eq!(f.step_at(4.0), Vec::<SatId>::new()); // window closed
        assert!(!f.is_down(3));
        assert!(f.active_after(1.0)); // window still ahead at t=1
        assert!(!f.active_after(5.0)); // nothing can happen after 5
    }

    #[test]
    fn active_after_tracks_bernoulli_and_down() {
        let f = FaultInjector::new(4, 0.1, 0.5, 1);
        assert!(f.active_after(1e9)); // p_fail > 0: always live
        let mut g = FaultInjector::new(4, 0.0, 0.5, 1);
        assert!(!g.active_after(0.0));
        g.set_trace(FaultTrace::parse_str("0 2 sat:1\n").unwrap());
        g.step_at(0.0);
        assert!(g.is_down(1));
        assert!(g.active_after(3.0)); // someone is down -> still live
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut f = FaultInjector::new(30, 0.2, 0.4, seed);
            (0..40).map(|_| f.step().len()).collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }
}
