//! *DQN* baseline (§V-A): a deep-Q-network agent that "endeavors to
//! minimize the task drop rate and delay based on current observed
//! network states".
//!
//! Design (the paper leaves the implementation unspecified; see
//! DESIGN.md §4): segments are placed one at a time by walking the grid —
//! from the previous segment's satellite the agent picks among
//! `N_ACTIONS = 5` moves (stay, or hop to one of the 4 ISL neighbours),
//! constrained to the decision space `A_x`. The observation encodes the
//! normalized load/residual of those 5 candidates, the segment workload,
//! progress `k/L`, and distance-to-origin — `STATE_DIM = 32` features,
//! matching the AOT-exported `qnet` artifact so the same policy shape can
//! be served via PJRT. Online ε-greedy Q-learning with experience replay
//! and a periodically-synced target network.

use super::{OffloadContext, OffloadScheme, SchemeKind};
use crate::nn::{Mlp, ReplayBuffer, Transition};
use crate::topology::SatId;
use crate::util::rng::Pcg64;

/// Observation feature count — must match python/compile/model.py STATE_DIM.
pub const STATE_DIM: usize = 32;
/// Stay + up to 4 ISL neighbours — must match model.py N_ACTIONS.
pub const N_ACTIONS: usize = 5;

pub struct DqnScheme {
    qnet: Mlp,
    target: Mlp,
    replay: ReplayBuffer,
    rng: Pcg64,
    /// ε for ε-greedy exploration, annealed per decision.
    epsilon: f64,
    epsilon_min: f64,
    epsilon_decay: f64,
    gamma: f64,
    lr: f64,
    batch: usize,
    steps: u64,
    target_sync: u64,
    /// Train only every `train_freq`-th observe() — the standard DQN
    /// step/train ratio; cuts per-task cost 4x with no measurable quality
    /// loss (EXPERIMENTS.md SSPerf iteration 1).
    train_freq: u64,
    observes: u64,
    /// Transitions of the most recent decision, kept until `observe`
    /// provides the realized reward.
    pending: Vec<(Vec<f64>, usize, Vec<f64>)>,
}

impl DqnScheme {
    pub fn new(seed: u64) -> DqnScheme {
        DqnScheme {
            qnet: Mlp::new(&[STATE_DIM, 64, 64, N_ACTIONS], seed ^ 0x514E),
            target: Mlp::new(&[STATE_DIM, 64, 64, N_ACTIONS], seed ^ 0x514E),
            replay: ReplayBuffer::new(4096),
            rng: Pcg64::new(seed, 0xD14E),
            epsilon: 1.0,
            epsilon_min: 0.05,
            epsilon_decay: 0.995,
            gamma: 0.9,
            lr: 1e-3,
            batch: 32,
            steps: 0,
            target_sync: 200,
            train_freq: 4,
            observes: 0,
            pending: Vec::new(),
        }
    }

    /// Candidate satellites for one step: previous position + its (up to)
    /// 4 ISL neighbours, filtered to the decision space (padded by
    /// repeating the previous position so the action set is always 5; a
    /// Walker-Star seam satellite's missing link pads the same way).
    fn action_sats(ctx: &OffloadContext, prev: SatId) -> [SatId; N_ACTIONS] {
        let nb = ctx.topo.neighbors4(prev);
        let mut out = [prev; N_ACTIONS];
        for (slot, cand) in nb.into_iter().enumerate() {
            if ctx.candidates.contains(&cand) {
                out[slot + 1] = cand;
            }
        }
        out
    }

    /// Build the observation vector for placing segment `k` from `prev`.
    fn observe_state(
        ctx: &OffloadContext,
        prev: SatId,
        k: usize,
        acts: &[SatId; N_ACTIONS],
    ) -> Vec<f64> {
        let mut s = Vec::with_capacity(STATE_DIM);
        let l = ctx.segments.len();
        for &a in acts {
            s.push(ctx.view.utilization(a));
            s.push(ctx.view.residual(a) / ctx.view.max_workload(a));
            s.push(ctx.topo.hops(ctx.origin, a) as f64 / 8.0);
        }
        // 15 so far
        let q = ctx.segments[k];
        let cap = ctx.view.capacity(prev);
        s.push(q / cap / 10.0); // segment compute slots (scaled)
        s.push(k as f64 / l as f64);
        s.push(l as f64 / 8.0);
        s.push(ctx.kappa * q); // per-hop shipping cost of this segment
        // mean utilization across the candidate space (global pressure)
        let mean_util: f64 = ctx
            .candidates
            .iter()
            .map(|&c| ctx.view.utilization(c))
            .sum::<f64>()
            / ctx.candidates.len() as f64;
        s.push(mean_util);
        while s.len() < STATE_DIM {
            s.push(0.0);
        }
        s
    }

    fn train_batch(&mut self) {
        if self.replay.len() < self.batch {
            return;
        }
        let samples: Vec<Transition> = self
            .replay
            .sample(&mut self.rng, self.batch)
            .into_iter()
            .cloned()
            .collect();
        for t in samples {
            let target = if t.terminal {
                t.reward
            } else {
                let next_q = self.target.forward(&t.next_state);
                t.reward + self.gamma * next_q.iter().cloned().fold(f64::MIN, f64::max)
            };
            self.qnet.sgd_step_single(&t.state, t.action, target, self.lr);
        }
        self.steps += 1;
        if self.steps % self.target_sync == 0 {
            self.target.copy_from(&self.qnet);
        }
    }
}

impl OffloadScheme for DqnScheme {
    fn decide_into(&mut self, ctx: &OffloadContext, out: &mut Vec<SatId>) {
        let l = ctx.segments.len();
        out.clear();
        out.reserve(l);
        self.pending.clear();
        let mut prev = ctx.origin;
        for k in 0..l {
            let acts = Self::action_sats(ctx, prev);
            let state = Self::observe_state(ctx, prev, k, &acts);
            let action = if self.rng.bool(self.epsilon) {
                self.rng.usize_in(0, N_ACTIONS)
            } else {
                let q = self.qnet.forward(&state);
                q.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            };
            let chosen = acts[action];
            self.pending.push((state, action, Vec::new()));
            out.push(chosen);
            prev = chosen;
        }
        // fill next_state links (s_{k+1} observed from the chosen position)
        for k in 0..l.saturating_sub(1) {
            let next = self.pending[k + 1].0.clone();
            self.pending[k].2 = next;
        }
        self.epsilon = (self.epsilon * self.epsilon_decay).max(self.epsilon_min);
    }

    fn observe(
        &mut self,
        _ctx: &OffloadContext,
        _chrom: &[SatId],
        dropped_at: Option<usize>,
        delay_s: f64,
    ) {
        // reward shaping: completed task → small negative delay cost;
        // drop → large penalty on the offending step.
        let n = self.pending.len();
        let pending = std::mem::take(&mut self.pending);
        for (k, (state, action, next_state)) in pending.into_iter().enumerate() {
            let terminal = k + 1 == n || dropped_at == Some(k);
            let reward = match dropped_at {
                Some(d) if k == d => -10.0,
                Some(d) if k > d => continue, // never executed
                _ => -delay_s / n as f64,
            };
            self.replay.push(Transition {
                state,
                action,
                reward,
                next_state,
                terminal,
            });
            if dropped_at == Some(k) {
                break;
            }
        }
        self.observes += 1;
        if self.observes % self.train_freq == 0 {
            self.train_batch();
        }
    }

    fn kind(&self) -> SchemeKind {
        SchemeKind::Dqn
    }

    fn learns(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GaConfig;
    use crate::satellite::Satellite;
    use crate::topology::Constellation;

    fn setup<'a>(
        topo: &'a Constellation,
        sats: &'a [Satellite],
        cands: &'a [SatId],
        segs: &'a [f64],
        ga: &'a GaConfig,
    ) -> OffloadContext<'a> {
        OffloadContext {
            topo,
            view: crate::state::StateView::live(sats),
            origin: cands[0],
            candidates: cands,
            segments: segs,
            kappa: 1e-4,
            ga,
            migration: None,
            outages: None,
        }
    }

    #[test]
    fn state_dim_matches_artifact() {
        let topo = Constellation::torus(6);
        let sats: Vec<Satellite> =
            (0..36).map(|i| Satellite::new(i, 3000.0, 15000.0)).collect();
        let cands = topo.decision_space(0, 2);
        let segs = vec![100.0, 200.0];
        let ga = GaConfig::default();
        let ctx = setup(&topo, &sats, &cands, &segs, &ga);
        let acts = DqnScheme::action_sats(&ctx, 0);
        let s = DqnScheme::observe_state(&ctx, 0, 0, &acts);
        assert_eq!(s.len(), STATE_DIM);
        assert!(s.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn decisions_stay_in_candidate_space() {
        let topo = Constellation::torus(6);
        let sats: Vec<Satellite> =
            (0..36).map(|i| Satellite::new(i, 3000.0, 15000.0)).collect();
        let cands = topo.decision_space(10, 2);
        let segs = vec![100.0, 200.0, 300.0];
        let ga = GaConfig::default();
        let ctx = setup(&topo, &sats, &cands, &segs, &ga);
        let mut agent = DqnScheme::new(1);
        for _ in 0..30 {
            let chrom = agent.decide(&ctx);
            assert_eq!(chrom.len(), 3);
            assert!(chrom.iter().all(|c| cands.contains(c)), "{chrom:?}");
        }
    }

    #[test]
    fn learns_to_avoid_overloaded_satellite() {
        // one neighbour is permanently saturated; after training the agent
        // should drop it from its greedy policy.
        let topo = Constellation::torus(4);
        let mut sats: Vec<Satellite> =
            (0..16).map(|i| Satellite::new(i, 3000.0, 15000.0)).collect();
        let bad = topo.neighbors(0)[0];
        sats[bad].try_load(14_999.0);
        let cands = topo.decision_space(0, 2);
        let segs = vec![2000.0];
        let ga = GaConfig::default();
        let ctx = setup(&topo, &sats, &cands, &segs, &ga);
        let mut agent = DqnScheme::new(2);
        // train: selecting `bad` yields a drop penalty
        for _ in 0..400 {
            let chrom = agent.decide(&ctx);
            let dropped = if chrom[0] == bad { Some(0) } else { None };
            agent.observe(&ctx, &chrom, dropped, 0.5);
        }
        // evaluate greedily
        agent.epsilon = 0.0;
        let mut bad_picks = 0;
        for _ in 0..50 {
            if agent.decide(&ctx)[0] == bad {
                bad_picks += 1;
            }
        }
        assert!(bad_picks <= 5, "picked saturated sat {bad_picks}/50 times");
    }

    #[test]
    fn epsilon_anneals() {
        let topo = Constellation::torus(4);
        let sats: Vec<Satellite> =
            (0..16).map(|i| Satellite::new(i, 3000.0, 15000.0)).collect();
        let cands = topo.decision_space(0, 1);
        let segs = vec![10.0];
        let ga = GaConfig::default();
        let ctx = setup(&topo, &sats, &cands, &segs, &ga);
        let mut agent = DqnScheme::new(3);
        let e0 = agent.epsilon;
        for _ in 0..100 {
            agent.decide(&ctx);
        }
        assert!(agent.epsilon < e0);
        assert!(agent.epsilon >= agent.epsilon_min);
    }
}
