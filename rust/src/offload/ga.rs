//! *SCC*: the GA-based self-adaptive task offloading scheme
//! (Algorithm 2, §IV-B) — the paper's proposal.
//!
//! A chromosome is the processing sequence `(c_1, …, c_L)`; fitness is the
//! (negated) deficit of Eq. 12. Each GA iteration performs, in order:
//!
//! 1. **Reproduction** (line 6): for every pair of distinct parents `C`,
//!    `D` and every index pair `(i, j)` with `c_i = d_j`, the heuristic
//!    splice summons two offspring that switch between the parents at the
//!    shared gene — keeping offspring length `L` and inheriting contiguous
//!    runs from both parents.
//! 2. **Elimination** (line 7): chromosomes with the highest deficit are
//!    removed until the group size is ≤ `N_K`.
//! 3. **Augmentation** (line 8): `N_summ` fresh random chromosomes keep
//!    diversity.
//!
//! Early stop (line 3): when the best deficit improves by ≤ ε between
//! iterations. Complexity `O(N_iter · (N_summ + N_K)² · L)` as analysed
//! in §IV-B.
//!
//! ## The indexed hot path
//!
//! `decide` runs once per admitted task, and each run performs hundreds of
//! Eq. 12 evaluations — at heavy traffic this kernel, not the DNN,
//! dominates wall-clock. The implementation therefore works on
//! candidate-local [`Gene`]s over a per-decision [`DecisionSpaceIndex`]
//! (hop LUT + cached satellite state), with three GA-internal
//! optimizations that preserve **bit-for-bit identical decisions per
//! seed** (enforced by `tests/prop_invariants.rs`):
//!
//! * **scratch-buffer reuse** — chromosome buffers are recycled through a
//!   free pool, so steady-state iterations allocate nothing;
//! * **seen-chromosome memo** — duplicate splices (common once the
//!   population converges) return their cached deficit instead of
//!   re-walking Eq. 12; the memo key is the exact `u128`-packed gene
//!   vector, so a hit can never alias a different chromosome;
//! * **whole-generation batched evaluation** — each GA phase (initial
//!   population, the reproduction brood, the summoned refresh) stages its
//!   chromosomes first, then evaluates the memo misses in one
//!   [`DecisionSpaceIndex::deficit_batch`] pass over the
//!   structure-of-arrays side tables (comp-term LUT, `κ·q_k`, hop LUT) —
//!   fixed-stride lanes the autovectorizer can chew, reduced in the
//!   scalar kernel's operation order so every value is bit-identical.
//!   ([`super::DeficitScratch`]'s incremental path remains available as
//!   the scalar oracle.)
//!
//! The paper-literal implementation is retained as
//! [`GaScheme::decide_reference`], the equivalence oracle.

use super::pool::{resolve_threads, EvalPool};
use super::{
    BatchScratch, DecisionSpaceIndex, Gene, OffloadContext, OffloadScheme, SchemeKind,
    MEMO_MAX_L,
};
use crate::topology::SatId;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// Cheap multiply-xor hasher for the packed-chromosome memo. The key is an
/// exact encoding of the gene vector (no collision risk — equality is
/// checked by the map); SipHash would dominate the lookup cost at this key
/// size, and the map is only ever probed, never iterated, so hash quality
/// beyond bucket spread is irrelevant.
#[derive(Default)]
struct PackHasher(u64);

impl Hasher for PackHasher {
    fn finish(&self) -> u64 {
        self.0
    }

    fn write(&mut self, bytes: &[u8]) {
        // generic fallback (FNV-1a); the memo key path uses write_u128
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    fn write_u128(&mut self, x: u128) {
        const K1: u64 = 0x9e37_79b9_7f4a_7c15;
        const K2: u64 = 0xff51_afd7_ed55_8ccd;
        let h = (x as u64).wrapping_mul(K1) ^ ((x >> 64) as u64).wrapping_mul(K2);
        self.0 = h ^ (h >> 29);
    }
}

type Memo = HashMap<u128, f64, BuildHasherDefault<PackHasher>>;

/// Pack a gene chromosome (L ≤ [`MEMO_MAX_L`]) into its exact memo key.
#[inline]
fn pack(genes: &[Gene]) -> u128 {
    debug_assert!(genes.len() <= MEMO_MAX_L);
    let mut key = 0u128;
    for &g in genes {
        key = (key << 16) | g as u128;
    }
    key
}

pub struct GaScheme {
    rng: Pcg64,
    /// Scratch population buffer, reused across decisions (hot path).
    pop: Vec<Individual>,
    /// Recycled chromosome buffers (no per-iteration `Vec` churn).
    free: Vec<Vec<Gene>>,
    /// Per-decision candidate index (buffers reused across decisions).
    index: DecisionSpaceIndex,
    /// Batched-deficit accumulator lanes (whole-generation Eq. 12 pass).
    batch: BatchScratch,
    /// Staging buffers for [`eval_generation`], reused across decisions.
    bufs: EvalBuffers,
    /// deficit memo keyed on the packed chromosome (cleared per decision:
    /// satellite loads change between tasks).
    memo: Memo,
    /// Lifetime kernel counters, read once at end of run for the report's
    /// telemetry block (plain integer increments on paths already taken —
    /// no effect on decisions or the RNG stream).
    stats: GaStats,
    /// Pooled generation evaluation (`--decide-threads` resolved above 1);
    /// `None` keeps the plain sequential kernel — the bitwise oracle the
    /// pooled path is property-tested against (`tests/prop_pool.rs`).
    pool: Option<EvalPool>,
    /// Epoch-keyed final-placement cache (`--decision-cache`); `None`
    /// (the default) is the legacy decision path, bit for bit.
    dcache: Option<DecisionCache>,
}

/// Opt-in final-placement memo (`--decision-cache`): between view epochs
/// — state broadcasts, fault batches, and handovers bump
/// [`crate::state::ViewTracker`]'s monotone counter — a decide for the
/// same (origin, segment profile, migration) returns the cached placement
/// instead of re-running the GA. A hit skips the GA's RNG draws, so this
/// is **not** byte-identical to the uncached run: it is off by default,
/// and off == legacy is pinned by `tests/prop_pool.rs`. Only consulted on
/// stale (disseminated) views — a live view changes with every admission
/// and carries no epoch discipline, so caching it would serve arbitrarily
/// outdated placements.
#[derive(Default)]
struct DecisionCache {
    /// Epoch the cached placements were computed in. Any epoch change
    /// clears the map (epochs are monotone), which both keeps placements
    /// from outliving the view they were solved against and bounds memory
    /// to one epoch's working set.
    epoch: u64,
    map: HashMap<DecisionKey, Vec<SatId>>,
    /// Cache-eligible decides answered from the map.
    hits: u64,
    /// Cache-eligible decides (the hit-rate denominator).
    lookups: u64,
}

/// Exact identity of a cacheable decision within one view epoch. Segment
/// workloads are keyed by their f64 bit patterns, so a key can never
/// alias a different split profile; migration keys the sticky source and
/// its per-hop cost the same way.
#[derive(PartialEq, Eq, Hash)]
struct DecisionKey {
    origin: SatId,
    segments: Vec<u64>,
    migration: Option<(SatId, u64)>,
}

/// Lifetime counters over the GA kernel's caching layers: chromosome-memo
/// hit/miss totals and the shape of the batched Eq. 12 passes. Exposed via
/// [`OffloadScheme::telemetry`] alongside the
/// [`GaScheme::index_cache_stats`] pair.
#[derive(Default, Clone, Debug)]
pub struct GaStats {
    /// Chromosome evaluations answered from the per-decision memo.
    pub memo_hits: u64,
    /// Chromosome evaluations that went to the batched kernel.
    pub memo_misses: u64,
    /// Number of [`DecisionSpaceIndex::deficit_batch`] invocations.
    pub batches: u64,
    /// Total chromosomes across all batched passes (`memo_misses`
    /// restated per-batch; mean batch size = `batch_chromosomes /
    /// batches`).
    pub batch_chromosomes: u64,
    /// Total `decide_into` calls over the scheme's lifetime (cached or
    /// not) — the decides/s numerator for the `decidecache` sweep.
    pub decides: u64,
}

#[derive(Clone, Debug)]
struct Individual {
    chrom: Vec<Gene>,
    deficit: f64,
}

/// Reused staging of one generation's memo-missing chromosomes: the dense
/// gene matrix handed to the batch kernel, which population indices the
/// rows belong to, and the kernel's outputs.
#[derive(Default)]
struct EvalBuffers {
    genes: Vec<Gene>,
    miss: Vec<usize>,
    out: Vec<f64>,
}

/// Evaluate the deficits of one whole generation (`pop`, typically a
/// fresh slice of the population) in a single batched pass: memo hits
/// fill directly, misses are compacted into a dense chromosome matrix and
/// handed to [`DecisionSpaceIndex::deficit_batch`] (the SoA kernel), then
/// written back and memoized. Every value is bit-for-bit what the scalar
/// kernel would produce, so decisions are unchanged (enforced by
/// `tests/prop_invariants.rs::prop_ga_decide_identical_to_reference_per_seed`).
///
/// Free function over disjoint `GaScheme` fields so the borrow checker
/// accepts calls against population slices.
fn eval_generation(
    index: &DecisionSpaceIndex,
    pool: Option<&EvalPool>,
    batch: &mut BatchScratch,
    bufs: &mut EvalBuffers,
    memo: &mut Memo,
    stats: &mut GaStats,
    pop: &mut [Individual],
) {
    let memoizable = index.n_segments() <= MEMO_MAX_L;
    bufs.genes.clear();
    bufs.miss.clear();
    for (i, ind) in pop.iter_mut().enumerate() {
        if memoizable {
            if let Some(&d) = memo.get(&pack(&ind.chrom)) {
                ind.deficit = d;
                stats.memo_hits += 1;
                continue;
            }
        }
        bufs.genes.extend_from_slice(&ind.chrom);
        bufs.miss.push(i);
    }
    if bufs.miss.is_empty() {
        return;
    }
    stats.memo_misses += bufs.miss.len() as u64;
    stats.batches += 1;
    stats.batch_chromosomes += bufs.miss.len() as u64;
    // Pooled evaluation produces exactly the sequential kernel's bytes
    // (chromosome deficits are independent — see `offload::pool`), so the
    // dispatch choice can never change a decision.
    match pool {
        Some(p) => p.deficit_batch(index, batch, &bufs.genes, &mut bufs.out),
        None => index.deficit_batch(batch, &bufs.genes, &mut bufs.out),
    }
    debug_assert_eq!(bufs.out.len(), bufs.miss.len());
    for (&i, &d) in bufs.miss.iter().zip(&bufs.out) {
        pop[i].deficit = d;
        if memoizable {
            memo.insert(pack(&pop[i].chrom), d);
        }
    }
}

/// Draw a fresh random chromosome into a recycled buffer. Consumes the RNG
/// exactly like the reference's `rng.choose(candidates)` per gene, so the
/// indexed and reference paths stay in RNG lockstep.
fn random_genes(rng: &mut Pcg64, free: &mut Vec<Vec<Gene>>, n_cands: usize, l: usize) -> Vec<Gene> {
    let mut chrom = free.pop().unwrap_or_default();
    chrom.clear();
    chrom.reserve(l);
    for _ in 0..l {
        chrom.push(rng.usize_in(0, n_cands) as Gene);
    }
    chrom
}

impl GaScheme {
    pub fn new(seed: u64) -> GaScheme {
        GaScheme::with_opts(seed, 1, false)
    }

    /// [`GaScheme::new`] with the decision-layer perf knobs threaded
    /// through: pooled generation evaluation across `decide_threads`
    /// lanes (0 = auto, 1 = the sequential oracle — byte-identical
    /// either way) and the epoch-keyed decision cache (**not**
    /// byte-identical on hits; off by default).
    pub fn with_opts(seed: u64, decide_threads: usize, decision_cache: bool) -> GaScheme {
        GaScheme {
            rng: Pcg64::new(seed, 0x6A61),
            pop: Vec::new(),
            free: Vec::new(),
            index: DecisionSpaceIndex::new(),
            batch: BatchScratch::default(),
            bufs: EvalBuffers::default(),
            memo: Memo::default(),
            stats: GaStats::default(),
            pool: (resolve_threads(decide_threads) > 1).then(|| EvalPool::new(decide_threads)),
            dcache: decision_cache.then(DecisionCache::default),
        }
    }

    /// (hits, lookups) of the epoch-keyed decision cache; (0, 0) when
    /// `--decision-cache` is off.
    pub fn decision_cache_stats(&self) -> (u64, u64) {
        self.dcache
            .as_ref()
            .map_or((0, 0), |c| (c.hits, c.lookups))
    }

    /// Lifetime chromosome-memo / batch-kernel counters (see [`GaStats`]).
    pub fn ga_stats(&self) -> &GaStats {
        &self.stats
    }

    /// (hits, misses) of the per-decision [`DecisionSpaceIndex`] reuse
    /// cache: a hit means a decision reused the previous index verbatim
    /// because origin, candidate set, and observed view were unchanged.
    pub fn index_cache_stats(&self) -> (u64, u64) {
        (self.index.cache_hits(), self.index.cache_misses())
    }

    /// The paper's pairwise heuristic reproduction: for parents C and D
    /// with a shared gene (c_i = d_j), two offspring are formed by
    /// splicing the parents at that gene. We take, per parent pair, the
    /// first shared-gene index pair (scanning i then j) — summoning every
    /// (i, j) pair would square the population within one iteration.
    ///
    /// Writes into caller-provided buffers (cleared first) and reports
    /// whether a shared gene was found. Generic so the indexed kernel
    /// (genes) and the reference oracle (satellite ids) share one splice.
    pub fn reproduce_into<T: Copy + PartialEq>(
        c: &[T],
        d: &[T],
        a: &mut Vec<T>,
        b: &mut Vec<T>,
    ) -> bool {
        let l = c.len();
        for i in 0..l {
            for j in 0..l {
                if c[i] != d[j] {
                    continue;
                }
                // Offspring A: prefix of D through j, then C after i,
                // wrapping over C cyclically to restore length L.
                a.clear();
                a.extend_from_slice(&d[..=j]);
                let mut k = i + 1;
                while a.len() < l {
                    a.push(c[k % l]);
                    k += 1;
                }
                // Offspring B: the i genes of D just before d_j (taken
                // cyclically backwards), then C from the shared gene on.
                b.clear();
                let take = i;
                for t in 0..take {
                    let idx = (j + l - take + t) % l;
                    b.push(d[idx]);
                }
                b.extend_from_slice(&c[i..]);
                debug_assert_eq!(b.len(), l);
                return true;
            }
        }
        false
    }

    /// Allocating convenience wrapper over [`GaScheme::reproduce_into`].
    pub fn reproduce<T: Copy + PartialEq>(c: &[T], d: &[T]) -> Option<(Vec<T>, Vec<T>)> {
        let mut a = Vec::with_capacity(c.len());
        let mut b = Vec::with_capacity(c.len());
        if Self::reproduce_into(c, d, &mut a, &mut b) {
            Some((a, b))
        } else {
            None
        }
    }

    /// The paper-literal Algorithm 2 over raw satellite ids and the
    /// reference [`OffloadContext::deficit`], kept as the equivalence
    /// oracle for the indexed kernel: `decide` must return the identical
    /// sequence per seed (enforced by `tests/prop_invariants.rs`).
    pub fn decide_reference(&mut self, ctx: &OffloadContext) -> Vec<SatId> {
        struct RefInd {
            chrom: Vec<SatId>,
            deficit: f64,
        }
        let g = ctx.ga;
        let l = ctx.segments.len();
        if l == 0 {
            return Vec::new();
        }
        // Line 1: primitive group of N_ini random chromosomes.
        let mut pop: Vec<RefInd> = Vec::new();
        for _ in 0..g.n_ini {
            let chrom: Vec<SatId> =
                (0..l).map(|_| *self.rng.choose(ctx.candidates)).collect();
            let deficit = ctx.deficit(&chrom);
            pop.push(RefInd { chrom, deficit });
        }
        let mut best_prev = f64::INFINITY;

        for iter in 0..g.n_iter {
            let best_now = pop.iter().map(|i| i.deficit).fold(f64::INFINITY, f64::min);
            // Line 3: early stop on convergence.
            if iter != 0 && (best_prev - best_now).abs() <= g.epsilon {
                break;
            }
            best_prev = best_now;

            // Line 6: reproduce distinct pairs via the heuristic splice.
            let parents = pop.len();
            let mut children: Vec<RefInd> = Vec::new();
            for a in 0..parents {
                for b in (a + 1)..parents {
                    if pop[a].chrom == pop[b].chrom {
                        continue;
                    }
                    if let Some((x, y)) = Self::reproduce(&pop[a].chrom, &pop[b].chrom) {
                        let dx = ctx.deficit(&x);
                        let dy = ctx.deficit(&y);
                        children.push(RefInd { chrom: x, deficit: dx });
                        children.push(RefInd { chrom: y, deficit: dy });
                    }
                }
            }
            pop.extend(children);

            // Line 7: eliminate highest-deficit individuals until ≤ N_K.
            if pop.len() > g.n_k {
                pop.sort_by(|a, b| a.deficit.partial_cmp(&b.deficit).unwrap());
                pop.truncate(g.n_k);
            }

            // Line 8: summon N_summ fresh chromosomes.
            for _ in 0..g.n_summ {
                let chrom: Vec<SatId> =
                    (0..l).map(|_| *self.rng.choose(ctx.candidates)).collect();
                let deficit = ctx.deficit(&chrom);
                pop.push(RefInd { chrom, deficit });
            }
        }

        // Line 10: the chromosome with the lowest deficit.
        pop.iter()
            .min_by(|a, b| a.deficit.partial_cmp(&b.deficit).unwrap())
            .map(|i| i.chrom.clone())
            .expect("population non-empty")
    }
}

impl OffloadScheme for GaScheme {
    fn decide_into(&mut self, ctx: &OffloadContext, out: &mut Vec<SatId>) {
        out.clear();
        let g = ctx.ga;
        let l = ctx.segments.len();
        if l == 0 {
            return;
        }
        self.stats.decides += 1;
        // Epoch-keyed decision cache (opt-in; see [`DecisionCache`]): a
        // decide for the same (origin, segment profile, migration) within
        // the same view epoch returns the memoized placement without
        // touching the GA or its RNG.
        let cache_key = match &mut self.dcache {
            Some(cache) if ctx.view.is_stale() => {
                let epoch = ctx.view.epoch();
                if cache.epoch != epoch {
                    cache.map.clear();
                    cache.epoch = epoch;
                }
                let key = DecisionKey {
                    origin: ctx.origin,
                    segments: ctx.segments.iter().map(|q| q.to_bits()).collect(),
                    migration: ctx.migration.as_ref().map(|m| (m.from, m.secs_per_hop.to_bits())),
                };
                cache.lookups += 1;
                if let Some(placement) = cache.map.get(&key) {
                    cache.hits += 1;
                    out.extend_from_slice(placement);
                    return;
                }
                Some(key)
            }
            _ => None,
        };
        // Per-decision kernel state: candidate index (reused verbatim
        // across consecutive decisions when origin, candidates, and the
        // observed view are unchanged — the rebuild is skipped, the
        // decision is bit-for-bit the same), memo.
        self.index.build_cached(ctx);
        self.memo.clear();
        let n_cands = ctx.candidates.len();

        // Line 1: primitive group of N_ini random chromosomes, evaluated
        // as one batched generation (values identical to per-chromosome
        // evaluation; the RNG stream is consumed before any deficit is
        // computed, exactly like the reference's draw order).
        for ind in self.pop.drain(..) {
            self.free.push(ind.chrom);
        }
        for _ in 0..g.n_ini {
            let chrom = random_genes(&mut self.rng, &mut self.free, n_cands, l);
            self.pop.push(Individual { chrom, deficit: 0.0 });
        }
        eval_generation(
            &self.index,
            self.pool.as_ref(),
            &mut self.batch,
            &mut self.bufs,
            &mut self.memo,
            &mut self.stats,
            &mut self.pop,
        );
        let mut best_prev = f64::INFINITY;

        for iter in 0..g.n_iter {
            let best_now = self
                .pop
                .iter()
                .map(|i| i.deficit)
                .fold(f64::INFINITY, f64::min);
            // Line 3: early stop on convergence.
            if iter != 0 && (best_prev - best_now).abs() <= g.epsilon {
                break;
            }
            best_prev = best_now;

            // Line 6: reproduce distinct pairs via the heuristic splice.
            // Children append after index `parents`, so parent reads stay
            // confined to the pre-reproduction population exactly like the
            // reference's separate `children` vector. No child's deficit
            // is read during reproduction, so the whole brood is staged
            // first and evaluated in one batched pass at the generation
            // barrier — decision-preserving by value equality.
            let parents = self.pop.len();
            for a in 0..parents {
                for b in (a + 1)..parents {
                    if self.pop[a].chrom == self.pop[b].chrom {
                        continue;
                    }
                    let mut x = self.free.pop().unwrap_or_default();
                    let mut y = self.free.pop().unwrap_or_default();
                    if Self::reproduce_into(
                        &self.pop[a].chrom,
                        &self.pop[b].chrom,
                        &mut x,
                        &mut y,
                    ) {
                        self.pop.push(Individual { chrom: x, deficit: 0.0 });
                        self.pop.push(Individual { chrom: y, deficit: 0.0 });
                    } else {
                        self.free.push(x);
                        self.free.push(y);
                    }
                }
            }
            eval_generation(
                &self.index,
                self.pool.as_ref(),
                &mut self.batch,
                &mut self.bufs,
                &mut self.memo,
                &mut self.stats,
                &mut self.pop[parents..],
            );

            // Line 7: eliminate highest-deficit individuals until ≤ N_K
            // (stable sort on bit-identical keys ⇒ identical survivors).
            if self.pop.len() > g.n_k {
                self.pop
                    .sort_by(|a, b| a.deficit.partial_cmp(&b.deficit).unwrap());
                for ind in self.pop.drain(g.n_k..) {
                    self.free.push(ind.chrom);
                }
            }

            // Line 8: summon N_summ fresh chromosomes (drawn first, then
            // batch-evaluated — same RNG stream, same values).
            let summoned_from = self.pop.len();
            for _ in 0..g.n_summ {
                let chrom = random_genes(&mut self.rng, &mut self.free, n_cands, l);
                self.pop.push(Individual { chrom, deficit: 0.0 });
            }
            eval_generation(
                &self.index,
                self.pool.as_ref(),
                &mut self.batch,
                &mut self.bufs,
                &mut self.memo,
                &mut self.stats,
                &mut self.pop[summoned_from..],
            );
        }

        // Line 10: the chromosome with the lowest deficit.
        let best = self
            .pop
            .iter()
            .min_by(|a, b| a.deficit.partial_cmp(&b.deficit).unwrap())
            .expect("population non-empty");
        self.index.decode_into(&best.chrom, out);
        if let (Some(cache), Some(key)) = (&mut self.dcache, cache_key) {
            cache.map.insert(key, out.clone());
        }
    }

    fn kind(&self) -> SchemeKind {
        SchemeKind::Scc
    }

    fn telemetry(&self) -> Option<Json> {
        let (index_hits, index_misses) = self.index_cache_stats();
        let (dc_hits, dc_lookups) = self.decision_cache_stats();
        Some(Json::obj(vec![
            ("memo_hits", Json::Num(self.stats.memo_hits as f64)),
            ("memo_misses", Json::Num(self.stats.memo_misses as f64)),
            ("index_cache_hits", Json::Num(index_hits as f64)),
            ("index_cache_misses", Json::Num(index_misses as f64)),
            ("deficit_batches", Json::Num(self.stats.batches as f64)),
            (
                "batch_chromosomes",
                Json::Num(self.stats.batch_chromosomes as f64),
            ),
            ("decides", Json::Num(self.stats.decides as f64)),
            ("decision_cache_hits", Json::Num(dc_hits as f64)),
            ("decision_cache_lookups", Json::Num(dc_lookups as f64)),
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GaConfig;
    use crate::satellite::Satellite;
    use crate::topology::Constellation;

    fn setup(n: usize) -> (Constellation, Vec<Satellite>) {
        let topo = Constellation::torus(n);
        let sats = (0..topo.len())
            .map(|i| Satellite::new(i, 3000.0, 15000.0))
            .collect();
        (topo, sats)
    }

    fn ctx<'a>(
        topo: &'a Constellation,
        sats: &'a [Satellite],
        cands: &'a [SatId],
        segs: &'a [f64],
        ga: &'a GaConfig,
    ) -> OffloadContext<'a> {
        OffloadContext {
            topo,
            view: crate::state::StateView::live(sats),
            origin: cands[0],
            candidates: cands,
            segments: segs,
            kappa: 1e-4,
            ga,
            migration: None,
            outages: None,
        }
    }

    #[test]
    fn reproduce_keeps_length_and_shared_gene() {
        let c = vec![1usize, 2, 3, 4];
        let d = vec![5usize, 3, 6, 7];
        let (a, b) = GaScheme::reproduce(&c, &d).unwrap();
        assert_eq!(a.len(), 4);
        assert_eq!(b.len(), 4);
        // shared gene 3 (c_2 = d_1): offspring A starts with d-prefix [5,3]
        assert_eq!(&a[..2], &[5, 3]);
        // offspring B ends with c-suffix from the shared gene
        assert_eq!(&b[b.len() - 2..], &[3, 4]);
    }

    #[test]
    fn reproduce_none_when_disjoint() {
        assert!(GaScheme::reproduce(&[1, 2], &[3, 4]).is_none());
    }

    #[test]
    fn reproduce_cyclic_splice_at_i_zero() {
        // shared gene at c_0: offspring B takes zero genes of D context and
        // becomes C verbatim; offspring A splices D's prefix through d_j
        // then wraps over C.
        let c = vec![7usize, 1];
        let d = vec![2usize, 7];
        let (a, b) = GaScheme::reproduce(&c, &d).unwrap();
        assert_eq!(a, vec![2, 7]);
        assert_eq!(b, vec![7, 1]);

        // shared gene at c_0 = d_0: both offspring collapse to clean splices
        let c = vec![5usize, 6];
        let d = vec![5usize, 8];
        let (a, b) = GaScheme::reproduce(&c, &d).unwrap();
        assert_eq!(a, vec![5, 6]);
        assert_eq!(b, vec![5, 6]);

        // L-length preserved for a longer i = 0 wrap
        let c = vec![9usize, 2, 4];
        let d = vec![3usize, 8, 9];
        let (a, b) = GaScheme::reproduce(&c, &d).unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(b, vec![9, 2, 4]);
        // A: D's prefix [3,8,9] fills all of L already
        assert_eq!(a, vec![3, 8, 9]);
    }

    #[test]
    fn reproduce_into_reuses_buffers() {
        let mut a = vec![0u16; 7];
        let mut b = Vec::new();
        assert!(GaScheme::reproduce_into(
            &[1u16, 2, 3],
            &[4u16, 2, 5],
            &mut a,
            &mut b
        ));
        assert_eq!(a.len(), 3);
        assert_eq!(b.len(), 3);
        assert!(!GaScheme::reproduce_into(&[1u16], &[2u16], &mut a, &mut b));
    }

    #[test]
    fn decision_within_candidates() {
        let (topo, sats) = setup(6);
        let ga = GaConfig::default();
        let cands = topo.decision_space(8, 2);
        let segs = vec![500.0, 700.0, 300.0];
        let c = ctx(&topo, &sats, &cands, &segs, &ga);
        let mut s = GaScheme::new(1);
        for _ in 0..10 {
            let chrom = s.decide(&c);
            assert_eq!(chrom.len(), 3);
            assert!(chrom.iter().all(|x| cands.contains(x)));
        }
    }

    #[test]
    fn indexed_decide_matches_reference_per_seed() {
        let (topo, mut sats) = setup(8);
        for i in 0..sats.len() {
            if i % 3 == 0 {
                sats[i].try_load(11_000.0);
            }
        }
        let ga = GaConfig::default();
        let cands = topo.decision_space(20, 3);
        let segs = vec![3800.0, 2500.0, 3100.0, 1900.0];
        let c = ctx(&topo, &sats, &cands, &segs, &ga);
        for seed in [0u64, 1, 7, 42, 1234] {
            let mut fast = GaScheme::new(seed);
            let mut slow = GaScheme::new(seed);
            // repeated decisions exercise buffer recycling + memo clearing
            for round in 0..3 {
                let a = fast.decide(&c);
                let b = slow.decide_reference(&c);
                assert_eq!(a, b, "seed {seed} round {round} diverged");
            }
        }
    }

    #[test]
    fn ga_beats_random_on_deficit() {
        let (topo, mut sats) = setup(8);
        // heavily load half the neighborhood to create a real decision
        for i in 0..sats.len() {
            if i % 2 == 0 {
                sats[i].try_load(13_000.0);
            }
        }
        let ga = GaConfig::default();
        let cands = topo.decision_space(9, 3);
        let segs = vec![4000.0, 2500.0, 3500.0, 1500.0];
        let c = ctx(&topo, &sats, &cands, &segs, &ga);

        let mut g = GaScheme::new(2);
        let ga_deficit = c.deficit(&g.decide(&c));

        let mut rng = Pcg64::seed_from_u64(3);
        let mut rnd_total = 0.0;
        let trials = 50;
        for _ in 0..trials {
            let chrom: Vec<SatId> = (0..segs.len()).map(|_| *rng.choose(&cands)).collect();
            rnd_total += c.deficit(&chrom);
        }
        let rnd_mean = rnd_total / trials as f64;
        assert!(
            ga_deficit <= rnd_mean,
            "GA {ga_deficit} should beat mean random {rnd_mean}"
        );
    }

    #[test]
    fn ga_finds_near_optimal_small_instance() {
        // exhaustive optimum over a 5-candidate, L=2 instance
        let (topo, mut sats) = setup(4);
        sats[0].try_load(14_000.0);
        let ga = GaConfig {
            n_iter: 20,
            ..GaConfig::default()
        };
        let cands = topo.decision_space(0, 1); // 5 sats
        let segs = vec![2000.0, 2000.0];
        let c = ctx(&topo, &sats, &cands, &segs, &ga);
        let mut best = f64::INFINITY;
        for &a in &cands {
            for &b in &cands {
                best = best.min(c.deficit(&[a, b]));
            }
        }
        let mut g = GaScheme::new(4);
        let got = c.deficit(&g.decide(&c));
        assert!(
            got <= best * 1.001 + 1e-9,
            "GA {got} vs exhaustive {best}"
        );
    }

    #[test]
    fn converges_early_with_tight_epsilon() {
        // with a single candidate every chromosome is identical: the GA
        // must early-stop and still return a valid sequence
        let (topo, sats) = setup(4);
        let ga = GaConfig::default();
        let cands = vec![5usize];
        let segs = vec![100.0, 100.0];
        let c = ctx(&topo, &sats, &cands, &segs, &ga);
        let mut g = GaScheme::new(5);
        assert_eq!(g.decide(&c), vec![5, 5]);
    }

    #[test]
    fn empty_segments_ok() {
        let (topo, sats) = setup(4);
        let ga = GaConfig::default();
        let cands = topo.decision_space(0, 1);
        // L=3 but one block is empty (padded by Alg. 1)
        let segs = vec![500.0, 0.0, 300.0];
        let c = ctx(&topo, &sats, &cands, &segs, &ga);
        let mut g = GaScheme::new(6);
        let chrom = g.decide(&c);
        assert_eq!(chrom.len(), 3);
    }

    #[test]
    fn ga_stats_count_memo_and_batches() {
        let (topo, sats) = setup(6);
        let ga = GaConfig::default();
        let cands = topo.decision_space(8, 2);
        let segs = vec![500.0, 700.0, 300.0];
        let c = ctx(&topo, &sats, &cands, &segs, &ga);
        let mut s = GaScheme::new(9);
        for _ in 0..3 {
            s.decide(&c);
        }
        let st = s.ga_stats();
        assert!(st.memo_misses > 0, "every decision batches at least once");
        assert!(st.batches > 0);
        assert_eq!(st.batch_chromosomes, st.memo_misses);
        // telemetry block mirrors the counters
        let t = s.telemetry().expect("GA exposes kernel telemetry");
        assert_eq!(
            t.get("memo_misses").and_then(|j| j.as_f64()),
            Some(st.memo_misses as f64)
        );
        assert_eq!(
            t.get("deficit_batches").and_then(|j| j.as_f64()),
            Some(st.batches as f64)
        );
    }

    #[test]
    fn pooled_decide_is_identical_to_sequential() {
        let (topo, mut sats) = setup(8);
        for i in 0..sats.len() {
            if i % 3 == 0 {
                sats[i].try_load(11_000.0);
            }
        }
        let ga = GaConfig::default();
        let cands = topo.decision_space(20, 3);
        let segs = vec![3800.0, 2500.0, 3100.0, 1900.0];
        let c = ctx(&topo, &sats, &cands, &segs, &ga);
        for threads in [2usize, 4, 0] {
            let mut seq = GaScheme::new(33);
            let mut pooled = GaScheme::with_opts(33, threads, false);
            for round in 0..3 {
                assert_eq!(
                    seq.decide(&c),
                    pooled.decide(&c),
                    "threads {threads} round {round} diverged"
                );
            }
        }
    }

    #[test]
    fn decision_cache_hits_within_epoch_and_invalidates_across() {
        let (topo, mut sats) = setup(8);
        for i in 0..sats.len() {
            if i % 2 == 0 {
                sats[i].try_load(9_000.0);
            }
        }
        let ga = GaConfig::default();
        let cands = topo.decision_space(20, 3);
        let segs = vec![3800.0, 2500.0, 3100.0];
        let observed: Vec<f64> = sats.iter().map(|s| s.loaded()).collect();
        let mut c = ctx(&topo, &sats, &cands, &segs, &ga);
        c.view = crate::state::StateView::observed(&sats, &observed).at_epoch(1);
        let mut s = GaScheme::with_opts(77, 1, true);
        let first = s.decide(&c);
        let again = s.decide(&c);
        assert_eq!(first, again, "a cache hit must replay the placement");
        assert_eq!(s.decision_cache_stats(), (1, 2));
        // a new epoch invalidates: the decide re-runs the GA
        c.view = crate::state::StateView::observed(&sats, &observed).at_epoch(2);
        s.decide(&c);
        assert_eq!(s.decision_cache_stats(), (1, 3));
        // live views are never cached, even with the knob on
        c.view = crate::state::StateView::live(&sats);
        s.decide(&c);
        assert_eq!(s.decision_cache_stats(), (1, 3));
        assert_eq!(s.ga_stats().decides, 4);
    }

    #[test]
    fn decision_cache_off_keeps_stats_at_zero() {
        let (topo, sats) = setup(6);
        let ga = GaConfig::default();
        let cands = topo.decision_space(8, 2);
        let segs = vec![500.0, 700.0, 300.0];
        let c = ctx(&topo, &sats, &cands, &segs, &ga);
        let mut s = GaScheme::new(9);
        s.decide(&c);
        assert_eq!(s.decision_cache_stats(), (0, 0));
        let t = s.telemetry().unwrap();
        assert_eq!(
            t.get("decision_cache_lookups").and_then(|j| j.as_f64()),
            Some(0.0)
        );
        assert_eq!(t.get("decides").and_then(|j| j.as_f64()), Some(1.0));
    }

    #[test]
    fn memo_pack_is_injective_per_length() {
        let a = pack(&[1, 2, 3]);
        let b = pack(&[1, 2, 4]);
        let c = pack(&[2, 1, 3]);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(pack(&[1, 2, 3]), a);
    }
}
