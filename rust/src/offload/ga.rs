//! *SCC*: the GA-based self-adaptive task offloading scheme
//! (Algorithm 2, §IV-B) — the paper's proposal.
//!
//! A chromosome is the processing sequence `(c_1, …, c_L)`; fitness is the
//! (negated) deficit of Eq. 12. Each GA iteration performs, in order:
//!
//! 1. **Reproduction** (line 6): for every pair of distinct parents `C`,
//!    `D` and every index pair `(i, j)` with `c_i = d_j`, the heuristic
//!    splice summons two offspring that switch between the parents at the
//!    shared gene — keeping offspring length `L` and inheriting contiguous
//!    runs from both parents.
//! 2. **Elimination** (line 7): chromosomes with the highest deficit are
//!    removed until the group size is ≤ `N_K`.
//! 3. **Augmentation** (line 8): `N_summ` fresh random chromosomes keep
//!    diversity.
//!
//! Early stop (line 3): when the best deficit improves by ≤ ε between
//! iterations. Complexity `O(N_iter · (N_summ + N_K)² · L)` as analysed
//! in §IV-B.

use super::{OffloadContext, OffloadScheme, SchemeKind};
use crate::topology::SatId;
use crate::util::rng::Pcg64;

pub struct GaScheme {
    rng: Pcg64,
    /// Scratch population buffer, reused across decisions (hot path).
    pop: Vec<Individual>,
}

#[derive(Clone, Debug)]
struct Individual {
    chrom: Vec<SatId>,
    deficit: f64,
}

impl GaScheme {
    pub fn new(seed: u64) -> GaScheme {
        GaScheme {
            rng: Pcg64::new(seed, 0x6A61),
            pop: Vec::new(),
        }
    }

    fn random_chrom(&mut self, ctx: &OffloadContext) -> Vec<SatId> {
        (0..ctx.segments.len())
            .map(|_| *self.rng.choose(ctx.candidates))
            .collect()
    }

    /// The paper's pairwise heuristic reproduction: for parents C and D
    /// with a shared gene (c_i = d_j), two offspring are formed by
    /// splicing the parents at that gene. We take, per parent pair, the
    /// first shared-gene index pair (scanning i then j) — summoning every
    /// (i, j) pair would square the population within one iteration.
    fn reproduce(c: &[SatId], d: &[SatId]) -> Option<(Vec<SatId>, Vec<SatId>)> {
        let l = c.len();
        for i in 0..l {
            for j in 0..l {
                if c[i] != d[j] {
                    continue;
                }
                // Offspring A: prefix of D through j, then C after i,
                // wrapping over C cyclically to restore length L.
                let mut a = Vec::with_capacity(l);
                a.extend_from_slice(&d[..=j]);
                let mut k = i + 1;
                while a.len() < l {
                    a.push(c[k % l]);
                    k += 1;
                }
                // Offspring B: suffix of D ending at j-1 (taken cyclically
                // backwards), then C from i to the end.
                let mut b = Vec::with_capacity(l);
                let take = l - (l - i); // = i genes before c_i
                // d-window of length `take` ending just before j (cyclic)
                for t in 0..take {
                    let idx = (j + l - take + t) % l;
                    b.push(d[idx]);
                }
                b.extend_from_slice(&c[i..]);
                debug_assert_eq!(b.len(), l);
                return Some((a, b));
            }
        }
        None
    }
}

impl OffloadScheme for GaScheme {
    fn decide(&mut self, ctx: &OffloadContext) -> Vec<SatId> {
        let g = ctx.ga;
        let l = ctx.segments.len();
        if l == 0 {
            return Vec::new();
        }
        // Line 1: primitive group of N_ini random chromosomes.
        self.pop.clear();
        for _ in 0..g.n_ini {
            let chrom = self.random_chrom(ctx);
            let deficit = ctx.deficit(&chrom);
            self.pop.push(Individual { chrom, deficit });
        }
        let mut best_prev = f64::INFINITY;

        for iter in 0..g.n_iter {
            let best_now = self
                .pop
                .iter()
                .map(|i| i.deficit)
                .fold(f64::INFINITY, f64::min);
            // Line 3: early stop on convergence.
            if iter != 0 && (best_prev - best_now).abs() <= g.epsilon {
                break;
            }
            best_prev = best_now;

            // Line 6: reproduce distinct pairs via the heuristic splice.
            let parents = self.pop.len();
            let mut children: Vec<Individual> = Vec::new();
            for a in 0..parents {
                for b in (a + 1)..parents {
                    if self.pop[a].chrom == self.pop[b].chrom {
                        continue;
                    }
                    if let Some((x, y)) =
                        Self::reproduce(&self.pop[a].chrom, &self.pop[b].chrom)
                    {
                        let dx = ctx.deficit(&x);
                        let dy = ctx.deficit(&y);
                        children.push(Individual { chrom: x, deficit: dx });
                        children.push(Individual { chrom: y, deficit: dy });
                    }
                }
            }
            self.pop.extend(children);

            // Line 7: eliminate highest-deficit individuals until ≤ N_K.
            if self.pop.len() > g.n_k {
                self.pop
                    .sort_by(|a, b| a.deficit.partial_cmp(&b.deficit).unwrap());
                self.pop.truncate(g.n_k);
            }

            // Line 8: summon N_summ fresh chromosomes.
            for _ in 0..g.n_summ {
                let chrom = self.random_chrom(ctx);
                let deficit = ctx.deficit(&chrom);
                self.pop.push(Individual { chrom, deficit });
            }
        }

        // Line 10: the chromosome with the lowest deficit.
        self.pop
            .iter()
            .min_by(|a, b| a.deficit.partial_cmp(&b.deficit).unwrap())
            .map(|i| i.chrom.clone())
            .expect("population non-empty")
    }

    fn kind(&self) -> SchemeKind {
        SchemeKind::Scc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GaConfig;
    use crate::satellite::Satellite;
    use crate::topology::Torus;

    fn setup(n: usize) -> (Torus, Vec<Satellite>) {
        let torus = Torus::new(n);
        let sats = (0..torus.len())
            .map(|i| Satellite::new(i, 3000.0, 15000.0))
            .collect();
        (torus, sats)
    }

    fn ctx<'a>(
        torus: &'a Torus,
        sats: &'a [Satellite],
        cands: &'a [SatId],
        segs: &'a [f64],
        ga: &'a GaConfig,
    ) -> OffloadContext<'a> {
        OffloadContext {
            torus,
            satellites: sats,
            origin: cands[0],
            candidates: cands,
            segments: segs,
            kappa: 1e-4,
            ga,
        }
    }

    #[test]
    fn reproduce_keeps_length_and_shared_gene() {
        let c = vec![1usize, 2, 3, 4];
        let d = vec![5usize, 3, 6, 7];
        let (a, b) = GaScheme::reproduce(&c, &d).unwrap();
        assert_eq!(a.len(), 4);
        assert_eq!(b.len(), 4);
        // shared gene 3 (c_2 = d_1): offspring A starts with d-prefix [5,3]
        assert_eq!(&a[..2], &[5, 3]);
        // offspring B ends with c-suffix from the shared gene
        assert_eq!(&b[b.len() - 2..], &[3, 4]);
    }

    #[test]
    fn reproduce_none_when_disjoint() {
        assert!(GaScheme::reproduce(&[1, 2], &[3, 4]).is_none());
    }

    #[test]
    fn decision_within_candidates() {
        let (torus, sats) = setup(6);
        let ga = GaConfig::default();
        let cands = torus.decision_space(8, 2);
        let segs = vec![500.0, 700.0, 300.0];
        let c = ctx(&torus, &sats, &cands, &segs, &ga);
        let mut s = GaScheme::new(1);
        for _ in 0..10 {
            let chrom = s.decide(&c);
            assert_eq!(chrom.len(), 3);
            assert!(chrom.iter().all(|x| cands.contains(x)));
        }
    }

    #[test]
    fn ga_beats_random_on_deficit() {
        let (torus, mut sats) = setup(8);
        // heavily load half the neighborhood to create a real decision
        for i in 0..sats.len() {
            if i % 2 == 0 {
                sats[i].try_load(13_000.0);
            }
        }
        let ga = GaConfig::default();
        let cands = torus.decision_space(9, 3);
        let segs = vec![4000.0, 2500.0, 3500.0, 1500.0];
        let c = ctx(&torus, &sats, &cands, &segs, &ga);

        let mut g = GaScheme::new(2);
        let ga_deficit = c.deficit(&g.decide(&c));

        let mut rng = Pcg64::seed_from_u64(3);
        let mut rnd_total = 0.0;
        let trials = 50;
        for _ in 0..trials {
            let chrom: Vec<SatId> = (0..segs.len()).map(|_| *rng.choose(&cands)).collect();
            rnd_total += c.deficit(&chrom);
        }
        let rnd_mean = rnd_total / trials as f64;
        assert!(
            ga_deficit <= rnd_mean,
            "GA {ga_deficit} should beat mean random {rnd_mean}"
        );
    }

    #[test]
    fn ga_finds_near_optimal_small_instance() {
        // exhaustive optimum over a 5-candidate, L=2 instance
        let (torus, mut sats) = setup(4);
        sats[0].try_load(14_000.0);
        let ga = GaConfig {
            n_iter: 20,
            ..GaConfig::default()
        };
        let cands = torus.decision_space(0, 1); // 5 sats
        let segs = vec![2000.0, 2000.0];
        let c = ctx(&torus, &sats, &cands, &segs, &ga);
        let mut best = f64::INFINITY;
        for &a in &cands {
            for &b in &cands {
                best = best.min(c.deficit(&[a, b]));
            }
        }
        let mut g = GaScheme::new(4);
        let got = c.deficit(&g.decide(&c));
        assert!(
            got <= best * 1.001 + 1e-9,
            "GA {got} vs exhaustive {best}"
        );
    }

    #[test]
    fn converges_early_with_tight_epsilon() {
        // with a single candidate every chromosome is identical: the GA
        // must early-stop and still return a valid sequence
        let (torus, sats) = setup(4);
        let ga = GaConfig::default();
        let cands = vec![5usize];
        let segs = vec![100.0, 100.0];
        let c = ctx(&torus, &sats, &cands, &segs, &ga);
        let mut g = GaScheme::new(5);
        assert_eq!(g.decide(&c), vec![5, 5]);
    }

    #[test]
    fn empty_segments_ok() {
        let (torus, sats) = setup(4);
        let ga = GaConfig::default();
        let cands = torus.decision_space(0, 1);
        // L=3 but one block is empty (padded by Alg. 1)
        let segs = vec![500.0, 0.0, 300.0];
        let c = ctx(&torus, &sats, &cands, &segs, &ga);
        let mut g = GaScheme::new(6);
        let chrom = g.decide(&c);
        assert_eq!(chrom.len(), 3);
    }
}
