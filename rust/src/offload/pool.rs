//! Pooled GA generation evaluation — the `--decide-threads` knob.
//!
//! A persistent worker pool that splits one `deficit_batch` generation
//! into contiguous chromosome chunks evaluated concurrently into
//! disjoint, indexed slots of the shared output buffer. This is the
//! first perf axis that speeds up a *single* run instead of many: every
//! earlier layer parallelized across sweep cells or repeats, while the
//! GA inside one million-task run still burned one core.
//!
//! Determinism: chromosome deficits are independent per-chromosome
//! reductions — [`DecisionSpaceIndex::deficit_batch_slice`] carries no
//! state across chromosomes, and the SIMD lanes' scalar tails are
//! bitwise-equal to lane results — so splitting a generation at any
//! chunk boundary writes exactly the bytes a sequential pass would, at
//! any lane count. All RNG stays on the coordinator thread: workers only
//! read the index and write their own `out` range. Whole-run
//! byte-identity of `--decide-threads K` vs `1` is enforced by
//! `tests/prop_pool.rs` across both engines and all four schemes.
//!
//! std::thread only — no new dependencies. The pool is persistent
//! (workers park on a condvar between generations) because one GA decide
//! dispatches hundreds of small generations; spawning threads per
//! generation would cost more than the evaluation itself.

use super::{BatchScratch, DecisionSpaceIndex, Gene};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Below this many chromosomes per lane the dispatch/wake overhead beats
/// the win, so the coordinator evaluates the generation inline instead
/// (same bytes either way — only the schedule changes).
const MIN_CHUNK: usize = 16;

/// Resolve the `--decide-threads` knob to a concrete lane count:
/// `0` = auto (one lane per available core), anything else is taken
/// literally. `1` is the sequential oracle.
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        threads
    }
}

/// One dispatched generation: raw views of the coordinator's borrows.
/// Valid strictly while the coordinator blocks in
/// [`EvalPool::deficit_batch`] waiting for `pending == 0`, which is what
/// lets a persistent ('static) worker touch non-'static borrows.
#[derive(Clone, Copy)]
struct Job {
    index: *const DecisionSpaceIndex,
    genes: *const Gene,
    genes_len: usize,
    out: *mut f64,
    /// Chromosome count of the generation.
    n: usize,
    /// Total lanes this generation was split into (workers + the
    /// coordinator, which evaluates chunk 0 itself).
    lanes: usize,
}

// SAFETY: the pointers are only dereferenced between dispatch and the
// coordinator's completion wait, while the underlying borrows are live
// and the per-lane ranges are disjoint.
unsafe impl Send for Job {}

struct JobState {
    /// Monotone dispatch counter; a worker runs a job exactly once when
    /// it observes a seq newer than the last one it completed.
    seq: u64,
    job: Option<Job>,
    /// Worker chunks not yet completed for the current seq.
    pending: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<JobState>,
    /// Workers park here between generations.
    work: Condvar,
    /// The coordinator parks here until `pending == 0`.
    done: Condvar,
}

/// Contiguous chunk `t` of `n` items split `lanes` ways: `n·t/lanes`
/// bounds, so chunk sizes differ by at most one and cover exactly
/// `[0, n)`.
fn chunk_bounds(n: usize, lanes: usize, t: usize) -> (usize, usize) {
    (n * t / lanes, n * (t + 1) / lanes)
}

fn worker_loop(shared: Arc<Shared>, worker: usize) {
    let mut scratch = BatchScratch::default();
    let mut last_seq = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.seq > last_seq {
                    if let Some(job) = st.job {
                        last_seq = st.seq;
                        break job;
                    }
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        // This worker's fixed chunk: `worker + 1` (the coordinator runs
        // chunk 0 concurrently).
        let (lo, hi) = chunk_bounds(job.n, job.lanes, worker + 1);
        if hi > lo {
            // SAFETY: the coordinator blocks until every worker reports
            // done, so the borrows behind these pointers outlive this
            // block; chunk ranges are disjoint, so the slices alias
            // nothing — see `Job`.
            unsafe {
                let index = &*job.index;
                let l = index.segments.len();
                debug_assert_eq!(job.genes_len, job.n * l);
                let genes = std::slice::from_raw_parts(job.genes.add(lo * l), (hi - lo) * l);
                let out = std::slice::from_raw_parts_mut(job.out.add(lo), hi - lo);
                index.deficit_batch_slice(&mut scratch, genes, out);
            }
        }
        let mut st = shared.state.lock().unwrap();
        st.pending -= 1;
        if st.pending == 0 {
            shared.done.notify_one();
        }
    }
}

/// Persistent pooled evaluator for GA generations. One per
/// [`super::ga::GaScheme`] when `--decide-threads` resolves above 1; the
/// coordinator (the engine thread driving the GA) counts as one lane and
/// evaluates chunk 0 itself, so `lanes - 1` workers are spawned.
pub struct EvalPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    lanes: usize,
}

impl EvalPool {
    /// Build a pool evaluating generations `threads` ways
    /// ([`resolve_threads`] semantics: 0 = auto). Callers should keep the
    /// plain sequential path instead of a 1-lane pool — `GaScheme` only
    /// constructs one when the resolved count exceeds 1 — but a 1-lane
    /// pool is still correct (every generation evaluates inline).
    pub fn new(threads: usize) -> EvalPool {
        let lanes = resolve_threads(threads).max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(JobState {
                seq: 0,
                job: None,
                pending: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let workers = (0..lanes - 1)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("satkit-eval-{i}"))
                    .spawn(move || worker_loop(sh, i))
                    .expect("spawning pooled-eval worker")
            })
            .collect();
        EvalPool {
            shared,
            workers,
            lanes,
        }
    }

    /// Lane count (workers + coordinator) generations are split into.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Evaluate one generation into `out`, producing exactly the bytes
    /// [`DecisionSpaceIndex::deficit_batch`] would. Generations too small
    /// to amortize a wake-up, empty decision spaces, and the `L > 128`
    /// fallback all run inline on the coordinator.
    pub fn deficit_batch(
        &self,
        index: &DecisionSpaceIndex,
        scratch: &mut BatchScratch,
        genes: &[Gene],
        out: &mut Vec<f64>,
    ) {
        let l = index.segments.len();
        let n = if l == 0 { 0 } else { genes.len() / l };
        if self.lanes <= 1 || l == 0 || l > 128 || n < self.lanes * MIN_CHUNK {
            index.deficit_batch(scratch, genes, out);
            return;
        }
        debug_assert_eq!(genes.len() % l, 0, "ragged chromosome matrix");
        out.clear();
        out.resize(n, 0.0);
        // From here until the completion wait below, `out` is only
        // touched through `base` + disjoint per-lane ranges.
        let base = out.as_mut_ptr();
        {
            let mut st = self.shared.state.lock().unwrap();
            st.seq += 1;
            st.pending = self.lanes - 1;
            st.job = Some(Job {
                index,
                genes: genes.as_ptr(),
                genes_len: genes.len(),
                out: base,
                n,
                lanes: self.lanes,
            });
            self.shared.work.notify_all();
        }
        // The coordinator's own share: chunk 0.
        let (lo, hi) = chunk_bounds(n, self.lanes, 0);
        // SAFETY: disjoint from every worker chunk (chunk_bounds ranges
        // partition [0, n)).
        let out0 = unsafe { std::slice::from_raw_parts_mut(base.add(lo), hi - lo) };
        index.deficit_batch_slice(scratch, &genes[lo * l..hi * l], out0);
        let mut st = self.shared.state.lock().unwrap();
        while st.pending != 0 {
            st = self.shared.done.wait(st).unwrap();
        }
        st.job = None;
    }
}

impl Drop for EvalPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::test_ctx;
    use super::super::{BatchScratch, DecisionSpaceIndex, Gene};
    use super::{chunk_bounds, resolve_threads, EvalPool};
    use crate::config::GaConfig;
    use crate::satellite::Satellite;
    use crate::topology::Constellation;
    use crate::util::rng::Pcg64;

    fn built_index() -> DecisionSpaceIndex {
        let topo = Constellation::torus(6);
        let mut rng = Pcg64::seed_from_u64(17);
        let sats: Vec<Satellite> = (0..topo.len())
            .map(|i| {
                let mut s = Satellite::new(i, 3000.0, 15_000.0);
                s.try_load(rng.f64_in(0.0, 12_000.0));
                s
            })
            .collect();
        let cands = topo.decision_space(7, 2);
        let segs = [4000.0, 1500.0, 3500.0, 2800.0];
        let ga = GaConfig::default();
        let ctx = test_ctx(&topo, &sats, &cands, &segs, &ga);
        DecisionSpaceIndex::from_ctx(&ctx)
    }

    fn random_batch(index: &DecisionSpaceIndex, n: usize, seed: u64) -> Vec<Gene> {
        let mut rng = Pcg64::new(seed, 0xB00);
        let nc = index.n_cands();
        let l = index.n_segments();
        (0..n * l)
            .map(|_| rng.usize_in(0, nc) as Gene)
            .collect()
    }

    #[test]
    fn chunk_bounds_partition_without_gaps() {
        for n in [0usize, 1, 7, 64, 65, 4096] {
            for lanes in [1usize, 2, 3, 4, 7] {
                let mut covered = 0usize;
                for t in 0..lanes {
                    let (lo, hi) = chunk_bounds(n, lanes, t);
                    assert_eq!(lo, covered, "n={n} lanes={lanes} t={t}");
                    assert!(hi >= lo);
                    covered = hi;
                }
                assert_eq!(covered, n);
            }
        }
    }

    #[test]
    fn pooled_batch_is_bitwise_equal_to_sequential() {
        let index = built_index();
        let mut scratch = BatchScratch::default();
        let mut seq = Vec::new();
        let mut pooled = Vec::new();
        for threads in [2usize, 3, 4] {
            let pool = EvalPool::new(threads);
            // Sizes straddle the inline cutoff, SIMD lane tails, and
            // uneven chunk splits.
            for n in [0usize, 1, 5, 63, 64, 129, 500] {
                let genes = random_batch(&index, n, 42 + n as u64);
                index.deficit_batch(&mut scratch, &genes, &mut seq);
                pool.deficit_batch(&index, &mut scratch, &genes, &mut pooled);
                assert_eq!(seq.len(), pooled.len());
                for (i, (a, b)) in seq.iter().zip(&pooled).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "threads={threads} n={n} chrom={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn pool_survives_repeated_dispatches() {
        let index = built_index();
        let mut scratch = BatchScratch::default();
        let pool = EvalPool::new(4);
        let mut seq = Vec::new();
        let mut pooled = Vec::new();
        for round in 0..50u64 {
            let genes = random_batch(&index, 200, round);
            index.deficit_batch(&mut scratch, &genes, &mut seq);
            pool.deficit_batch(&index, &mut scratch, &genes, &mut pooled);
            assert_eq!(seq, pooled, "round {round}");
        }
    }

    #[test]
    fn auto_resolves_to_at_least_one_lane() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(1), 1);
        assert_eq!(resolve_threads(6), 6);
    }
}
