//! *Random* baseline (§V-A): each segment's satellite is selected
//! independently and uniformly at random from the decision space `A_x`.
//! Theoretically achieves a perfectly even long-run workload distribution
//! (the Fig. 2(c)/3(c) reference point) but ignores loads and distance,
//! so it drops more tasks and pays more transmission delay.

use super::{OffloadContext, OffloadScheme, SchemeKind};
use crate::topology::SatId;
use crate::util::rng::Pcg64;

pub struct RandomScheme {
    rng: Pcg64,
}

impl RandomScheme {
    pub fn new(seed: u64) -> RandomScheme {
        RandomScheme {
            rng: Pcg64::new(seed, 0x5A4D),
        }
    }
}

impl OffloadScheme for RandomScheme {
    fn decide_into(&mut self, ctx: &OffloadContext, out: &mut Vec<SatId>) {
        out.clear();
        out.reserve(ctx.segments.len());
        for _ in 0..ctx.segments.len() {
            out.push(*self.rng.choose(ctx.candidates));
        }
    }

    fn kind(&self) -> SchemeKind {
        SchemeKind::Random
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GaConfig;
    use crate::satellite::Satellite;
    use crate::topology::Constellation;

    #[test]
    fn picks_only_candidates_and_right_length() {
        let topo = Constellation::torus(6);
        let sats: Vec<Satellite> = (0..36).map(|i| Satellite::new(i, 3000.0, 15000.0)).collect();
        let cands = topo.decision_space(7, 2);
        let segs = vec![100.0; 5];
        let ga = GaConfig::default();
        let ctx = OffloadContext {
            topo: &topo,
            view: crate::state::StateView::live(&sats),
            origin: 7,
            candidates: &cands,
            segments: &segs,
            kappa: 1e-4,
            ga: &ga,
            migration: None,
            outages: None,
        };
        let mut s = RandomScheme::new(3);
        for _ in 0..50 {
            let c = s.decide(&ctx);
            assert_eq!(c.len(), 5);
            assert!(c.iter().all(|x| cands.contains(x)));
        }
    }

    #[test]
    fn spreads_over_candidates() {
        let topo = Constellation::torus(8);
        let sats: Vec<Satellite> = (0..64).map(|i| Satellite::new(i, 3000.0, 15000.0)).collect();
        let cands = topo.decision_space(0, 2);
        let segs = vec![1.0];
        let ga = GaConfig::default();
        let ctx = OffloadContext {
            topo: &topo,
            view: crate::state::StateView::live(&sats),
            origin: 0,
            candidates: &cands,
            segments: &segs,
            kappa: 1e-4,
            ga: &ga,
            migration: None,
            outages: None,
        };
        let mut s = RandomScheme::new(4);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..400 {
            seen.insert(s.decide(&ctx)[0]);
        }
        // 13 candidates; a uniform picker should hit nearly all of them
        assert!(seen.len() >= cands.len() - 1, "seen {}", seen.len());
    }
}
