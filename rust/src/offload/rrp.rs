//! *Residual-Resource-Priority (RRP)* baseline (§V-A): "selects the
//! available satellites with the most residual computing resources to
//! process the **next segment**" — i.e. per-segment re-selection of the
//! residual argmax, accounting the workload its own earlier segments
//! already planned onto a candidate. Because the argmax after placing
//! segment k is generally a *different* satellite, RRP's sequences zigzag
//! between the fittest satellites regardless of distance — the
//! load-oblivious-to-topology behaviour §V-B blames for its delay — and
//! every decision satellite chases the same fittest targets
//! ("a particular satellite is chosen by multiple decision-making
//! satellites"), hurting balance.

use super::{OffloadContext, OffloadScheme, SchemeKind};
use crate::topology::SatId;

#[derive(Default)]
pub struct RrpScheme {
    /// Candidate-local workload planned by the current task's earlier
    /// segments (indexed by candidate position; reused across decisions so
    /// the per-task hot path allocates nothing). Accumulation order equals
    /// the old association-list sum order, so decisions are unchanged.
    planned: Vec<f64>,
}

impl RrpScheme {
    pub fn new() -> RrpScheme {
        RrpScheme::default()
    }
}

impl OffloadScheme for RrpScheme {
    fn decide_into(&mut self, ctx: &OffloadContext, out: &mut Vec<SatId>) {
        out.clear();
        out.reserve(ctx.segments.len());
        self.planned.clear();
        self.planned.resize(ctx.candidates.len(), 0.0);
        for &q in ctx.segments {
            let best_pos = (0..ctx.candidates.len())
                .max_by(|&i, &j| {
                    let ri =
                        (ctx.view.residual(ctx.candidates[i]) - self.planned[i]).max(0.0);
                    let rj =
                        (ctx.view.residual(ctx.candidates[j]) - self.planned[j]).max(0.0);
                    ri.partial_cmp(&rj)
                        .unwrap()
                        // deterministic tie-break: lower id wins
                        .then(ctx.candidates[j].cmp(&ctx.candidates[i]))
                })
                .expect("non-empty candidate set");
            self.planned[best_pos] += q;
            out.push(ctx.candidates[best_pos]);
        }
    }

    fn kind(&self) -> SchemeKind {
        SchemeKind::Rrp
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GaConfig;
    use crate::satellite::Satellite;
    use crate::topology::Constellation;

    fn ctx_with<'a>(
        topo: &'a Constellation,
        sats: &'a [Satellite],
        cands: &'a [SatId],
        segs: &'a [f64],
        ga: &'a GaConfig,
    ) -> OffloadContext<'a> {
        OffloadContext {
            topo,
            view: crate::state::StateView::live(sats),
            origin: 0,
            candidates: cands,
            segments: segs,
            kappa: 1e-4,
            ga,
            migration: None,
            outages: None,
        }
    }

    #[test]
    fn picks_most_residual() {
        let topo = Constellation::torus(4);
        let mut sats: Vec<Satellite> =
            (0..16).map(|i| Satellite::new(i, 3000.0, 15000.0)).collect();
        let cands = topo.decision_space(0, 1);
        for &c in &cands {
            if c != 1 {
                sats[c].try_load(10_000.0);
            }
        }
        let segs = vec![100.0];
        let ga = GaConfig::default();
        let ctx = ctx_with(&topo, &sats, &cands, &segs, &ga);
        assert!(cands.contains(&1));
        assert_eq!(RrpScheme::new().decide(&ctx), vec![1]);
    }

    #[test]
    fn zigzags_across_fittest_satellites() {
        // equal big segments: after planning seg1 on the argmax, the next
        // argmax is a different satellite — the sequence hops
        let topo = Constellation::torus(4);
        let mut sats: Vec<Satellite> =
            (0..16).map(|i| Satellite::new(i, 3000.0, 15000.0)).collect();
        let cands = topo.decision_space(0, 1);
        for (i, &c) in cands.iter().enumerate() {
            sats[c].try_load(100.0 * i as f64); // strictly ordered residuals
        }
        let segs = vec![8_000.0, 8_000.0];
        let ga = GaConfig::default();
        let ctx = ctx_with(&topo, &sats, &cands, &segs, &ga);
        let chrom = RrpScheme::new().decide(&ctx);
        assert_ne!(chrom[0], chrom[1], "expected per-segment re-selection");
    }

    #[test]
    fn accounts_for_own_planned_segments() {
        let topo = Constellation::torus(4);
        let mut sats: Vec<Satellite> =
            (0..16).map(|i| Satellite::new(i, 3000.0, 15000.0)).collect();
        let cands = topo.decision_space(0, 1);
        for &c in &cands {
            match c {
                1 => {}
                4 => {
                    sats[c].try_load(100.0);
                }
                c2 => {
                    sats[c2].try_load(5_000.0);
                }
            }
        }
        let segs = vec![8_000.0, 8_000.0];
        let ga = GaConfig::default();
        let ctx = ctx_with(&topo, &sats, &cands, &segs, &ga);
        let chrom = RrpScheme::new().decide(&ctx);
        assert_eq!(chrom[0], 1);
        assert_eq!(chrom[1], 4);
    }

    #[test]
    fn deterministic() {
        let topo = Constellation::torus(5);
        let sats: Vec<Satellite> =
            (0..25).map(|i| Satellite::new(i, 3000.0, 15000.0)).collect();
        let cands = topo.decision_space(2, 2);
        let segs = vec![10.0, 10.0, 10.0];
        let ga = GaConfig::default();
        let ctx = ctx_with(&topo, &sats, &cands, &segs, &ga);
        assert_eq!(RrpScheme::new().decide(&ctx), RrpScheme::new().decide(&ctx));
    }
}
