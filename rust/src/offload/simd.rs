//! Explicit SIMD lanes for [`DecisionSpaceIndex::deficit_batch`]
//! (`simd` cargo feature): 4-wide AVX2 on x86_64 (runtime-detected) and
//! 2-wide NEON on aarch64 (baseline), over the k-major `comp_lut` / `kq`
//! fixed-stride layout the scalar kernel already uses.
//!
//! Chromosomes are the lanes: lane `i` of every vector holds chromosome
//! `base + i`'s accumulator, and the k-loop walks segments exactly like
//! the scalar kernel, so every lane performs the scalar kernel's adds in
//! the scalar kernel's order. The Eq. 4 admission walk — the last scalar
//! stretch — runs as bitmask lanes: `admitted[j] AND genes[j] == genes[k]`
//! masks each `segments[j]` contribution, and a masked-out lane adds
//! `+0.0`, which is bit-identical to the scalar skip because planned
//! prefixes are never `-0.0` (workloads are non-negative). The final
//! `θ1·comp + θ2·tran + θ3·drops` combine uses discrete mul/add
//! intrinsics — never FMA — in the scalar's association order. Results
//! are therefore **bit-for-bit identical** to
//! [`DecisionSpaceIndex::deficit_batch`]'s scalar body (enforced by
//! `tests/prop_sharded.rs::prop_deficit_batch_simd_matches_scalar`).
//!
//! The `n % LANES` chromosome tail goes through the scalar
//! [`DecisionSpaceIndex::deficit`] (bit-identical by the existing batch
//! oracle property). Chromosomes longer than `ADM_MAX_L` fall back to the
//! scalar admission walk per lane — Table-I L is 3–4, so real runs never
//! take that branch.

use super::{DecisionSpaceIndex, Gene};

/// True when this build + CPU dispatches `deficit_batch` to SIMD lanes.
pub(super) fn active() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2")
    }
    #[cfg(target_arch = "aarch64")]
    {
        true
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        false
    }
}

/// Evaluate the whole batch with SIMD lanes into the pre-sized `out`
/// slots. Returns false (leaving `out` untouched) when the CPU lacks the
/// lanes — the caller then runs the scalar body. The caller guarantees
/// `1 <= L <= 128`, a non-empty, non-ragged `genes` matrix, and
/// `out.len() · L == genes.len()`. Writing slots instead of pushing lets
/// the pooled evaluator hand each worker its own disjoint sub-range.
pub(super) fn deficit_batch(
    index: &DecisionSpaceIndex,
    genes: &[Gene],
    out: &mut [f64],
) -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            // SAFETY: AVX2 presence verified at runtime just above.
            unsafe { avx2::deficit_batch(index, genes, out) };
            return true;
        }
        false
    }
    #[cfg(target_arch = "aarch64")]
    {
        // SAFETY: NEON is part of the aarch64 baseline.
        unsafe { neon::deficit_batch(index, genes, out) };
        true
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        let _ = (index, genes, out);
        false
    }
}

/// Admission-walk lane history is kept in vector masks up to this L;
/// longer chromosomes use the scalar walk per lane (never hit by real
/// configs — Table I has L = 3–4).
const ADM_MAX_L: usize = 16;

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::super::{DecisionSpaceIndex, Gene};
    use super::ADM_MAX_L;
    use std::arch::x86_64::*;

    const LANES: usize = 4;

    /// Gene indices of segment `k` for four consecutive chromosomes
    /// starting at `base`, as the i32 offsets a gather consumes.
    #[inline(always)]
    unsafe fn gene_idx(genes: &[Gene], base: usize, l: usize, k: usize) -> __m128i {
        _mm_set_epi32(
            genes[base + 3 * l + k] as i32,
            genes[base + 2 * l + k] as i32,
            genes[base + l + k] as i32,
            genes[base + k] as i32,
        )
    }

    /// One lane of the θ2 term: `hops[a·nc + b]` as f64 (the hop LUT is
    /// u16, so lanes are built scalar and combined).
    #[inline(always)]
    fn hop(index: &DecisionSpaceIndex, genes: &[Gene], row: usize, k: usize, nc: usize) -> f64 {
        let a = genes[row + k] as usize;
        let b = genes[row + k + 1] as usize;
        index.hops[a * nc + b] as f64
    }

    /// Eq. 4 admission walk, four chromosome lanes wide, bitmask lanes
    /// for the admitted-prefix history. Per-lane float operations match
    /// the scalar walk's order exactly; masked-out contributions add
    /// `+0.0` (bit-safe — planned prefixes are never `-0.0`).
    #[target_feature(enable = "avx2")]
    unsafe fn admission_lanes(
        index: &DecisionSpaceIndex,
        genes: &[Gene],
        base: usize,
        l: usize,
    ) -> __m256d {
        let mut gene_v = [_mm256_setzero_si256(); ADM_MAX_L];
        let mut adm = [_mm256_setzero_si256(); ADM_MAX_L];
        for k in 0..l {
            gene_v[k] = _mm256_set_epi64x(
                genes[base + 3 * l + k] as i64,
                genes[base + 2 * l + k] as i64,
                genes[base + l + k] as i64,
                genes[base + k] as i64,
            );
        }
        let ones = _mm256_set1_pd(1.0);
        let all_bits = _mm256_castsi256_pd(_mm256_set1_epi64x(-1));
        let mut drops = _mm256_setzero_pd();
        for k in 0..l {
            let q = index.segments[k];
            let mut planned = _mm256_setzero_pd();
            for j in 0..k {
                // admitted[j] && genes[j] == genes[k], as full lane masks
                let eq = _mm256_cmpeq_epi64(gene_v[j], gene_v[k]);
                let m = _mm256_castsi256_pd(_mm256_and_si256(eq, adm[j]));
                let add = _mm256_and_pd(m, _mm256_set1_pd(index.segments[j]));
                planned = _mm256_add_pd(planned, add);
            }
            let gk = gene_idx(genes, base, l, k);
            let loaded = _mm256_i32gather_pd::<8>(index.loaded.as_ptr(), gk);
            let maxw = _mm256_i32gather_pd::<8>(index.max_workload.as_ptr(), gk);
            // (loaded + planned) + q — the scalar's association order
            let tot = _mm256_add_pd(_mm256_add_pd(loaded, planned), _mm256_set1_pd(q));
            // drop where q > 0 (lane-uniform: segments are shared) and
            // the planned total reaches the workload cap
            let dropm = if q > 0.0 {
                _mm256_cmp_pd::<{ _CMP_GE_OQ }>(tot, maxw)
            } else {
                _mm256_setzero_pd()
            };
            drops = _mm256_add_pd(drops, _mm256_and_pd(dropm, ones));
            adm[k] = _mm256_castpd_si256(_mm256_andnot_pd(dropm, all_bits));
        }
        drops
    }

    #[target_feature(enable = "avx2")]
    pub(in super::super) unsafe fn deficit_batch(
        index: &DecisionSpaceIndex,
        genes: &[Gene],
        out: &mut [f64],
    ) {
        let l = index.segments.len();
        let n = genes.len() / l;
        let nc = index.sat_ids.len();
        let main = n - n % LANES;
        let mut i = 0usize;
        while i < main {
            let base = i * l;
            let mut comp = _mm256_setzero_pd();
            let mut tran = _mm256_setzero_pd();
            for k in 0..l {
                let lut = index.comp_lut.as_ptr().add(k * nc);
                let v = _mm256_i32gather_pd::<8>(lut, gene_idx(genes, base, l, k));
                comp = _mm256_add_pd(comp, v);
            }
            for k in 0..l - 1 {
                let kq = _mm256_set1_pd(index.kq[k]);
                let h = _mm256_set_pd(
                    hop(index, genes, base + 3 * l, k, nc),
                    hop(index, genes, base + 2 * l, k, nc),
                    hop(index, genes, base + l, k, nc),
                    hop(index, genes, base, k, nc),
                );
                tran = _mm256_add_pd(tran, _mm256_mul_pd(kq, h));
            }
            let drops = if l <= ADM_MAX_L {
                admission_lanes(index, genes, base, l)
            } else {
                _mm256_set_pd(
                    index.admission_drops(&genes[base + 3 * l..base + 4 * l]),
                    index.admission_drops(&genes[base + 2 * l..base + 3 * l]),
                    index.admission_drops(&genes[base + l..base + 2 * l]),
                    index.admission_drops(&genes[base..base + l]),
                )
            };
            // θ1·comp + θ2·tran + θ3·drops, discrete mul/add (no FMA),
            // scalar association order
            let d = _mm256_add_pd(
                _mm256_add_pd(
                    _mm256_mul_pd(_mm256_set1_pd(index.theta1), comp),
                    _mm256_mul_pd(_mm256_set1_pd(index.theta2), tran),
                ),
                _mm256_mul_pd(_mm256_set1_pd(index.theta3), drops),
            );
            _mm256_storeu_pd(out.as_mut_ptr().add(i), d);
            i += LANES;
        }
        // scalar tail for the trailing n % LANES chromosomes
        for (j, c) in genes[main * l..].chunks(l).enumerate() {
            out[main + j] = index.deficit(c);
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::super::{DecisionSpaceIndex, Gene};
    use super::ADM_MAX_L;
    use std::arch::aarch64::*;

    const LANES: usize = 2;

    /// Two f64 lanes, lane 0 first.
    #[inline(always)]
    unsafe fn make2(e0: f64, e1: f64) -> float64x2_t {
        let arr = [e0, e1];
        vld1q_f64(arr.as_ptr())
    }

    /// Gene values of segment `k` for two consecutive chromosomes as u64
    /// lanes (for bitmask equality in the admission walk).
    #[inline(always)]
    unsafe fn gene2(genes: &[Gene], base: usize, l: usize, k: usize) -> uint64x2_t {
        let arr = [genes[base + k] as u64, genes[base + l + k] as u64];
        vld1q_u64(arr.as_ptr())
    }

    #[inline(always)]
    fn hop(index: &DecisionSpaceIndex, genes: &[Gene], row: usize, k: usize, nc: usize) -> f64 {
        let a = genes[row + k] as usize;
        let b = genes[row + k + 1] as usize;
        index.hops[a * nc + b] as f64
    }

    /// Eq. 4 admission walk, two chromosome lanes wide — the NEON mirror
    /// of the AVX2 bitmask-lane walk.
    unsafe fn admission_lanes(
        index: &DecisionSpaceIndex,
        genes: &[Gene],
        base: usize,
        l: usize,
    ) -> float64x2_t {
        let mut gene_v = [vdupq_n_u64(0); ADM_MAX_L];
        let mut adm = [vdupq_n_u64(0); ADM_MAX_L];
        for k in 0..l {
            gene_v[k] = gene2(genes, base, l, k);
        }
        let ones = vreinterpretq_u64_f64(vdupq_n_f64(1.0));
        let all_bits = vdupq_n_u64(!0u64);
        let mut drops = vdupq_n_f64(0.0);
        for k in 0..l {
            let q = index.segments[k];
            let mut planned = vdupq_n_f64(0.0);
            for j in 0..k {
                let eq = vceqq_u64(gene_v[j], gene_v[k]);
                let m = vandq_u64(eq, adm[j]);
                let add = vreinterpretq_f64_u64(vandq_u64(
                    m,
                    vreinterpretq_u64_f64(vdupq_n_f64(index.segments[j])),
                ));
                planned = vaddq_f64(planned, add);
            }
            let loaded = make2(
                index.loaded[genes[base + k] as usize],
                index.loaded[genes[base + l + k] as usize],
            );
            let maxw = make2(
                index.max_workload[genes[base + k] as usize],
                index.max_workload[genes[base + l + k] as usize],
            );
            // (loaded + planned) + q — the scalar's association order
            let tot = vaddq_f64(vaddq_f64(loaded, planned), vdupq_n_f64(q));
            let dropm = if q > 0.0 {
                vcgeq_f64(tot, maxw)
            } else {
                vdupq_n_u64(0)
            };
            drops = vaddq_f64(drops, vreinterpretq_f64_u64(vandq_u64(dropm, ones)));
            // admitted[k] = !drop  (BIC: all_bits AND NOT dropm)
            adm[k] = vbicq_u64(all_bits, dropm);
        }
        drops
    }

    pub(in super::super) unsafe fn deficit_batch(
        index: &DecisionSpaceIndex,
        genes: &[Gene],
        out: &mut [f64],
    ) {
        let l = index.segments.len();
        let n = genes.len() / l;
        let nc = index.sat_ids.len();
        let main = n - n % LANES;
        let mut i = 0usize;
        while i < main {
            let base = i * l;
            let mut comp = vdupq_n_f64(0.0);
            let mut tran = vdupq_n_f64(0.0);
            for k in 0..l {
                let lut = &index.comp_lut[k * nc..(k + 1) * nc];
                let v = make2(
                    lut[genes[base + k] as usize],
                    lut[genes[base + l + k] as usize],
                );
                comp = vaddq_f64(comp, v);
            }
            for k in 0..l - 1 {
                let kq = vdupq_n_f64(index.kq[k]);
                let h = make2(
                    hop(index, genes, base, k, nc),
                    hop(index, genes, base + l, k, nc),
                );
                tran = vaddq_f64(tran, vmulq_f64(kq, h));
            }
            let drops = if l <= ADM_MAX_L {
                admission_lanes(index, genes, base, l)
            } else {
                make2(
                    index.admission_drops(&genes[base..base + l]),
                    index.admission_drops(&genes[base + l..base + 2 * l]),
                )
            };
            // θ1·comp + θ2·tran + θ3·drops, discrete mul/add (no FMA),
            // scalar association order
            let d = vaddq_f64(
                vaddq_f64(
                    vmulq_f64(vdupq_n_f64(index.theta1), comp),
                    vmulq_f64(vdupq_n_f64(index.theta2), tran),
                ),
                vmulq_f64(vdupq_n_f64(index.theta3), drops),
            );
            vst1q_f64(out.as_mut_ptr().add(i), d);
            i += LANES;
        }
        // scalar tail for the trailing n % LANES chromosomes
        for (j, c) in genes[main * l..].chunks(l).enumerate() {
            out[main + j] = index.deficit(c);
        }
    }
}
