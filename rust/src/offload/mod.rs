//! Task offloading schemes (§IV-B, §V-A): the paper's GA-based SCC scheme
//! plus the three baselines it is evaluated against (Random, RRP, DQN).
//!
//! A scheme maps one split task — segment workloads `{q_1..q_L}` plus the
//! current network state — to a processing sequence `(c_1, …, c_L)`
//! (the "chromosome"): segment k executes on satellite c_k, intermediate
//! activations hop `MH(c_k, c_{k+1})` ISLs (Eq. 7).

pub mod dqn;
pub mod ga;
pub mod pool;
pub mod random;
pub mod rrp;
#[cfg(feature = "simd")]
mod simd;

use crate::config::GaConfig;
use crate::resilience::OutageMap;
use crate::state::StateView;
use crate::topology::{Constellation, SatId};
use crate::util::json::Json;

/// Which scheme to run (CLI / experiment selector).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SchemeKind {
    /// The paper's proposal (Alg. 1 + Alg. 2 GA offloading).
    Scc,
    Random,
    Rrp,
    Dqn,
}

impl SchemeKind {
    pub fn parse(s: &str) -> Result<SchemeKind, String> {
        match s.to_ascii_lowercase().as_str() {
            "scc" | "ga" => Ok(SchemeKind::Scc),
            "random" => Ok(SchemeKind::Random),
            "rrp" => Ok(SchemeKind::Rrp),
            "dqn" => Ok(SchemeKind::Dqn),
            other => Err(format!("unknown scheme '{other}' (scc|random|rrp|dqn)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchemeKind::Scc => "SCC",
            SchemeKind::Random => "Random",
            SchemeKind::Rrp => "RRP",
            SchemeKind::Dqn => "DQN",
        }
    }

    pub fn all() -> [SchemeKind; 4] {
        [
            SchemeKind::Scc,
            SchemeKind::Random,
            SchemeKind::Rrp,
            SchemeKind::Dqn,
        ]
    }
}

/// Sticky-state surcharge for re-placing a live autoregressive task:
/// its KV-cache (`state_bytes`) lives on `from`, so any placement whose
/// final decode satellite differs pays `secs_per_hop · MH(from, c_L)` of
/// extra ISL transmission (Eq. 7 reuse over the state size instead of an
/// activation cut). `None` for one-shot tasks — the deficit is then
/// bit-for-bit the pre-LLM expression.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MigrationCost {
    /// Satellite currently holding the task's KV-cache state.
    pub from: SatId,
    /// ISL seconds per hop to ship the state (`IslLink::hop_secs(state_bytes)`).
    pub secs_per_hop: f64,
}

/// Everything a scheme may observe when deciding (local view of the
/// decision-making satellite: its decision space and those satellites'
/// resource state — §I's "local observations").
///
/// Schemes never read live satellite state: `view` is the disseminated
/// [`StateView`] maintained by the engine's
/// [`crate::state::ViewTracker`], so decision staleness
/// (`--dissemination instant|periodic:<s>|gossip`) is modeled uniformly
/// across all four schemes and both engines.
pub struct OffloadContext<'a> {
    /// Constellation topology: ISL hop distances and the decision space
    /// come from here (torus, walker-delta, or walker-star).
    pub topo: &'a Constellation,
    /// Disseminated resource-state view of the deciding satellite.
    pub view: StateView<'a>,
    /// Decision-making satellite x (task origin).
    pub origin: SatId,
    /// A_x — candidate satellites within D_M of x (constraint 11c).
    pub candidates: &'a [SatId],
    /// Per-segment workloads {q_1..q_L} [MFLOP] from Alg. 1.
    pub segments: &'a [f64],
    /// κ — ISL transfer coefficient [s per MFLOP·hop] (Eq. 7 scaling).
    pub kappa: f64,
    pub ga: &'a GaConfig,
    /// Sticky-state migration surcharge (autoregressive tasks only);
    /// `None` leaves every deficit bit-for-bit unchanged.
    pub migration: Option<MigrationCost>,
    /// Outage-masked ISL distances ([`crate::resilience::OutageMap`]):
    /// when link faults are active the θ2 tran term prices hops over the
    /// *alive* topology — a chromosome routed across a dead link pays the
    /// detour (or [`crate::resilience::UNREACHABLE_HOPS`] when the pair is
    /// partitioned), steering schemes away from severed regions. `None`
    /// (the default, and every run without link faults) leaves each
    /// deficit bit-for-bit the legacy expression on `topo.hops`.
    pub outages: Option<&'a OutageMap>,
}

impl<'a> OffloadContext<'a> {
    /// ISL hop distance the θ2 tran term prices: the outage-masked
    /// distance when an [`OutageMap`] is attached (detours around dead
    /// links; `UNREACHABLE_HOPS` across a partition), the plain
    /// [`Constellation::hops`] otherwise. The sticky-state migration term
    /// intentionally stays on `topo.hops`: the KV-cache ship happens
    /// after recovery settles, so it is priced on the nominal topology.
    #[inline]
    fn hop_count(&self, a: SatId, b: SatId) -> usize {
        match self.outages {
            Some(o) => o.hops_or_penalty(a, b),
            None => self.topo.hops(a, b),
        }
    }

    /// Eq. 12 deficit of a chromosome `(d_1..d_L)`:
    /// `θ1·Σ q_k/C_{d_k} + θ2·Σ q_k·MH(d_k, d_{k+1}) + θ3·D_{i,j}`,
    /// where `D_{i,j}` counts segments that would be rejected by Eq. 4
    /// when the sequence is walked against current loads.
    pub fn deficit(&self, chrom: &[SatId]) -> f64 {
        debug_assert_eq!(chrom.len(), self.segments.len());
        let g = self.ga;
        let mut comp = 0.0;
        let mut tran = 0.0;
        // hypothetical extra load per satellite while walking the sequence
        // (a segment may revisit a satellite; loads accumulate). L is tiny
        // (3-4), so an O(L^2) scan over the accepted prefix beats any
        // allocation (§Perf iter 3: allocation-free deficit — this runs
        // ~900x per GA decide).
        let mut drops = 0.0;
        // admitted[k] = segment k was admitted in this walk
        let mut admitted = [false; 16];
        let short = chrom.len() <= 16;
        let mut extra_fallback: Vec<(SatId, f64)> = if short {
            Vec::new()
        } else {
            Vec::with_capacity(chrom.len())
        };
        for (k, (&c, &q)) in chrom.iter().zip(self.segments).enumerate() {
            // θ1 term, queue-aware: the GA observes the disseminated loads
            // (the "self-adaptive" part of Alg. 2) — waiting behind a
            // loaded satellite's backlog is paid like service time.
            comp += (self.view.loaded(c) + q) / self.view.capacity(c);
            if k + 1 < chrom.len() {
                // Eq. 12 tran term in SECONDS: κ·q_k·MH is the realized
                // Eq. 7 transmission delay of shipping segment k's cut
                // activation. Expressing both delay terms in the same unit
                // keeps Table I's weights meaningful as priorities
                // (θ3·drop ≫ θ2·tran ≳ θ1·comp); with raw q·MH a single
                // 4-hop ship would outweigh a dropped task and the GA
                // would trade completions for hops.
                tran += self.kappa * q * self.hop_count(c, chrom[k + 1]) as f64;
            }
            // Eq. 4 admission against loaded + planned-extra workload
            let planned: f64 = if short {
                chrom[..k]
                    .iter()
                    .zip(self.segments)
                    .enumerate()
                    .filter(|(j, (&cj, _))| admitted[*j] && cj == c)
                    .map(|(_, (_, &qj))| qj)
                    .sum()
            } else {
                extra_fallback
                    .iter()
                    .filter(|(id, _)| *id == c)
                    .map(|(_, w)| *w)
                    .sum()
            };
            if q > 0.0 && self.view.loaded(c) + planned + q >= self.view.max_workload(c) {
                drops += 1.0;
            } else if short {
                admitted[k] = true;
            } else {
                extra_fallback.push((c, q));
            }
        }
        // Sticky-state term: decode rounds run where the chain ends, so a
        // live task whose state sits elsewhere pays the Eq. 7 state ship
        // toward that final satellite. Added after the loop (single term,
        // left-to-right) so the indexed kernels can reproduce it exactly.
        if let Some(m) = &self.migration {
            if let Some(&last) = chrom.last() {
                tran += m.secs_per_hop * self.topo.hops(m.from, last) as f64;
            }
        }
        g.theta1 * comp + g.theta2 * tran + g.theta3 * drops
    }

    /// Predicted drop count for a chromosome (θ3 term in isolation).
    pub fn predicted_drops(&self, chrom: &[SatId]) -> usize {
        let mut drops = 0usize;
        let mut extra: Vec<(SatId, f64)> = Vec::with_capacity(chrom.len());
        for (&c, &q) in chrom.iter().zip(self.segments) {
            let planned: f64 = extra
                .iter()
                .filter(|(id, _)| *id == c)
                .map(|(_, w)| *w)
                .sum();
            if q > 0.0 && self.view.loaded(c) + planned + q >= self.view.max_workload(c) {
                drops += 1;
            } else {
                extra.push((c, q));
            }
        }
        drops
    }
}

/// Candidate-local gene: an index into `OffloadContext::candidates`.
///
/// The GA kernel works on genes instead of raw [`SatId`]s so a chromosome
/// is a handful of `u16`s — comparable with a memcmp, packable into a
/// `u128` memo key, and a direct subscript into the [`DecisionSpaceIndex`]
/// arrays. Candidates are sorted and distinct, so gene equality is
/// equivalent to satellite equality.
pub type Gene = u16;

/// Chromosomes up to this length pack losslessly into a `u128` memo key
/// (8 × 16-bit genes); longer ones skip memoization (L is 3–4 in Table I).
pub const MEMO_MAX_L: usize = 8;

/// Per-decision index over the decision space `A_x`: candidate-local
/// copies of everything [`OffloadContext::deficit`] touches, so the Eq. 12
/// evaluation that runs ~`N_iter·(N_summ+N_K)²` times per `decide()`
/// becomes pure array arithmetic — zero [`Constellation`] calls, zero
/// heap allocation, no `Satellite` pointer chasing.
///
/// Built once per decision (`build` reuses its buffers across decisions,
/// and [`DecisionSpaceIndex::build_cached`] skips even that when the
/// decision inputs are unchanged since the last build); the indexed
/// [`DecisionSpaceIndex::deficit`] is bit-for-bit identical to the
/// reference implementation (enforced by
/// `tests/prop_invariants.rs::prop_indexed_deficit_matches_reference`).
#[derive(Clone, Debug, Default)]
pub struct DecisionSpaceIndex {
    /// `sat_ids[g]` — the satellite a gene decodes to.
    sat_ids: Vec<SatId>,
    /// Row-major `|A_x|²` ISL-hop LUT (Manhattan on the torus, BFS
    /// distances on a Walker topology).
    hops: Vec<u16>,
    /// Per-candidate copies of the observed satellite state `deficit`
    /// reads (taken from the decision's [`StateView`], so the index
    /// carries whatever staleness the dissemination model imposes).
    loaded: Vec<f64>,
    capacity: Vec<f64>,
    max_workload: Vec<f64>,
    /// Copy of the per-segment workloads `{q_1..q_L}`.
    segments: Vec<f64>,
    /// k-major `L × |A_x|` computation-term LUT:
    /// `comp_lut[k·|A_x| + g] = (loaded[g] + q_k) / capacity[g]` — the
    /// exact float [`DecisionSpaceIndex::deficit`]'s θ1 term computes, so
    /// the batched kernel replaces its per-evaluation division with a
    /// table load while staying bit-for-bit identical.
    comp_lut: Vec<f64>,
    /// `kq[k] = κ·q_k` — the Eq. 7 prefix of the θ2 term (the scalar
    /// kernel computes `κ·q_k·MH` left-to-right, so `kq[k]·MH` reproduces
    /// it bit-for-bit).
    kq: Vec<f64>,
    /// `mig[g]` — sticky-state surcharge of ending the chain on gene `g`
    /// (`secs_per_hop · MH(from, sat_ids[g])`); empty when the decision
    /// carries no [`MigrationCost`], so the one-shot kernels never touch it.
    mig: Vec<f64>,
    /// The migration the side table was built from (reuse-cache key).
    migration: Option<MigrationCost>,
    kappa: f64,
    theta1: f64,
    theta2: f64,
    theta3: f64,
    /// Origin the current contents were built for (reuse-cache key).
    origin: SatId,
    /// Whether the hop LUT was filled from an [`OutageMap`] (reuse-cache
    /// key — an outage-masked LUT must never satisfy a nominal build, and
    /// vice versa).
    outaged: bool,
    /// [`OutageMap::version`] the LUT was filled from (reuse-cache key —
    /// any link failure or recovery bumps the version and forces a
    /// rebuild). 0 when `outaged` is false.
    outage_version: u64,
    /// True once `build` has populated the index (cache validity gate).
    built: bool,
    /// Reuse-cache counters ([`DecisionSpaceIndex::build_cached`]).
    hits: u64,
    misses: u64,
}

impl DecisionSpaceIndex {
    pub fn new() -> DecisionSpaceIndex {
        DecisionSpaceIndex::default()
    }

    /// (Re)build from a decision context, reusing all internal buffers.
    ///
    /// Panics if `|A_x|` exceeds the `u16` gene space (2d²+2d+1 > 65536
    /// needs d_max ≥ 181 on an N ≥ 256 grid) — a hard assert, once per
    /// decision, so release builds fail loudly instead of silently
    /// truncating genes into wrong decisions.
    pub fn build(&mut self, ctx: &OffloadContext) {
        assert!(
            ctx.candidates.len() <= Gene::MAX as usize + 1,
            "decision space |A_x| = {} exceeds the u16 gene space",
            ctx.candidates.len()
        );
        self.sat_ids.clear();
        self.sat_ids.extend_from_slice(ctx.candidates);
        match ctx.outages {
            Some(o) => o.hops_lut(ctx.candidates, &mut self.hops),
            None => ctx.topo.hops_lut(ctx.candidates, &mut self.hops),
        }
        self.outaged = ctx.outages.is_some();
        self.outage_version = ctx.outages.map(|o| o.version()).unwrap_or(0);
        self.loaded.clear();
        self.capacity.clear();
        self.max_workload.clear();
        for &c in ctx.candidates {
            self.loaded.push(ctx.view.loaded(c));
            self.capacity.push(ctx.view.capacity(c));
            self.max_workload.push(ctx.view.max_workload(c));
        }
        self.segments.clear();
        self.segments.extend_from_slice(ctx.segments);
        // SoA side tables for the batched kernel, derived from the arrays
        // above with the scalar kernel's exact expressions (the per-build
        // cost — L·|A_x| divisions — amortizes over the ~10² to 10³
        // evaluations of one GA decide).
        let nc = self.sat_ids.len();
        self.comp_lut.clear();
        self.comp_lut.reserve(self.segments.len() * nc);
        for &q in &self.segments {
            for g in 0..nc {
                self.comp_lut.push((self.loaded[g] + q) / self.capacity[g]);
            }
        }
        self.kq.clear();
        self.kq.extend(self.segments.iter().map(|&q| ctx.kappa * q));
        self.mig.clear();
        if let Some(m) = &ctx.migration {
            self.mig.extend(
                ctx.candidates
                    .iter()
                    .map(|&c| m.secs_per_hop * ctx.topo.hops(m.from, c) as f64),
            );
        }
        self.migration = ctx.migration;
        self.kappa = ctx.kappa;
        self.theta1 = ctx.ga.theta1;
        self.theta2 = ctx.ga.theta2;
        self.theta3 = ctx.ga.theta3;
        self.origin = ctx.origin;
        self.built = true;
    }

    /// Rebuild only when the decision inputs changed since the last
    /// build: same origin, same candidate set, bit-identical observed
    /// state, segments, κ and θ weights (ROADMAP follow-up to PR 2).
    /// Returns true on a cache hit — the `O(|A_x|²)` hop-LUT fill and the
    /// array copies are skipped, and the retained contents are exactly
    /// what `build` would have produced, so decisions stay bit-for-bit
    /// identical (enforced by
    /// `tests/prop_invariants.rs::prop_index_cache_preserves_decisions`).
    /// Callers keep one index per scheme instance over a single topology,
    /// so candidate-set equality implies hop-LUT equality — with link
    /// faults active the LUT additionally keys on the [`OutageMap`]
    /// version, so any outage change forces a rebuild.
    pub fn build_cached(&mut self, ctx: &OffloadContext) -> bool {
        if self.built && self.matches(ctx) {
            self.hits += 1;
            return true;
        }
        self.build(ctx);
        self.misses += 1;
        false
    }

    /// True when the cached contents equal what `build(ctx)` would write.
    fn matches(&self, ctx: &OffloadContext) -> bool {
        let same_migration = match (&self.migration, &ctx.migration) {
            (None, None) => true,
            (Some(a), Some(b)) => {
                a.from == b.from && a.secs_per_hop.to_bits() == b.secs_per_hop.to_bits()
            }
            _ => false,
        };
        let same_static = same_migration
            && self.outaged == ctx.outages.is_some()
            && self.outage_version == ctx.outages.map(|o| o.version()).unwrap_or(0)
            && self.origin == ctx.origin
            && self.sat_ids.as_slice() == ctx.candidates
            && self.kappa.to_bits() == ctx.kappa.to_bits()
            && self.theta1.to_bits() == ctx.ga.theta1.to_bits()
            && self.theta2.to_bits() == ctx.ga.theta2.to_bits()
            && self.theta3.to_bits() == ctx.ga.theta3.to_bits()
            && self.segments.len() == ctx.segments.len()
            && self
                .segments
                .iter()
                .zip(ctx.segments)
                .all(|(a, b)| a.to_bits() == b.to_bits());
        same_static
            && ctx.candidates.iter().enumerate().all(|(i, &c)| {
                self.loaded[i].to_bits() == ctx.view.loaded(c).to_bits()
                    && self.capacity[i].to_bits() == ctx.view.capacity(c).to_bits()
                    && self.max_workload[i].to_bits() == ctx.view.max_workload(c).to_bits()
            })
    }

    /// Reuse-cache hits counted by [`DecisionSpaceIndex::build_cached`].
    pub fn cache_hits(&self) -> u64 {
        self.hits
    }

    /// Reuse-cache misses (full rebuilds) counted by
    /// [`DecisionSpaceIndex::build_cached`].
    pub fn cache_misses(&self) -> u64 {
        self.misses
    }

    pub fn from_ctx(ctx: &OffloadContext) -> DecisionSpaceIndex {
        let mut idx = DecisionSpaceIndex::new();
        idx.build(ctx);
        idx
    }

    /// `|A_x|` — number of candidates (valid genes are `0..n_cands`).
    pub fn n_cands(&self) -> usize {
        self.sat_ids.len()
    }

    /// Segment count L this index was built for.
    pub fn n_segments(&self) -> usize {
        self.segments.len()
    }

    /// Decode one gene to its satellite.
    #[inline]
    pub fn sat(&self, g: Gene) -> SatId {
        self.sat_ids[g as usize]
    }

    /// Decode a gene chromosome into satellite ids.
    pub fn decode_into(&self, genes: &[Gene], out: &mut Vec<SatId>) {
        out.clear();
        out.extend(genes.iter().map(|&g| self.sat_ids[g as usize]));
    }

    #[inline]
    fn hop(&self, a: Gene, b: Gene) -> u16 {
        self.hops[a as usize * self.sat_ids.len() + b as usize]
    }

    #[inline]
    fn comp_term(&self, g: Gene, q: f64) -> f64 {
        let gi = g as usize;
        (self.loaded[gi] + q) / self.capacity[gi]
    }

    #[inline]
    fn tran_term(&self, genes: &[Gene], k: usize) -> f64 {
        self.kappa * self.segments[k] * self.hop(genes[k], genes[k + 1]) as f64
    }

    /// Sticky-state surcharge of a chromosome (0 unless the decision was
    /// built with a [`MigrationCost`]): the reference adds this single
    /// term to `tran` after its segment loop, and every kernel below adds
    /// it at the same reduction position, keeping them bit-for-bit equal.
    #[inline]
    fn mig_term(&self, genes: &[Gene]) -> f64 {
        match genes.last() {
            Some(&g) if !self.mig.is_empty() => self.mig[g as usize],
            _ => 0.0,
        }
    }

    /// The Eq. 4 admission walk of the reference `deficit` (θ3 drop count):
    /// planned loads accumulate over the admitted prefix in segment order,
    /// so the floating-point sums match the reference bit for bit.
    fn admission_drops(&self, genes: &[Gene]) -> f64 {
        let mut drops = 0.0;
        let mut admitted: u128 = 0;
        for (k, (&g, &q)) in genes.iter().zip(&self.segments).enumerate() {
            let gi = g as usize;
            let mut planned = 0.0;
            for j in 0..k {
                if admitted & (1u128 << j) != 0 && genes[j] == g {
                    planned += self.segments[j];
                }
            }
            if q > 0.0 && self.loaded[gi] + planned + q >= self.max_workload[gi] {
                drops += 1.0;
            } else {
                admitted |= 1u128 << k;
            }
        }
        drops
    }

    /// Eq. 12 deficit of a gene chromosome — allocation-free, identical
    /// floating-point operation order to [`OffloadContext::deficit`].
    pub fn deficit(&self, genes: &[Gene]) -> f64 {
        debug_assert_eq!(genes.len(), self.segments.len());
        if genes.len() > 128 {
            return self.deficit_long(genes);
        }
        let mut comp = 0.0;
        let mut tran = 0.0;
        for (k, (&g, &q)) in genes.iter().zip(&self.segments).enumerate() {
            comp += self.comp_term(g, q);
            if k + 1 < genes.len() {
                tran += self.kappa * q * self.hop(g, genes[k + 1]) as f64;
            }
        }
        if !self.mig.is_empty() {
            tran += self.mig_term(genes);
        }
        let drops = self.admission_drops(genes);
        self.theta1 * comp + self.theta2 * tran + self.theta3 * drops
    }

    /// Fallback for L > 128 (beyond the admission bitmask width; never hit
    /// by real configs where L is 3–4): same semantics, heap-allocated
    /// admitted set.
    fn deficit_long(&self, genes: &[Gene]) -> f64 {
        let mut comp = 0.0;
        let mut tran = 0.0;
        let mut drops = 0.0;
        let mut admitted = vec![false; genes.len()];
        for (k, (&g, &q)) in genes.iter().zip(&self.segments).enumerate() {
            let gi = g as usize;
            comp += self.comp_term(g, q);
            if k + 1 < genes.len() {
                tran += self.kappa * q * self.hop(g, genes[k + 1]) as f64;
            }
            let mut planned = 0.0;
            for j in 0..k {
                if admitted[j] && genes[j] == g {
                    planned += self.segments[j];
                }
            }
            if q > 0.0 && self.loaded[gi] + planned + q >= self.max_workload[gi] {
                drops += 1.0;
            } else {
                admitted[k] = true;
            }
        }
        if !self.mig.is_empty() {
            tran += self.mig_term(genes);
        }
        self.theta1 * comp + self.theta2 * tran + self.theta3 * drops
    }

    /// Deficit with incremental term reuse: per-position computation and
    /// transmission terms are cached in `scratch` and recomputed only for
    /// positions whose gene (or successor gene) changed since the last
    /// evaluation. A single-gene difference costs one division and two
    /// multiplications instead of L of each; the final reductions run in
    /// the reference's left-to-right order, so results stay bit-for-bit
    /// identical to [`DecisionSpaceIndex::deficit`].
    pub fn deficit_with(&self, scratch: &mut DeficitScratch, genes: &[Gene]) -> f64 {
        let l = genes.len();
        debug_assert_eq!(l, self.segments.len());
        if l > 128 {
            return self.deficit_long(genes);
        }
        let n_tran = l.saturating_sub(1);
        if !scratch.valid || scratch.genes.len() != l {
            scratch.genes.clear();
            scratch.genes.extend_from_slice(genes);
            scratch.comp_terms.clear();
            scratch.tran_terms.clear();
            for k in 0..l {
                scratch.comp_terms.push(self.comp_term(genes[k], self.segments[k]));
            }
            for k in 0..n_tran {
                scratch.tran_terms.push(self.tran_term(genes, k));
            }
            scratch.valid = true;
        } else {
            for k in 0..l {
                if genes[k] != scratch.genes[k] {
                    scratch.comp_terms[k] = self.comp_term(genes[k], self.segments[k]);
                }
            }
            for k in 0..n_tran {
                if genes[k] != scratch.genes[k] || genes[k + 1] != scratch.genes[k + 1] {
                    scratch.tran_terms[k] = self.tran_term(genes, k);
                }
            }
            scratch.genes.copy_from_slice(genes);
        }
        let mut comp = 0.0;
        for &t in &scratch.comp_terms {
            comp += t;
        }
        let mut tran = 0.0;
        for &t in &scratch.tran_terms {
            tran += t;
        }
        if !self.mig.is_empty() {
            tran += self.mig_term(genes);
        }
        let drops = self.admission_drops(genes);
        self.theta1 * comp + self.theta2 * tran + self.theta3 * drops
    }

    /// Eq. 12 deficits of a whole GA generation in one pass: `genes`
    /// holds `n` chromosomes of length `L = n_segments()` back to back
    /// (fixed stride `L`); `out` receives one deficit per chromosome, in
    /// order.
    ///
    /// The θ1/θ2 accumulations run k-outer over fixed-stride chromosome
    /// lanes against the structure-of-arrays side tables (`comp_lut`,
    /// `kq`, the hop LUT) — the layout the autovectorizer can chew — and
    /// every per-chromosome reduction happens in the scalar kernel's
    /// left-to-right order, so each result is **bit-for-bit identical**
    /// to [`DecisionSpaceIndex::deficit`] on the same chromosome
    /// (enforced by
    /// `tests/prop_invariants.rs::prop_deficit_batch_matches_scalar`).
    pub fn deficit_batch(&self, scratch: &mut BatchScratch, genes: &[Gene], out: &mut Vec<f64>) {
        let l = self.segments.len();
        out.clear();
        if l == 0 || genes.is_empty() {
            return;
        }
        debug_assert_eq!(genes.len() % l, 0, "ragged chromosome matrix");
        if l > 128 {
            out.extend(genes.chunks(l).map(|c| self.deficit_long(c)));
            return;
        }
        out.resize(genes.len() / l, 0.0);
        self.deficit_batch_slice(scratch, genes, out);
    }

    /// Slice-writing core of [`DecisionSpaceIndex::deficit_batch`] and
    /// the pooled-eval chunk entry ([`pool::EvalPool`]): evaluates the
    /// chromosomes of `genes` into the pre-sized `out` slots
    /// (`out.len() · L == genes.len()`). Per-chromosome results are fully
    /// independent — neither the scalar body nor the SIMD lanes carry any
    /// state across chromosomes, and the lanes' scalar tails are
    /// bitwise-equal to lane results — so evaluating any contiguous
    /// sub-range writes exactly the values a whole-batch pass would.
    /// That independence is what makes chunked parallel evaluation
    /// bit-safe by construction at any thread count. Requires
    /// `1 <= L <= 128` and a non-ragged matrix.
    pub(crate) fn deficit_batch_slice(
        &self,
        scratch: &mut BatchScratch,
        genes: &[Gene],
        out: &mut [f64],
    ) {
        debug_assert!((1..=128).contains(&self.segments.len()));
        debug_assert_eq!(
            genes.len(),
            out.len() * self.segments.len(),
            "out slots != chromosomes"
        );
        // Explicit SIMD lanes (4-wide AVX2 / 2-wide NEON, `simd` feature,
        // runtime CPU detection): bit-identical to the scalar body below
        // — same per-lane add order, masked adds of +0.0 for skipped
        // admission terms, no FMA contraction — so the dispatch can never
        // change a decision (`tests/prop_sharded.rs::
        // prop_deficit_batch_simd_matches_scalar`). The lanes predate the
        // sticky-state side table, so dispatch only when it is empty
        // (one-shot decisions — the entire pre-LLM hot path).
        #[cfg(feature = "simd")]
        if self.mig.is_empty() && simd::deficit_batch(self, genes, out) {
            return;
        }
        self.deficit_batch_scalar(scratch, genes, out);
    }

    /// The scalar (autovectorizer-friendly) body of
    /// [`DecisionSpaceIndex::deficit_batch`] — the bitwise oracle the
    /// explicit `simd` lanes are property-tested against.
    fn deficit_batch_scalar(
        &self,
        scratch: &mut BatchScratch,
        genes: &[Gene],
        out: &mut [f64],
    ) {
        let l = self.segments.len();
        let n = genes.len() / l;
        let nc = self.sat_ids.len();
        scratch.comp.clear();
        scratch.comp.resize(n, 0.0);
        scratch.tran.clear();
        scratch.tran.resize(n, 0.0);
        for k in 0..l {
            let lut = &self.comp_lut[k * nc..(k + 1) * nc];
            for (i, acc) in scratch.comp.iter_mut().enumerate() {
                *acc += lut[genes[i * l + k] as usize];
            }
        }
        for k in 0..l.saturating_sub(1) {
            let kq = self.kq[k];
            for (i, acc) in scratch.tran.iter_mut().enumerate() {
                let a = genes[i * l + k] as usize;
                let b = genes[i * l + k + 1] as usize;
                *acc += kq * self.hops[a * nc + b] as f64;
            }
        }
        if !self.mig.is_empty() {
            for (i, acc) in scratch.tran.iter_mut().enumerate() {
                *acc += self.mig[genes[i * l + l - 1] as usize];
            }
        }
        for (i, slot) in out.iter_mut().enumerate() {
            let drops = self.admission_drops(&genes[i * l..(i + 1) * l]);
            *slot = self.theta1 * scratch.comp[i]
                + self.theta2 * scratch.tran[i]
                + self.theta3 * drops;
        }
    }
}

/// True when [`DecisionSpaceIndex::deficit_batch`] dispatches to the
/// explicit-SIMD kernel: the build has the `simd` feature AND the CPU
/// provides the lanes (AVX2 on x86_64; NEON is baseline on aarch64).
/// Benches and the CI perf gate read this to label/judge the simd row.
pub fn simd_active() -> bool {
    #[cfg(feature = "simd")]
    {
        simd::active()
    }
    #[cfg(not(feature = "simd"))]
    {
        false
    }
}

/// Reusable θ1/θ2 accumulator lanes for
/// [`DecisionSpaceIndex::deficit_batch`] (one slot per chromosome of the
/// generation being evaluated), kept by the caller so steady-state batch
/// evaluation allocates nothing.
#[derive(Clone, Debug, Default)]
pub struct BatchScratch {
    comp: Vec<f64>,
    tran: Vec<f64>,
}

/// Reusable per-scheme scratch for [`DecisionSpaceIndex::deficit_with`]:
/// the last evaluated chromosome and its per-position deficit terms.
#[derive(Clone, Debug, Default)]
pub struct DeficitScratch {
    genes: Vec<Gene>,
    comp_terms: Vec<f64>,
    tran_terms: Vec<f64>,
    valid: bool,
}

impl DeficitScratch {
    /// Drop the cached terms (call when the index is rebuilt — satellite
    /// loads or segments changed, so every cached term is stale).
    pub fn invalidate(&mut self) {
        self.valid = false;
    }
}

/// A task-offloading decision scheme.
pub trait OffloadScheme {
    /// Write the chromosome `(c_1..c_L)` — all members of
    /// `ctx.candidates` — into `out` (cleared first). The buffer-reuse
    /// entry point: engines call this with a recycled buffer so the
    /// per-task hot path allocates nothing.
    fn decide_into(&mut self, ctx: &OffloadContext, out: &mut Vec<SatId>);

    /// Chromosome `(c_1..c_L)`, all members of `ctx.candidates`.
    fn decide(&mut self, ctx: &OffloadContext) -> Vec<SatId> {
        let mut out = Vec::with_capacity(ctx.segments.len());
        self.decide_into(ctx, &mut out);
        out
    }

    fn kind(&self) -> SchemeKind;

    /// Learning hook: called after the decided sequence executed.
    /// `dropped_at` = Some(k) if segment k was rejected; `delay_s` is the
    /// realized task delay. Default: no-op (only DQN learns online).
    ///
    /// Engines only call this when [`OffloadScheme::learns`] is true — a
    /// scheme that overrides `observe` MUST also override `learns` to
    /// return true, or its observations are silently skipped.
    fn observe(&mut self, _ctx: &OffloadContext, _chrom: &[SatId], _dropped_at: Option<usize>, _delay_s: f64) {}

    /// True when [`OffloadScheme::observe`] does real work. Engines skip
    /// building the observation context (and the Eq. 5/7 delay estimate
    /// that feeds it) for schemes that keep the default no-op — a pure
    /// hot-path gate that cannot change any decision.
    fn learns(&self) -> bool {
        false
    }

    /// Kernel-level counters for the report's `telemetry` block, read once
    /// at end of run (never on the hot path). Default `None`: schemes
    /// without internal caches contribute nothing. [`ga::GaScheme`]
    /// overrides this with its chromosome-memo / index-cache hit rates and
    /// `deficit_batch` sizes.
    fn telemetry(&self) -> Option<Json> {
        None
    }
}

/// Construct a scheme instance with the default decision-layer knobs
/// (sequential evaluation, no decision cache).
pub fn make_scheme(kind: SchemeKind, seed: u64) -> Box<dyn OffloadScheme> {
    make_scheme_with(kind, seed, 1, false)
}

/// Construct a scheme instance with the decision-layer perf knobs
/// threaded through (engines pass [`crate::config::SimConfig`]'s
/// `decide_threads` / `decision_cache`). Only the GA scheme has pooled
/// generation evaluation and an epoch-keyed decision cache — the other
/// schemes' decides are O(|A_x|·L) table walks with nothing to pool or
/// memoize, so they ignore both knobs (pinned by `tests/prop_pool.rs`:
/// every scheme is byte-identical across thread counts).
pub fn make_scheme_with(
    kind: SchemeKind,
    seed: u64,
    decide_threads: usize,
    decision_cache: bool,
) -> Box<dyn OffloadScheme> {
    match kind {
        SchemeKind::Scc => Box::new(ga::GaScheme::with_opts(seed, decide_threads, decision_cache)),
        SchemeKind::Random => Box::new(random::RandomScheme::new(seed)),
        SchemeKind::Rrp => Box::new(rrp::RrpScheme::new()),
        SchemeKind::Dqn => Box::new(dqn::DqnScheme::new(seed)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GaConfig;
    use crate::satellite::Satellite;
    use crate::topology::Constellation;

    pub(crate) fn test_ctx<'a>(
        topo: &'a Constellation,
        sats: &'a [Satellite],
        candidates: &'a [SatId],
        segments: &'a [f64],
        ga: &'a GaConfig,
    ) -> OffloadContext<'a> {
        OffloadContext {
            topo,
            view: StateView::live(sats),
            origin: 0,
            candidates,
            segments,
            kappa: 1e-4,
            ga,
            migration: None,
            outages: None,
        }
    }

    fn setup(n: usize) -> (Constellation, Vec<Satellite>, GaConfig) {
        let topo = Constellation::torus(n);
        let sats = (0..topo.len())
            .map(|i| Satellite::new(i, 3000.0, 15000.0))
            .collect();
        (topo, sats, GaConfig::default())
    }

    #[test]
    fn deficit_computation_term() {
        let (topo, sats, mut ga) = setup(4);
        ga.theta2 = 0.0;
        ga.theta3 = 0.0;
        let cands = topo.decision_space(0, 2);
        let segs = [3000.0, 6000.0];
        let ctx = test_ctx(&topo, &sats, &cands, &segs, &ga);
        // both on sat 0: comp = 3000/3000 + 6000/3000 = 3
        assert!((ctx.deficit(&[0, 0]) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn deficit_transmission_term_eq12() {
        let (topo, sats, mut ga) = setup(4);
        ga.theta1 = 0.0;
        ga.theta3 = 0.0;
        ga.theta2 = 2.0;
        let cands = topo.decision_space(0, 2);
        let segs = [100.0, 50.0, 10.0];
        let ctx = test_ctx(&topo, &sats, &cands, &segs, &ga);
        let a = 0;
        let b = topo.neighbors(0)[0];
        // hops: MH(a,b)=1 after seg1, MH(b,b)=0 after seg2; last segment
        // ships nothing. tran = kappa*q*MH summed, weighted by theta2.
        let d = ctx.deficit(&[a, b, b]);
        let want = 2.0 * ctx.kappa * (100.0 * 1.0 + 50.0 * 0.0);
        assert!((d - want).abs() < 1e-12, "d={d} want={want}");
    }

    #[test]
    fn deficit_counts_drops_with_accumulation() {
        let (topo, mut sats, mut ga) = setup(4);
        ga.theta1 = 0.0;
        ga.theta2 = 0.0;
        ga.theta3 = 1.0;
        // satellite 0 can only admit < 15000 total
        sats[0].try_load(9000.0);
        let cands = topo.decision_space(0, 2);
        let segs = [4000.0, 4000.0];
        let ctx = test_ctx(&topo, &sats, &cands, &segs, &ga);
        // first 4000 fits (13000 < 15000), second does not (17000 >= 15000)
        assert!((ctx.deficit(&[0, 0]) - 1.0).abs() < 1e-12);
        assert_eq!(ctx.predicted_drops(&[0, 0]), 1);
        // spreading avoids the drop
        let nb = topo.neighbors(0)[0];
        assert_eq!(ctx.predicted_drops(&[0, nb]), 0);
    }

    #[test]
    fn empty_segments_never_counted_as_drops() {
        let (topo, mut sats, ga) = setup(4);
        sats[0].try_load(14999.0);
        let cands = topo.decision_space(0, 2);
        let segs = [0.0, 0.0];
        let ctx = test_ctx(&topo, &sats, &cands, &segs, &ga);
        assert_eq!(ctx.predicted_drops(&[0, 0]), 0);
    }

    #[test]
    fn indexed_deficit_matches_reference_bitwise() {
        let (topo, mut sats, ga) = setup(6);
        let mut rng = crate::util::rng::Pcg64::seed_from_u64(11);
        for s in sats.iter_mut() {
            s.try_load(rng.f64_in(0.0, 14_000.0));
        }
        let cands = topo.decision_space(7, 2);
        let segs = [4000.0, 0.0, 3500.0, 2800.0];
        let ctx = test_ctx(&topo, &sats, &cands, &segs, &ga);
        let index = DecisionSpaceIndex::from_ctx(&ctx);
        assert_eq!(index.n_cands(), cands.len());
        assert_eq!(index.n_segments(), segs.len());
        let mut scratch = DeficitScratch::default();
        for _ in 0..200 {
            let genes: Vec<Gene> = (0..segs.len())
                .map(|_| rng.usize_in(0, cands.len()) as Gene)
                .collect();
            let mut chrom = Vec::new();
            index.decode_into(&genes, &mut chrom);
            assert!(chrom.iter().all(|c| cands.contains(c)));
            let want = ctx.deficit(&chrom);
            let got = index.deficit(&genes);
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "indexed {got} != reference {want} for {chrom:?}"
            );
            let inc = index.deficit_with(&mut scratch, &genes);
            assert_eq!(inc.to_bits(), want.to_bits(), "incremental path diverged");
        }
    }

    #[test]
    fn incremental_deficit_tracks_single_gene_mutations() {
        let (topo, mut sats, ga) = setup(5);
        sats[0].try_load(12_000.0);
        sats[6].try_load(9_000.0);
        let cands = topo.decision_space(6, 2);
        let segs = [3000.0, 4000.0, 2000.0];
        let ctx = test_ctx(&topo, &sats, &cands, &segs, &ga);
        let index = DecisionSpaceIndex::from_ctx(&ctx);
        let mut scratch = DeficitScratch::default();
        let mut genes: Vec<Gene> = vec![0, 1, 2];
        let _ = index.deficit_with(&mut scratch, &genes);
        let mut rng = crate::util::rng::Pcg64::seed_from_u64(3);
        for _ in 0..100 {
            let pos = rng.usize_in(0, genes.len());
            genes[pos] = rng.usize_in(0, cands.len()) as Gene;
            let inc = index.deficit_with(&mut scratch, &genes);
            let full = index.deficit(&genes);
            assert_eq!(inc.to_bits(), full.to_bits());
        }
        // invalidation after a rebuild keeps results correct
        scratch.invalidate();
        let after = index.deficit_with(&mut scratch, &genes);
        assert_eq!(after.to_bits(), index.deficit(&genes).to_bits());
    }

    #[test]
    fn batched_deficit_matches_scalar_bitwise() {
        let (topo, mut sats, ga) = setup(6);
        let mut rng = crate::util::rng::Pcg64::seed_from_u64(21);
        for s in sats.iter_mut() {
            s.try_load(rng.f64_in(0.0, 14_000.0));
        }
        let cands = topo.decision_space(9, 2);
        let segs = [4100.0, 0.0, 2600.0, 3300.0];
        let ctx = test_ctx(&topo, &sats, &cands, &segs, &ga);
        let index = DecisionSpaceIndex::from_ctx(&ctx);
        let n = 37usize;
        let flat: Vec<Gene> = (0..n * segs.len())
            .map(|_| rng.usize_in(0, cands.len()) as Gene)
            .collect();
        let mut scratch = BatchScratch::default();
        let mut out = Vec::new();
        index.deficit_batch(&mut scratch, &flat, &mut out);
        assert_eq!(out.len(), n);
        for (chrom, &got) in flat.chunks(segs.len()).zip(&out) {
            let want = index.deficit(chrom);
            assert_eq!(got.to_bits(), want.to_bits(), "batch diverged on {chrom:?}");
        }
        // scratch reuse across differently-sized generations stays exact
        index.deficit_batch(&mut scratch, &flat[..segs.len() * 3], &mut out);
        assert_eq!(out.len(), 3);
        for (chrom, &got) in flat[..segs.len() * 3].chunks(segs.len()).zip(&out) {
            assert_eq!(got.to_bits(), index.deficit(chrom).to_bits());
        }
        // empty generation is a clean no-op
        index.deficit_batch(&mut scratch, &[], &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn migration_cost_charges_final_hop_distance() {
        let (topo, sats, mut ga) = setup(4);
        ga.theta1 = 0.0;
        ga.theta3 = 0.0;
        ga.theta2 = 1.0;
        let cands = topo.decision_space(0, 2);
        let segs = [100.0, 50.0];
        let mut ctx = test_ctx(&topo, &sats, &cands, &segs, &ga);
        let base = ctx.deficit(&[0, 0]);
        ctx.migration = Some(MigrationCost {
            from: 0,
            secs_per_hop: 0.25,
        });
        // chain ends on the state-holding satellite: no surcharge
        assert_eq!(ctx.deficit(&[0, 0]).to_bits(), base.to_bits());
        // chain ends one hop away: + secs_per_hop · 1 on the θ2 term
        let nb = topo.neighbors(0)[0];
        let d = ctx.deficit(&[0, nb]);
        let plain = {
            let mut c2 = test_ctx(&topo, &sats, &cands, &segs, &ga);
            c2.migration = None;
            c2.deficit(&[0, nb])
        };
        assert!((d - (plain + 0.25)).abs() < 1e-12, "d={d} plain={plain}");
    }

    #[test]
    fn indexed_migration_matches_reference_bitwise() {
        let (topo, mut sats, ga) = setup(6);
        let mut rng = crate::util::rng::Pcg64::seed_from_u64(31);
        for s in sats.iter_mut() {
            s.try_load(rng.f64_in(0.0, 14_000.0));
        }
        let cands = topo.decision_space(7, 2);
        let segs = [4000.0, 0.0, 3500.0];
        let mut ctx = test_ctx(&topo, &sats, &cands, &segs, &ga);
        ctx.origin = 7;
        ctx.migration = Some(MigrationCost {
            from: 7,
            secs_per_hop: 0.031_25,
        });
        let index = DecisionSpaceIndex::from_ctx(&ctx);
        let mut scratch = DeficitScratch::default();
        let mut batch = BatchScratch::default();
        let mut flat: Vec<Gene> = Vec::new();
        let mut chrom = Vec::new();
        for _ in 0..100 {
            let genes: Vec<Gene> = (0..segs.len())
                .map(|_| rng.usize_in(0, cands.len()) as Gene)
                .collect();
            index.decode_into(&genes, &mut chrom);
            let want = ctx.deficit(&chrom);
            assert_eq!(index.deficit(&genes).to_bits(), want.to_bits());
            assert_eq!(index.deficit_with(&mut scratch, &genes).to_bits(), want.to_bits());
            flat.extend_from_slice(&genes);
        }
        let mut out = Vec::new();
        index.deficit_batch(&mut batch, &flat, &mut out);
        for (genes, &got) in flat.chunks(segs.len()).zip(&out) {
            assert_eq!(got.to_bits(), index.deficit(genes).to_bits());
        }
        // the reuse cache keys on the migration: same → hit, changed → rebuild
        let mut cached = DecisionSpaceIndex::new();
        assert!(!cached.build_cached(&ctx));
        assert!(cached.build_cached(&ctx));
        ctx.migration = Some(MigrationCost {
            from: 7,
            secs_per_hop: 0.0625,
        });
        assert!(!cached.build_cached(&ctx));
        ctx.migration = None;
        assert!(!cached.build_cached(&ctx));
        assert_eq!(
            cached.deficit(&[0, 0, 0]).to_bits(),
            ctx.deficit(&[cands[0], cands[0], cands[0]]).to_bits()
        );
    }

    #[test]
    fn outage_masked_hops_price_detours_and_key_the_cache() {
        let (topo, sats, mut ga) = setup(4);
        ga.theta1 = 0.0;
        ga.theta3 = 0.0;
        ga.theta2 = 1.0;
        let cands = topo.decision_space(0, 2);
        let segs = [100.0, 50.0];
        let nb = topo.neighbors(0)[0];
        let mut ctx = test_ctx(&topo, &sats, &cands, &segs, &ga);
        let base = ctx.deficit(&[0, nb]);

        // sever the direct 0<->nb link: the tran term must price the detour
        let mut outages = OutageMap::new();
        let (lo, hi) = (0.min(nb), 0.max(nb));
        outages.rebuild_with(&topo, |a, b| (a.min(b), a.max(b)) == (lo, hi));
        ctx.outages = Some(&outages);
        let masked = ctx.deficit(&[0, nb]);
        let detour = outages.hops_or_penalty(0, nb);
        assert!(detour > 1, "severing the direct link must lengthen the path");
        assert!(masked > base, "masked={masked} base={base}");

        // indexed kernel agrees bit-for-bit with the masked reference
        let index = DecisionSpaceIndex::from_ctx(&ctx);
        let g_nb = cands.iter().position(|&c| c == nb).unwrap() as Gene;
        assert_eq!(index.deficit(&[0, g_nb]).to_bits(), masked.to_bits());

        // the reuse cache keys on presence and version of the outage map
        let mut cached = DecisionSpaceIndex::new();
        assert!(!cached.build_cached(&ctx));
        assert!(cached.build_cached(&ctx));
        outages.rebuild_with(&topo, |_, _| false); // version bump
        ctx.outages = Some(&outages);
        assert!(!cached.build_cached(&ctx));
        ctx.outages = None;
        assert!(!cached.build_cached(&ctx));
        assert_eq!(cached.deficit(&[0, g_nb]).to_bits(), base.to_bits());
    }

    #[test]
    fn only_dqn_learns() {
        for kind in SchemeKind::all() {
            let s = make_scheme(kind, 3);
            assert_eq!(s.learns(), kind == SchemeKind::Dqn, "{kind:?}");
        }
    }

    #[test]
    fn scheme_kind_parse_and_names() {
        assert_eq!(SchemeKind::parse("SCC").unwrap(), SchemeKind::Scc);
        assert_eq!(SchemeKind::parse("rrp").unwrap(), SchemeKind::Rrp);
        assert!(SchemeKind::parse("foo").is_err());
        assert_eq!(SchemeKind::all().len(), 4);
    }
}
