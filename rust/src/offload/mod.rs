//! Task offloading schemes (§IV-B, §V-A): the paper's GA-based SCC scheme
//! plus the three baselines it is evaluated against (Random, RRP, DQN).
//!
//! A scheme maps one split task — segment workloads `{q_1..q_L}` plus the
//! current network state — to a processing sequence `(c_1, …, c_L)`
//! (the "chromosome"): segment k executes on satellite c_k, intermediate
//! activations hop `MH(c_k, c_{k+1})` ISLs (Eq. 7).

pub mod dqn;
pub mod ga;
pub mod random;
pub mod rrp;

use crate::config::GaConfig;
use crate::satellite::Satellite;
use crate::topology::{SatId, Torus};

/// Which scheme to run (CLI / experiment selector).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SchemeKind {
    /// The paper's proposal (Alg. 1 + Alg. 2 GA offloading).
    Scc,
    Random,
    Rrp,
    Dqn,
}

impl SchemeKind {
    pub fn parse(s: &str) -> Result<SchemeKind, String> {
        match s.to_ascii_lowercase().as_str() {
            "scc" | "ga" => Ok(SchemeKind::Scc),
            "random" => Ok(SchemeKind::Random),
            "rrp" => Ok(SchemeKind::Rrp),
            "dqn" => Ok(SchemeKind::Dqn),
            other => Err(format!("unknown scheme '{other}' (scc|random|rrp|dqn)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchemeKind::Scc => "SCC",
            SchemeKind::Random => "Random",
            SchemeKind::Rrp => "RRP",
            SchemeKind::Dqn => "DQN",
        }
    }

    pub fn all() -> [SchemeKind; 4] {
        [
            SchemeKind::Scc,
            SchemeKind::Random,
            SchemeKind::Rrp,
            SchemeKind::Dqn,
        ]
    }
}

/// Everything a scheme may observe when deciding (local view of the
/// decision-making satellite: its decision space and those satellites'
/// resource state — §I's "local observations").
pub struct OffloadContext<'a> {
    pub torus: &'a Torus,
    pub satellites: &'a [Satellite],
    /// Decision-making satellite x (task origin).
    pub origin: SatId,
    /// A_x — candidate satellites within D_M of x (constraint 11c).
    pub candidates: &'a [SatId],
    /// Per-segment workloads {q_1..q_L} [MFLOP] from Alg. 1.
    pub segments: &'a [f64],
    /// κ — ISL transfer coefficient [s per MFLOP·hop] (Eq. 7 scaling).
    pub kappa: f64,
    pub ga: &'a GaConfig,
}

impl<'a> OffloadContext<'a> {
    /// Eq. 12 deficit of a chromosome `(d_1..d_L)`:
    /// `θ1·Σ q_k/C_{d_k} + θ2·Σ q_k·MH(d_k, d_{k+1}) + θ3·D_{i,j}`,
    /// where `D_{i,j}` counts segments that would be rejected by Eq. 4
    /// when the sequence is walked against current loads.
    pub fn deficit(&self, chrom: &[SatId]) -> f64 {
        debug_assert_eq!(chrom.len(), self.segments.len());
        let g = self.ga;
        let mut comp = 0.0;
        let mut tran = 0.0;
        // hypothetical extra load per satellite while walking the sequence
        // (a segment may revisit a satellite; loads accumulate). L is tiny
        // (3-4), so an O(L^2) scan over the accepted prefix beats any
        // allocation (§Perf iter 3: allocation-free deficit — this runs
        // ~900x per GA decide).
        let mut drops = 0.0;
        // admitted[k] = segment k was admitted in this walk
        let mut admitted = [false; 16];
        let short = chrom.len() <= 16;
        let mut extra_fallback: Vec<(SatId, f64)> = if short {
            Vec::new()
        } else {
            Vec::with_capacity(chrom.len())
        };
        for (k, (&c, &q)) in chrom.iter().zip(self.segments).enumerate() {
            let sat = &self.satellites[c];
            // θ1 term, queue-aware: the GA observes current loads (the
            // "self-adaptive" part of Alg. 2) — waiting behind a loaded
            // satellite's backlog is paid like service time.
            comp += (sat.loaded() + q) / sat.capacity_mflops;
            if k + 1 < chrom.len() {
                // Eq. 12 tran term in SECONDS: κ·q_k·MH is the realized
                // Eq. 7 transmission delay of shipping segment k's cut
                // activation. Expressing both delay terms in the same unit
                // keeps Table I's weights meaningful as priorities
                // (θ3·drop ≫ θ2·tran ≳ θ1·comp); with raw q·MH a single
                // 4-hop ship would outweigh a dropped task and the GA
                // would trade completions for hops.
                tran += self.kappa * q * self.torus.manhattan(c, chrom[k + 1]) as f64;
            }
            // Eq. 4 admission against loaded + planned-extra workload
            let planned: f64 = if short {
                chrom[..k]
                    .iter()
                    .zip(self.segments)
                    .enumerate()
                    .filter(|(j, (&cj, _))| admitted[*j] && cj == c)
                    .map(|(_, (_, &qj))| qj)
                    .sum()
            } else {
                extra_fallback
                    .iter()
                    .filter(|(id, _)| *id == c)
                    .map(|(_, w)| *w)
                    .sum()
            };
            if q > 0.0 && sat.loaded() + planned + q >= sat.max_workload_mflops {
                drops += 1.0;
            } else if short {
                admitted[k] = true;
            } else {
                extra_fallback.push((c, q));
            }
        }
        g.theta1 * comp + g.theta2 * tran + g.theta3 * drops
    }

    /// Predicted drop count for a chromosome (θ3 term in isolation).
    pub fn predicted_drops(&self, chrom: &[SatId]) -> usize {
        let mut drops = 0usize;
        let mut extra: Vec<(SatId, f64)> = Vec::with_capacity(chrom.len());
        for (&c, &q) in chrom.iter().zip(self.segments) {
            let sat = &self.satellites[c];
            let planned: f64 = extra
                .iter()
                .filter(|(id, _)| *id == c)
                .map(|(_, w)| *w)
                .sum();
            if q > 0.0 && sat.loaded() + planned + q >= sat.max_workload_mflops {
                drops += 1;
            } else {
                extra.push((c, q));
            }
        }
        drops
    }
}

/// A task-offloading decision scheme.
pub trait OffloadScheme {
    /// Chromosome `(c_1..c_L)`, all members of `ctx.candidates`.
    fn decide(&mut self, ctx: &OffloadContext) -> Vec<SatId>;

    fn kind(&self) -> SchemeKind;

    /// Learning hook: called after the decided sequence executed.
    /// `dropped_at` = Some(k) if segment k was rejected; `delay_s` is the
    /// realized task delay. Default: no-op (only DQN learns online).
    fn observe(&mut self, _ctx: &OffloadContext, _chrom: &[SatId], _dropped_at: Option<usize>, _delay_s: f64) {}
}

/// Construct a scheme instance.
pub fn make_scheme(kind: SchemeKind, seed: u64) -> Box<dyn OffloadScheme> {
    match kind {
        SchemeKind::Scc => Box::new(ga::GaScheme::new(seed)),
        SchemeKind::Random => Box::new(random::RandomScheme::new(seed)),
        SchemeKind::Rrp => Box::new(rrp::RrpScheme::new()),
        SchemeKind::Dqn => Box::new(dqn::DqnScheme::new(seed)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GaConfig;
    use crate::satellite::Satellite;
    use crate::topology::Torus;

    pub(crate) fn test_ctx<'a>(
        torus: &'a Torus,
        sats: &'a [Satellite],
        candidates: &'a [SatId],
        segments: &'a [f64],
        ga: &'a GaConfig,
    ) -> OffloadContext<'a> {
        OffloadContext {
            torus,
            satellites: sats,
            origin: 0,
            candidates,
            segments,
            kappa: 1e-4,
            ga,
        }
    }

    fn setup(n: usize) -> (Torus, Vec<Satellite>, GaConfig) {
        let torus = Torus::new(n);
        let sats = (0..torus.len())
            .map(|i| Satellite::new(i, 3000.0, 15000.0))
            .collect();
        (torus, sats, GaConfig::default())
    }

    #[test]
    fn deficit_computation_term() {
        let (torus, sats, mut ga) = setup(4);
        ga.theta2 = 0.0;
        ga.theta3 = 0.0;
        let cands = torus.decision_space(0, 2);
        let segs = [3000.0, 6000.0];
        let ctx = test_ctx(&torus, &sats, &cands, &segs, &ga);
        // both on sat 0: comp = 3000/3000 + 6000/3000 = 3
        assert!((ctx.deficit(&[0, 0]) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn deficit_transmission_term_eq12() {
        let (torus, sats, mut ga) = setup(4);
        ga.theta1 = 0.0;
        ga.theta3 = 0.0;
        ga.theta2 = 2.0;
        let cands = torus.decision_space(0, 2);
        let segs = [100.0, 50.0, 10.0];
        let ctx = test_ctx(&torus, &sats, &cands, &segs, &ga);
        let a = 0;
        let b = torus.neighbors(0)[0];
        // hops: MH(a,b)=1 after seg1, MH(b,b)=0 after seg2; last segment
        // ships nothing. tran = kappa*q*MH summed, weighted by theta2.
        let d = ctx.deficit(&[a, b, b]);
        let want = 2.0 * ctx.kappa * (100.0 * 1.0 + 50.0 * 0.0);
        assert!((d - want).abs() < 1e-12, "d={d} want={want}");
    }

    #[test]
    fn deficit_counts_drops_with_accumulation() {
        let (torus, mut sats, mut ga) = setup(4);
        ga.theta1 = 0.0;
        ga.theta2 = 0.0;
        ga.theta3 = 1.0;
        // satellite 0 can only admit < 15000 total
        sats[0].try_load(9000.0);
        let cands = torus.decision_space(0, 2);
        let segs = [4000.0, 4000.0];
        let ctx = test_ctx(&torus, &sats, &cands, &segs, &ga);
        // first 4000 fits (13000 < 15000), second does not (17000 >= 15000)
        assert!((ctx.deficit(&[0, 0]) - 1.0).abs() < 1e-12);
        assert_eq!(ctx.predicted_drops(&[0, 0]), 1);
        // spreading avoids the drop
        let nb = torus.neighbors(0)[0];
        assert_eq!(ctx.predicted_drops(&[0, nb]), 0);
    }

    #[test]
    fn empty_segments_never_counted_as_drops() {
        let (torus, mut sats, ga) = setup(4);
        sats[0].try_load(14999.0);
        let cands = torus.decision_space(0, 2);
        let segs = [0.0, 0.0];
        let ctx = test_ctx(&torus, &sats, &cands, &segs, &ga);
        assert_eq!(ctx.predicted_drops(&[0, 0]), 0);
    }

    #[test]
    fn scheme_kind_parse_and_names() {
        assert_eq!(SchemeKind::parse("SCC").unwrap(), SchemeKind::Scc);
        assert_eq!(SchemeKind::parse("rrp").unwrap(), SchemeKind::Rrp);
        assert!(SchemeKind::parse("foo").is_err());
        assert_eq!(SchemeKind::all().len(), 4);
    }
}
