//! Deterministic RNG substrate (no `rand` crate on this offline image).
//!
//! PCG64 (O'Neill's PCG-XSL-RR 128/64) with explicit seeding plus the
//! samplers the simulator needs: uniform ints/floats, Bernoulli,
//! Box–Muller normal, exponential, and Poisson (Knuth for small λ, the
//! PTRS transformed-rejection sampler for large λ — §V: task incidence is
//! Poisson(λ) with λ up to 70).

/// Permuted congruential generator, 128-bit state / 64-bit output.
///
/// Deterministic across platforms; every simulation object derives its own
/// stream via [`Pcg64::split`] so experiment runs are reproducible
/// regardless of scheme-internal draw counts.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.next_u64();
        rng
    }

    /// Convenience: stream 0.
    pub fn seed_from_u64(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child stream (used per-satellite / per-scheme).
    pub fn split(&mut self, stream: u64) -> Self {
        Self::new(self.next_u64(), stream.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift with rejection.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut m = (self.next_u64() as u128).wrapping_mul(n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                m = (self.next_u64() as u128).wrapping_mul(n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a non-empty slice.
    #[inline]
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len())]
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_in(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Standard normal via Box–Muller (one value per call; no caching to
    /// keep the stream position deterministic per draw).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // (0, 1]
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal(mu, sigma).
    #[inline]
    pub fn normal_with(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Exponential with rate `lambda`.
    #[inline]
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Poisson(λ): Knuth's product method for λ < 30, PTRS
    /// (Hörmann's transformed rejection) above — O(1) for the paper's
    /// λ ∈ [4, 70] sweep.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0, "poisson: negative lambda");
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        }
        // PTRS, Hörmann (1993).
        let slam = lambda.sqrt();
        let loglam = lambda.ln();
        let b = 0.931 + 2.53 * slam;
        let a = -0.059 + 0.02483 * b;
        let inv_alpha = 1.1239 + 1.1328 / (b - 3.4);
        let v_r = 0.9277 - 3.6224 / (b - 2.0);
        loop {
            let u = self.f64() - 0.5;
            let v = self.f64();
            let us = 0.5 - u.abs();
            let k = ((2.0 * a / us + b) * u + lambda + 0.43).floor();
            if us >= 0.07 && v <= v_r {
                return k as u64;
            }
            if k < 0.0 || (us < 0.013 && v > us) {
                continue;
            }
            let lhs = (v * inv_alpha / (a / (us * us) + b)).ln();
            let rhs = -lambda + k * loglam - ln_gamma(k + 1.0);
            if lhs <= rhs {
                return k as u64;
            }
        }
    }
}

/// Lanczos ln Γ(x) — needed by the PTRS Poisson sampler.
pub fn ln_gamma(x: f64) -> f64 {
    const G: [f64; 8] = [
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_1,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection
        return (std::f64::consts::PI / (std::f64::consts::PI * x).sin()).ln()
            - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = 0.999_999_999_999_809_9;
    let t = x + 7.5;
    for (i, &g) in G.iter().enumerate() {
        a += g / (x + (i as f64) + 1.0);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Pcg64::seed_from_u64(1);
        for n in [1u64, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.gen_range(n) < n);
            }
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seed_from_u64(2);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn poisson_mean_small_lambda() {
        let mut r = Pcg64::seed_from_u64(3);
        let lam = 4.0;
        let n = 20_000;
        let mean = (0..n).map(|_| r.poisson(lam) as f64).sum::<f64>() / n as f64;
        assert!((mean - lam).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn poisson_mean_large_lambda() {
        let mut r = Pcg64::seed_from_u64(4);
        let lam = 70.0;
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.poisson(lam) as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - lam).abs() < 0.5, "mean={mean}");
        assert!((var - lam).abs() < 3.5, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seed_from_u64(5);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn ln_gamma_matches_factorials() {
        // ln Γ(n+1) = ln n!
        let mut fact = 1.0f64;
        for n in 1..15 {
            fact *= n as f64;
            assert!((ln_gamma(n as f64 + 1.0) - fact.ln()).abs() < 1e-9);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seed_from_u64(6);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn choose_uniformish() {
        let mut r = Pcg64::seed_from_u64(7);
        let xs = [0usize, 1, 2, 3];
        let mut counts = [0usize; 4];
        for _ in 0..8000 {
            counts[*r.choose(&xs)] += 1;
        }
        for c in counts {
            assert!((1700..2300).contains(&c), "counts={counts:?}");
        }
    }
}
