//! Small statistics helpers used by metrics, experiments, and the bench
//! harness (mean/variance/percentiles/confidence intervals).

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance (the paper's Fig 2(c)/3(c) metric is the variance of
/// total workload assigned to each satellite); 0.0 for < 2 samples.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator); 0.0 for < 2 samples.
pub fn stddev_sample(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated percentile, `p` in [0, 100]. Sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Min/max over a slice (NaN-free inputs assumed).
pub fn min_max(xs: &[f64]) -> (f64, f64) {
    xs.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &x| {
        (lo.min(x), hi.max(x))
    })
}

/// Approximate 95% confidence half-interval of the mean (normal approx).
pub fn ci95_half(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    1.96 * stddev_sample(xs) / (xs.len() as f64).sqrt()
}

/// Online mean/variance accumulator (Welford) — used in the sim hot loop to
/// avoid buffering every per-satellite sample.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0 + 1.0).collect();
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.variance() - variance(&xs)).abs() < 1e-10);
        assert_eq!(w.count(), 100);
    }

    #[test]
    fn min_max_works() {
        assert_eq!(min_max(&[3.0, -1.0, 2.0]), (-1.0, 3.0));
    }
}
