//! Property-testing substrate (offline image has no proptest): a seeded
//! case-generation loop with failure reporting and simple input shrinking
//! for integer-vector cases.
//!
//! Used by rust/tests/prop_invariants.rs to check splitting/offloading/
//! topology invariants over hundreds of random cases per property.

use crate::util::rng::Pcg64;

/// Number of random cases per property (override with SATKIT_QC_CASES).
pub fn default_cases() -> usize {
    std::env::var("SATKIT_QC_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(256)
}

/// Run `prop` on `cases` random inputs produced by `gen`. On failure, try
/// to shrink via `shrink` (halving-style candidates) and panic with the
/// smallest failing case and its seed.
pub fn check<T, G, P, S>(name: &str, cases: usize, mut gen: G, mut prop: P, shrink: S)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(&T) -> Result<(), String>,
    S: Fn(&T) -> Vec<T>,
{
    let base_seed = std::env::var("SATKIT_QC_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEEu64);
    for case in 0..cases {
        let mut rng = Pcg64::new(base_seed, case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // greedy shrink loop
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut improved = true;
            let mut budget = 200usize;
            while improved && budget > 0 {
                improved = false;
                for cand in shrink(&best) {
                    budget = budget.saturating_sub(1);
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        improved = true;
                        break;
                    }
                }
            }
            panic!(
                "property '{name}' failed (seed={base_seed}, case={case}):\n  input: {best:?}\n  error: {best_msg}"
            );
        }
    }
}

/// Convenience: no shrinking.
pub fn check_no_shrink<T, G, P>(name: &str, cases: usize, gen: G, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    check(name, cases, gen, prop, |_| Vec::new());
}

/// Shrinker for `Vec<f64>` workload vectors: drop halves, drop single
/// elements, halve values.
pub fn shrink_f64_vec(xs: &Vec<f64>) -> Vec<Vec<f64>> {
    let mut out = Vec::new();
    let n = xs.len();
    if n > 1 {
        out.push(xs[..n / 2].to_vec());
        out.push(xs[n / 2..].to_vec());
        if n <= 12 {
            for i in 0..n {
                let mut v = xs.clone();
                v.remove(i);
                if !v.is_empty() {
                    out.push(v);
                }
            }
        }
    }
    let halved: Vec<f64> = xs.iter().map(|x| (x / 2.0).max(1.0)).collect();
    if &halved != xs {
        out.push(halved);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check_no_shrink(
            "sum-nonneg",
            64,
            |r| (0..8).map(|_| r.f64()).collect::<Vec<f64>>(),
            |xs| {
                if xs.iter().sum::<f64>() >= 0.0 {
                    Ok(())
                } else {
                    Err("negative sum".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_context() {
        check(
            "always-fails",
            4,
            |r| vec![r.f64_in(1.0, 10.0)],
            |_| Err("nope".into()),
            shrink_f64_vec,
        );
    }

    #[test]
    fn shrinker_produces_smaller_cases() {
        let xs = vec![8.0, 6.0, 4.0, 2.0];
        let cands = shrink_f64_vec(&xs);
        assert!(cands.iter().any(|c| c.len() < xs.len()));
        assert!(!cands.iter().any(|c| c.is_empty()));
    }
}
