//! Tiny CLI argument substrate (offline image has no clap).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! subcommands, and generates usage text from registered options.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, named options, and positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse raw args (excluding argv[0]). The first non-dash token becomes
    /// the subcommand; `--key value` / `--key=value` become options; a
    /// trailing dash token with no value becomes a flag.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positionals.push(tok);
            }
        }
        out
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    /// Typed lookup with a default.
    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.options.get(name) {
            Some(v) => v.parse::<T>().unwrap_or(default),
            None => default,
        }
    }

    /// Typed lookup that reports a parse error instead of defaulting.
    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.options.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| format!("--{name}={v}: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("simulate --model vgg19 --lambda 25 --seed=7 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.get("model"), Some("vgg19"));
        assert_eq!(a.get_or::<u64>("lambda", 0), 25);
        assert_eq!(a.get_or::<u64>("seed", 0), 7);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn positionals_collected() {
        let a = parse("run a b c");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positionals, vec!["a", "b", "c"]);
    }

    #[test]
    fn typed_parse_error_reported() {
        let a = parse("x --n notanumber");
        assert!(a.get_parsed::<u32>("n").is_err());
        assert_eq!(parse("x --n 3").get_parsed::<u32>("n").unwrap(), Some(3));
    }

    #[test]
    fn flag_before_option_value_boundary() {
        // --dry is a flag because the next token starts with --
        let a = parse("x --dry --n 3");
        assert!(a.has_flag("dry"));
        assert_eq!(a.get_or::<u32>("n", 0), 3);
    }
}
