//! Shared substrates: deterministic RNG (+ Poisson/Normal samplers), small
//! statistics, a JSON reader/writer, a CLI parser, and a property-testing
//! loop — all self-contained because the build image is fully offline.

pub mod cli;
pub mod json;
pub mod quickcheck;
pub mod rng;
pub mod stats;
