//! Minimal JSON substrate (offline image has no serde): a writer for
//! metrics/experiment export and a recursive-descent parser for the
//! artifact sidecar `*.meta.json` files produced by python/compile/aot.py.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. `Object` uses a BTreeMap so serialization is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (full input must be consumed).
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8")?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj(vec![
            ("name", Json::Str("vgg_slice".into())),
            ("n", Json::Num(3.0)),
            ("ok", Json::Bool(true)),
            (
                "shape",
                Json::Arr(vec![Json::Num(1.0), Json::Num(56.0), Json::Num(64.0)]),
            ),
        ]);
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn parses_aot_sidecar_format() {
        let text = r#"{
  "name": "qnet",
  "inputs": [ { "shape": [8, 32], "dtype": "float32" } ],
  "outputs": [ { "shape": [8, 5], "dtype": "float32" } ]
}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("name").unwrap().as_str(), Some("qnet"));
        let inp = &j.get("inputs").unwrap().as_arr().unwrap()[0];
        let shape: Vec<f64> = inp
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap())
            .collect();
        assert_eq!(shape, vec![8.0, 32.0]);
    }

    #[test]
    fn string_escapes() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn negative_and_float_numbers() {
        let j = Json::parse("[-1.5, 2e3, 0.25]").unwrap();
        let v: Vec<f64> = j.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap()).collect();
        assert_eq!(v, vec![-1.5, 2000.0, 0.25]);
    }
}
