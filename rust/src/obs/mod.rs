//! Run-time observability: task-lifecycle tracing, runtime counters, and
//! Chrome-trace/Perfetto export (`--trace`, `--telemetry`).
//!
//! Both engines own one [`Obs`] instance and call its inline hooks from
//! their hot paths. Every hook branches on a single `enabled` flag and
//! returns immediately when telemetry is off — no trait objects, no RNG
//! draws, no float arithmetic, no allocation — so disabled runs stay
//! bit-for-bit identical to an uninstrumented engine (enforced by
//! `tests/prop_telemetry.rs`). All timestamps are **simulation time**
//! (seconds, exported as microseconds); the subsystem never reads a wall
//! clock, so traces are deterministic per seed.
//!
//! Three surfaces:
//!
//! * **Trace recorder** — a bounded ring buffer of [`SpanKind`] spans
//!   (task lifetime, ground-to-satellite uplink, segment execution, ISL
//!   transfer) and [`InstantKind`] instants (offload decisions, faults,
//!   handovers, state broadcasts), exported as Chrome-trace-event JSON
//!   loadable by `chrome://tracing` and <https://ui.perfetto.dev> via
//!   [`Obs::write_trace`]. When the buffer fills, the **oldest** records
//!   are overwritten (and counted), so the tail of a long run survives.
//! * **Counter registry** — cheap aggregate counters ([`Counters`]) plus
//!   per-satellite queue-depth/utilization samples on a sim-time cadence
//!   ([`Obs::maybe_sample`]) and engine gauges (event-queue depth,
//!   live-task slab occupancy, [`Obs::sample_engine`]), serialized as the
//!   `telemetry` block of [`crate::metrics::Report::to_json`] via
//!   [`Obs::telemetry_json`].
//! * **Sweep progress** — `--progress` per-cell start/finish lines on
//!   stderr, implemented by `satkit::experiments` (stdout untouched).
//!
//! Trace pid/tid mapping: task-scoped spans (`task`, `uplink`) live in
//! pid 0 with `tid = task id`; per-satellite spans (`exec`, `isl`) live
//! in `pid = 1 + satellite id` with `tid = task id`; instants are global
//! (pid 0, tid 0); counter samples attach to their satellite's pid.

use crate::satellite::Satellite;
use crate::util::json::Json;

/// Ring-buffer capacity used when `--trace <path>` gives no `:<max-events>`
/// suffix (~40 MB of records; a quick-mode run stays far below it).
pub const DEFAULT_MAX_EVENTS: usize = 1_000_000;

/// Where (and how much) to trace: parsed from `--trace <path>[:<max-events>]`.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceConfig {
    /// Output path of the Chrome-trace-event JSON file.
    pub path: String,
    /// Ring-buffer capacity in records; oldest records are overwritten
    /// once exceeded.
    pub max_events: usize,
}

impl TraceConfig {
    /// Parse `<path>[:<max-events>]`. A trailing `:<integer>` is the ring
    /// capacity; any other suffix (e.g. a Windows drive or a `:` in the
    /// filename) stays part of the path.
    pub fn parse(spec: &str) -> Result<TraceConfig, String> {
        if let Some((path, suffix)) = spec.rsplit_once(':') {
            if let Ok(n) = suffix.parse::<usize>() {
                if n == 0 {
                    return Err("--trace: max-events must be >= 1".into());
                }
                if path.is_empty() {
                    return Err("--trace: path must be non-empty".into());
                }
                return Ok(TraceConfig {
                    path: path.to_string(),
                    max_events: n,
                });
            }
        }
        if spec.is_empty() {
            return Err("--trace: path must be non-empty".into());
        }
        Ok(TraceConfig {
            path: spec.to_string(),
            max_events: DEFAULT_MAX_EVENTS,
        })
    }
}

/// Telemetry configuration carried on [`crate::config::SimConfig`] (so
/// both engines receive it through their ordinary constructors).
#[derive(Clone, Debug, PartialEq)]
pub struct ObsConfig {
    /// Collect runtime counters and emit the `telemetry` report block
    /// (`--telemetry`; implied by `--trace`).
    pub telemetry: bool,
    /// Record and export a task-lifecycle trace (`--trace`).
    pub trace: Option<TraceConfig>,
    /// Sim-time cadence of per-satellite counter samples [s]
    /// (`--counter-period`; the event engine samples at the first event
    /// on or after each due time, the slotted engine at slot starts).
    pub counter_period_s: f64,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            telemetry: false,
            trace: None,
            counter_period_s: 1.0,
        }
    }
}

impl ObsConfig {
    /// True when any telemetry surface is on — the single flag every
    /// engine hook branches on.
    pub fn enabled(&self) -> bool {
        self.telemetry || self.trace.is_some()
    }

    /// Range-check the knobs.
    pub fn validate(&self) -> Result<(), String> {
        if !self.counter_period_s.is_finite() || self.counter_period_s <= 0.0 {
            return Err(format!(
                "counter period {} must be finite and > 0",
                self.counter_period_s
            ));
        }
        Ok(())
    }
}

/// Duration-event classes of the task lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Whole task lifetime: arrival to completion/drop.
    Task,
    /// Ground-to-satellite uplink of the raw input (Eq. 5 prefix).
    Uplink,
    /// One segment executing on its satellite.
    Exec,
    /// Intermediate activation hopping ISLs to the next satellite (Eq. 7).
    Isl,
}

impl SpanKind {
    /// Chrome-trace event name.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Task => "task",
            SpanKind::Uplink => "uplink",
            SpanKind::Exec => "exec",
            SpanKind::Isl => "isl",
        }
    }
}

/// Instant-event classes (zero-duration marks on the global track).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InstantKind {
    /// An offload decision was made (arg = origin satellite).
    Decide,
    /// A fault-injector tick toggled satellites (arg = newly failed count).
    Fault,
    /// A serving-satellite handover (arg = affected areas).
    Handover,
    /// A `StateBroadcast` / gossip tick refreshed disseminated views
    /// (arg = broadcast ordinal).
    Broadcast,
    /// A faulted task was re-offloaded onto surviving satellites
    /// (arg = task id).
    Recover,
    /// An in-flight ISL transfer was re-routed around a dead link
    /// (arg = task id).
    Reroute,
}

impl InstantKind {
    /// Chrome-trace event name.
    pub fn name(self) -> &'static str {
        match self {
            InstantKind::Decide => "decide",
            InstantKind::Fault => "fault",
            InstantKind::Handover => "handover",
            InstantKind::Broadcast => "broadcast",
            InstantKind::Recover => "recover",
            InstantKind::Reroute => "reroute",
        }
    }
}

/// One ring-buffer record (kept `Copy`-small: the hot path stores these
/// by value, the exporter does all formatting after the run).
#[derive(Clone, Copy, Debug)]
enum Rec {
    Span {
        kind: SpanKind,
        t0: f64,
        t1: f64,
        sat: u32,
        task: u64,
        k: u16,
        ok: bool,
    },
    Instant {
        kind: InstantKind,
        t: f64,
        arg: u32,
    },
    SatSample {
        t: f64,
        sat: u32,
        queue: f64,
        util: f64,
    },
    EngineSample {
        t: f64,
        events: u32,
        live: u32,
        slots: u32,
    },
}

/// Bounded trace storage: a `Vec` ring with overwrite-oldest semantics.
struct TraceRecorder {
    path: String,
    cap: usize,
    buf: Vec<Rec>,
    /// Index of the oldest record once the ring has wrapped.
    head: usize,
    dropped: u64,
    total: u64,
}

impl TraceRecorder {
    fn new(cfg: &TraceConfig) -> TraceRecorder {
        TraceRecorder {
            path: cfg.path.clone(),
            cap: cfg.max_events.max(1),
            buf: Vec::new(),
            head: 0,
            dropped: 0,
            total: 0,
        }
    }

    #[inline]
    fn push(&mut self, r: Rec) {
        self.total += 1;
        if self.buf.len() < self.cap {
            self.buf.push(r);
        } else {
            self.buf[self.head] = r;
            self.head += 1;
            if self.head == self.cap {
                self.head = 0;
            }
            self.dropped += 1;
        }
    }

    /// Records in chronological order (ring unrolled from the oldest).
    fn iter(&self) -> impl Iterator<Item = &Rec> {
        self.buf[self.head..].iter().chain(self.buf[..self.head].iter())
    }

    fn write_events(&self, out: &mut String) {
        use std::fmt::Write as _;
        // sim seconds -> trace microseconds
        let us = |t: f64| t * 1e6;
        let mut first = true;
        for r in self.iter() {
            if !first {
                out.push(',');
            }
            first = false;
            match *r {
                Rec::Span {
                    kind,
                    t0,
                    t1,
                    sat,
                    task,
                    k,
                    ok,
                } => {
                    let pid = match kind {
                        SpanKind::Task | SpanKind::Uplink => 0,
                        SpanKind::Exec | SpanKind::Isl => 1 + sat,
                    };
                    let _ = write!(
                        out,
                        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":{pid},\"tid\":{task},\"args\":{{\"sat\":{sat},\"seg\":{k},\"ok\":{ok}}}}}",
                        kind.name(),
                        kind.name(),
                        us(t0),
                        (us(t1) - us(t0)).max(0.0),
                    );
                }
                Rec::Instant { kind, t, arg } => {
                    let _ = write!(
                        out,
                        "{{\"name\":\"{}\",\"cat\":\"mark\",\"ph\":\"i\",\"s\":\"g\",\"ts\":{},\"pid\":0,\"tid\":0,\"args\":{{\"v\":{arg}}}}}",
                        kind.name(),
                        us(t),
                    );
                }
                Rec::SatSample {
                    t,
                    sat,
                    queue,
                    util,
                } => {
                    let _ = write!(
                        out,
                        "{{\"name\":\"sat{sat}\",\"ph\":\"C\",\"ts\":{},\"pid\":{},\"tid\":0,\"args\":{{\"queue_mflops\":{queue},\"utilization\":{util}}}}}",
                        us(t),
                        1 + sat,
                    );
                }
                Rec::EngineSample {
                    t,
                    events,
                    live,
                    slots,
                } => {
                    let _ = write!(
                        out,
                        "{{\"name\":\"engine\",\"ph\":\"C\",\"ts\":{},\"pid\":0,\"tid\":0,\"args\":{{\"event_queue\":{events},\"live_tasks\":{live},\"arena_slots\":{slots}}}}}",
                        us(t),
                    );
                }
            }
        }
    }
}

/// Aggregate runtime counters, summed whenever telemetry is enabled and
/// serialized into the report's `telemetry` block.
#[derive(Clone, Debug, Default)]
pub struct Counters {
    /// Task-lifetime spans recorded (= tasks that reached an outcome).
    pub spans_task: u64,
    /// Uplink spans recorded.
    pub spans_uplink: u64,
    /// Segment-execution spans recorded.
    pub spans_exec: u64,
    /// ISL-transfer spans recorded.
    pub spans_isl: u64,
    /// Task spans that ended in completion.
    pub tasks_completed: u64,
    /// Task spans that ended in a drop.
    pub tasks_dropped: u64,
    /// Offload-decision instants.
    pub instants_decide: u64,
    /// Fault-tick instants (ticks that toggled at least one satellite).
    pub instants_fault: u64,
    /// Handover instants.
    pub instants_handover: u64,
    /// State-broadcast / gossip-tick instants.
    pub instants_broadcast: u64,
    /// Task-recovery (re-offload) instants.
    pub instants_recover: u64,
    /// ISL-transfer reroute instants.
    pub instants_reroute: u64,
    /// Per-satellite counter sampling rounds taken.
    pub samples: u64,
    /// Highest sampled per-satellite queue depth [MFLOP].
    pub queue_peak_mflops: f64,
    /// Sum of sampled utilizations (mean = `util_sum / util_points`).
    pub util_sum: f64,
    /// Number of per-satellite utilization points sampled.
    pub util_points: u64,
    /// Peak sampled event-queue depth (event engine).
    pub event_queue_peak: u64,
    /// Peak sampled live-task count (event engine slab arena).
    pub live_tasks_peak: u64,
    /// Peak sampled slab-arena slot count (allocation high-water mark).
    pub arena_slots_peak: u64,
}

/// The engine-facing telemetry instance: counters plus the optional
/// trace ring, behind one `enabled` flag.
pub struct Obs {
    enabled: bool,
    counter_period_s: f64,
    next_sample_s: f64,
    trace: Option<TraceRecorder>,
    counters: Counters,
}

impl Obs {
    /// A disabled instance: every hook is a single predicted-false branch.
    pub fn off() -> Obs {
        Obs {
            enabled: false,
            counter_period_s: 1.0,
            next_sample_s: 0.0,
            trace: None,
            counters: Counters::default(),
        }
    }

    /// Build from the config block ([`Obs::off`] when nothing is on).
    pub fn from_config(cfg: &ObsConfig) -> Obs {
        if !cfg.enabled() {
            return Obs::off();
        }
        Obs {
            enabled: true,
            counter_period_s: cfg.counter_period_s,
            next_sample_s: 0.0,
            trace: cfg.trace.as_ref().map(TraceRecorder::new),
            counters: Counters::default(),
        }
    }

    /// The single flag every hook branches on.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The aggregate counters collected so far.
    pub fn counters(&self) -> &Counters {
        &self.counters
    }

    /// Record a whole-task lifetime span (arrival to completion/drop).
    #[inline]
    pub fn task_span(&mut self, t0: f64, t1: f64, origin: usize, task: u64, completed: bool) {
        if !self.enabled {
            return;
        }
        self.counters.spans_task += 1;
        if completed {
            self.counters.tasks_completed += 1;
        } else {
            self.counters.tasks_dropped += 1;
        }
        if let Some(tr) = &mut self.trace {
            tr.push(Rec::Span {
                kind: SpanKind::Task,
                t0,
                t1,
                sat: origin as u32,
                task,
                k: 0,
                ok: completed,
            });
        }
    }

    /// Record an uplink / segment-exec / ISL-transfer span for segment `k`
    /// of `task` on satellite `sat`.
    #[inline]
    pub fn seg_span(&mut self, kind: SpanKind, t0: f64, t1: f64, sat: usize, task: u64, k: usize) {
        if !self.enabled {
            return;
        }
        match kind {
            SpanKind::Task => self.counters.spans_task += 1,
            SpanKind::Uplink => self.counters.spans_uplink += 1,
            SpanKind::Exec => self.counters.spans_exec += 1,
            SpanKind::Isl => self.counters.spans_isl += 1,
        }
        if let Some(tr) = &mut self.trace {
            tr.push(Rec::Span {
                kind,
                t0,
                t1,
                sat: sat as u32,
                task,
                k: k as u16,
                ok: true,
            });
        }
    }

    /// Record an instant event (fault, handover, broadcast, decision).
    #[inline]
    pub fn instant(&mut self, kind: InstantKind, t: f64, arg: usize) {
        if !self.enabled {
            return;
        }
        match kind {
            InstantKind::Decide => self.counters.instants_decide += 1,
            InstantKind::Fault => self.counters.instants_fault += 1,
            InstantKind::Handover => self.counters.instants_handover += 1,
            InstantKind::Broadcast => self.counters.instants_broadcast += 1,
            InstantKind::Recover => self.counters.instants_recover += 1,
            InstantKind::Reroute => self.counters.instants_reroute += 1,
        }
        if let Some(tr) = &mut self.trace {
            tr.push(Rec::Instant {
                kind,
                t,
                arg: arg as u32,
            });
        }
    }

    /// Sample per-satellite queue depth and utilization if the sim-time
    /// cadence is due at `t`; returns true when a sample was taken (the
    /// event engine follows up with [`Obs::sample_engine`]). Samples land
    /// on the first call at or after each due time, so consecutive
    /// samples are at least one period apart.
    #[inline]
    pub fn maybe_sample(&mut self, t: f64, sats: &[Satellite]) -> bool {
        if !self.enabled || t < self.next_sample_s {
            return false;
        }
        self.next_sample_s = t + self.counter_period_s;
        self.counters.samples += 1;
        for (id, s) in sats.iter().enumerate() {
            let queue = s.loaded();
            let util = s.utilization();
            if queue > self.counters.queue_peak_mflops {
                self.counters.queue_peak_mflops = queue;
            }
            self.counters.util_sum += util;
            self.counters.util_points += 1;
            if let Some(tr) = &mut self.trace {
                tr.push(Rec::SatSample {
                    t,
                    sat: id as u32,
                    queue,
                    util,
                });
            }
        }
        true
    }

    /// Engine-level gauges (event engine): pending-event-queue depth,
    /// live-task count, and slab-arena slot high-water mark.
    #[inline]
    pub fn sample_engine(
        &mut self,
        t: f64,
        event_queue: usize,
        live_tasks: usize,
        arena_slots: usize,
    ) {
        if !self.enabled {
            return;
        }
        let c = &mut self.counters;
        c.event_queue_peak = c.event_queue_peak.max(event_queue as u64);
        c.live_tasks_peak = c.live_tasks_peak.max(live_tasks as u64);
        c.arena_slots_peak = c.arena_slots_peak.max(arena_slots as u64);
        if let Some(tr) = &mut self.trace {
            tr.push(Rec::EngineSample {
                t,
                events: event_queue as u32,
                live: live_tasks as u32,
                slots: arena_slots as u32,
            });
        }
    }

    /// The full trace as a Chrome-trace-event JSON document
    /// (`{"traceEvents":[...]}`), empty when no trace is configured.
    pub fn to_chrome_json(&self) -> String {
        let mut out = String::from("{\"traceEvents\":[");
        if let Some(tr) = &self.trace {
            tr.write_events(&mut out);
        }
        out.push_str("]}");
        out
    }

    /// Write the trace file if one was configured (end of run). IO
    /// failures are reported on stderr, never panicking a finished run.
    pub fn write_trace(&self) {
        let Some(tr) = &self.trace else {
            return;
        };
        let json = self.to_chrome_json();
        match std::fs::write(&tr.path, json) {
            Ok(()) => eprintln!(
                "trace: wrote {} ({} of {} events retained, {} dropped)",
                tr.path,
                tr.buf.len(),
                tr.total,
                tr.dropped
            ),
            Err(e) => eprintln!("trace: writing {} failed: {e}", tr.path),
        }
    }

    /// The `telemetry` block for [`crate::metrics::Report::to_json`]:
    /// counter aggregates, trace bookkeeping, dissemination broadcasts,
    /// and the scheme's kernel stats (`scheme`, e.g. GA memo/index-cache
    /// hit rates — `None` for schemes without internal caches).
    pub fn telemetry_json(&self, engine: &str, broadcasts: u64, scheme: Option<Json>) -> Json {
        let c = &self.counters;
        let num = |x: u64| Json::Num(x as f64);
        let mut pairs = vec![
            ("engine", Json::Str(engine.into())),
            ("counter_period_s", Json::Num(self.counter_period_s)),
            (
                "spans",
                Json::obj(vec![
                    ("task", num(c.spans_task)),
                    ("uplink", num(c.spans_uplink)),
                    ("exec", num(c.spans_exec)),
                    ("isl", num(c.spans_isl)),
                ]),
            ),
            (
                "instants",
                Json::obj(vec![
                    ("decide", num(c.instants_decide)),
                    ("fault", num(c.instants_fault)),
                    ("handover", num(c.instants_handover)),
                    ("broadcast", num(c.instants_broadcast)),
                    ("recover", num(c.instants_recover)),
                    ("reroute", num(c.instants_reroute)),
                ]),
            ),
            ("samples", num(c.samples)),
            ("queue_peak_mflops", Json::Num(c.queue_peak_mflops)),
            (
                "utilization_mean",
                Json::Num(if c.util_points > 0 {
                    c.util_sum / c.util_points as f64
                } else {
                    0.0
                }),
            ),
            ("event_queue_peak", num(c.event_queue_peak)),
            ("live_tasks_peak", num(c.live_tasks_peak)),
            ("arena_slots_peak", num(c.arena_slots_peak)),
            ("state_broadcasts", num(broadcasts)),
        ];
        if let Some(tr) = &self.trace {
            pairs.push((
                "trace",
                Json::obj(vec![
                    ("path", Json::Str(tr.path.clone())),
                    ("events", num(tr.total)),
                    ("retained", num(tr.buf.len() as u64)),
                    ("dropped", num(tr.dropped)),
                    ("max_events", num(tr.cap as u64)),
                ]),
            ));
        }
        if let Some(s) = scheme {
            pairs.push(("scheme", s));
        }
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn traced(max_events: usize) -> Obs {
        Obs::from_config(&ObsConfig {
            telemetry: true,
            trace: Some(TraceConfig {
                path: "unused.json".into(),
                max_events,
            }),
            counter_period_s: 1.0,
        })
    }

    #[test]
    fn trace_spec_parses_path_and_cap() {
        let t = TraceConfig::parse("trace.json").unwrap();
        assert_eq!(t.path, "trace.json");
        assert_eq!(t.max_events, DEFAULT_MAX_EVENTS);
        let t = TraceConfig::parse("out/run.json:5000").unwrap();
        assert_eq!(t.path, "out/run.json");
        assert_eq!(t.max_events, 5000);
        // a non-numeric suffix belongs to the path
        let t = TraceConfig::parse("odd:name.json").unwrap();
        assert_eq!(t.path, "odd:name.json");
        assert_eq!(t.max_events, DEFAULT_MAX_EVENTS);
        assert!(TraceConfig::parse("").is_err());
        assert!(TraceConfig::parse(":5").is_err());
        assert!(TraceConfig::parse("t.json:0").is_err());
    }

    #[test]
    fn obs_config_enable_and_validate() {
        let mut c = ObsConfig::default();
        assert!(!c.enabled());
        assert!(c.validate().is_ok());
        c.telemetry = true;
        assert!(c.enabled());
        c.telemetry = false;
        c.trace = Some(TraceConfig::parse("t.json").unwrap());
        assert!(c.enabled());
        c.counter_period_s = 0.0;
        assert!(c.validate().is_err());
        c.counter_period_s = f64::NAN;
        assert!(c.validate().is_err());
    }

    #[test]
    fn disabled_hooks_record_nothing() {
        let mut o = Obs::off();
        assert!(!o.enabled());
        o.task_span(0.0, 1.0, 0, 1, true);
        o.seg_span(SpanKind::Exec, 0.0, 1.0, 0, 1, 0);
        o.instant(InstantKind::Fault, 0.5, 1);
        let sats = vec![Satellite::new(0, 3000.0, 15_000.0)];
        assert!(!o.maybe_sample(5.0, &sats));
        o.sample_engine(5.0, 10, 10, 10);
        assert_eq!(o.counters().spans_task, 0);
        assert_eq!(o.counters().samples, 0);
        assert_eq!(o.to_chrome_json(), "{\"traceEvents\":[]}");
    }

    #[test]
    fn ring_overwrites_oldest_and_exports_chronologically() {
        let mut o = traced(4);
        for i in 0..6 {
            o.instant(InstantKind::Broadcast, i as f64, i);
        }
        let doc = Json::parse(&o.to_chrome_json()).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 4);
        // oldest two (t=0, t=1) were overwritten; order stays chronological
        let ts: Vec<f64> = events
            .iter()
            .map(|e| e.get("ts").unwrap().as_f64().unwrap())
            .collect();
        assert_eq!(ts, vec![2e6, 3e6, 4e6, 5e6]);
        let tj = o.telemetry_json("event", 0, None);
        let trace = tj.get("trace").unwrap();
        assert_eq!(trace.get("events").unwrap().as_f64(), Some(6.0));
        assert_eq!(trace.get("retained").unwrap().as_f64(), Some(4.0));
        assert_eq!(trace.get("dropped").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn sampling_follows_sim_time_cadence() {
        let mut o = traced(64);
        let mut sats = vec![
            Satellite::new(0, 3000.0, 15_000.0),
            Satellite::new(1, 3000.0, 15_000.0),
        ];
        sats[1].try_load(4500.0);
        assert!(o.maybe_sample(0.0, &sats));
        assert!(!o.maybe_sample(0.5, &sats));
        assert!(o.maybe_sample(1.25, &sats));
        assert!(!o.maybe_sample(2.0, &sats)); // next due at 2.25
        assert!(o.maybe_sample(2.5, &sats));
        assert_eq!(o.counters().samples, 3);
        assert_eq!(o.counters().util_points, 6);
        assert_eq!(o.counters().queue_peak_mflops, 4500.0);
    }

    #[test]
    fn chrome_export_covers_every_record_class() {
        let mut o = traced(64);
        o.task_span(0.0, 2.0, 3, 7, false);
        o.seg_span(SpanKind::Uplink, 0.0, 0.25, 3, 7, 0);
        o.seg_span(SpanKind::Exec, 0.25, 1.0, 5, 7, 0);
        o.seg_span(SpanKind::Isl, 1.0, 1.5, 5, 7, 0);
        o.instant(InstantKind::Decide, 0.0, 3);
        o.instant(InstantKind::Fault, 0.5, 1);
        o.instant(InstantKind::Handover, 0.75, 2);
        o.instant(InstantKind::Broadcast, 1.0, 1);
        o.instant(InstantKind::Recover, 1.1, 7);
        o.instant(InstantKind::Reroute, 1.2, 7);
        let sats = vec![Satellite::new(0, 3000.0, 15_000.0)];
        assert!(o.maybe_sample(1.0, &sats));
        o.sample_engine(1.0, 9, 4, 12);
        let doc = Json::parse(&o.to_chrome_json()).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 12);
        let names: Vec<&str> = events
            .iter()
            .map(|e| e.get("name").unwrap().as_str().unwrap())
            .collect();
        for want in [
            "task", "uplink", "exec", "isl", "decide", "fault", "handover", "broadcast",
            "recover", "reroute", "sat0", "engine",
        ] {
            assert!(names.contains(&want), "missing {want} in {names:?}");
        }
        // exec span lives in its satellite's pid and carries the task tid
        let exec = events
            .iter()
            .find(|e| e.get("name").unwrap().as_str() == Some("exec"))
            .unwrap();
        assert_eq!(exec.get("pid").unwrap().as_f64(), Some(6.0));
        assert_eq!(exec.get("tid").unwrap().as_f64(), Some(7.0));
        assert_eq!(exec.get("dur").unwrap().as_f64(), Some(0.75e6));
        // counters aggregated alongside
        let c = o.counters();
        assert_eq!(c.spans_task, 1);
        assert_eq!(c.tasks_dropped, 1);
        assert_eq!(c.instants_decide, 1);
        assert_eq!(c.event_queue_peak, 9);
        assert_eq!(c.arena_slots_peak, 12);
    }

    #[test]
    fn telemetry_json_shape() {
        let mut o = Obs::from_config(&ObsConfig {
            telemetry: true,
            trace: None,
            counter_period_s: 0.5,
        });
        o.task_span(0.0, 1.0, 0, 1, true);
        let j = o.telemetry_json(
            "slotted",
            3,
            Some(Json::obj(vec![("memo_hits", Json::Num(5.0))])),
        );
        assert_eq!(j.get("engine").unwrap().as_str(), Some("slotted"));
        assert_eq!(j.get("counter_period_s").unwrap().as_f64(), Some(0.5));
        assert_eq!(
            j.get("spans").unwrap().get("task").unwrap().as_f64(),
            Some(1.0)
        );
        assert_eq!(j.get("state_broadcasts").unwrap().as_f64(), Some(3.0));
        assert_eq!(
            j.get("scheme").unwrap().get("memo_hits").unwrap().as_f64(),
            Some(5.0)
        );
        assert!(j.get("trace").is_none());
        // serializes as parseable JSON
        assert!(Json::parse(&j.to_string()).is_ok());
    }
}
