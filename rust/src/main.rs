//! satkit CLI — leader entrypoint.
//!
//! Subcommands:
//!   simulate            run one simulation and print the report
//!                       (--engine slotted|event, --scenario for traffic)
//!   sweep               λ-sweep all four schemes for one model
//!   experiment <id>     regenerate a paper figure (fig2|fig3|eventsim|
//!                       staleness|topology|decidecache|resilience|scale|
//!                       ablation-split|ablation-ga|all); writes
//!                       results/<id>.json next to the printed table
//!                       (staleness/topology/decidecache/resilience also
//!                       emit BENCH_staleness.json / BENCH_topology.json /
//!                       BENCH_decidecache.json / BENCH_resilience.json)
//!   serve               run the coordinator on real PJRT slice inference
//!   validate-artifacts  load + execute every artifact once
//!   print-config        show the effective Table-I configuration
//!
//! Common options: --config <file.toml>, --n, --slots, --lambda, --model,
//! --scheme, --seed, --split-l, --d-max, --json <out.json>.

use satkit::config::SimConfig;
use satkit::coordinator::{Coordinator, InferenceRequest};
use satkit::dnn::DnnModel;
use satkit::experiments as exp;
use satkit::offload::SchemeKind;
use satkit::runtime::{default_artifact_dir, Engine};
use satkit::util::cli::Args;
use satkit::util::stats;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<(), String> {
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "simulate" => simulate(args),
        "sweep" => sweep(args),
        "experiment" => experiment(args),
        "serve" => serve(args).map_err(|e| format!("{e:#}")),
        "validate-artifacts" => validate_artifacts().map_err(|e| format!("{e:#}")),
        "print-config" => {
            let cfg = load_cfg(args)?;
            println!("{}", cfg.table());
            Ok(())
        }
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "satkit — collaborative satellite computing (ISCC 2024 reproduction)

USAGE: satkit <subcommand> [--options]

SUBCOMMANDS
  simulate            one simulation run (--scheme scc|random|rrp|dqn)
  sweep               lambda sweep, all schemes (--model vgg19|resnet101)
  experiment <id>     fig2 | fig3 | eventsim | staleness | topology |
                      decidecache | llm | resilience | scale |
                      ablation-split | ablation-ga | all
  serve               coordinator with real PJRT slice inference
  validate-artifacts  compile + execute each artifacts/*.hlo.txt
  print-config        effective Table-I parameters

OPTIONS
  --config FILE   TOML config   --n N          grid edge (default 10)
  --slots S       time slots    --lambda L     task incidence (4-70)
  --model M       vgg19|resnet101              --scheme S
  --engine E      slotted|event (event = continuous-time kernel)
  --scenario T    poisson|diurnal|bursty|hotspot (event engine traffic)
  --topology T    torus:<n> | walker-delta:<p>x<s>[:f] | walker-star:<p>x<s>
                  constellation geometry (default: the paper torus from --n;
                  walker-star has a polar seam with no cross-seam ISLs)
  --dissemination D  instant|periodic:<s>|gossip[:<s>] — how stale the
                  resource state behind offloading decisions is (default:
                  instant on the event engine, periodic:1 on the slotted)
  --isl-latency-ms M  per-hop ISL store-and-forward latency (default 25);
                  sets the tick of a bare --dissemination gossip
  --task-kind K   oneshot | autoregressive[:<rounds>[:<mflops>[:<bytes>
                  [:<escalate_s>]]]] — task workload shape (default
                  oneshot; autoregressive runs LLM-style decode rounds
                  after the split chain; unstated fields fall back to
                  the [llm] TOML block)
  --p-fail P      per-tick satellite outage probability (default 0);
                  --p-recover sets the per-tick recovery probability
  --link-p-fail P per-tick ISL link outage probability (default 0);
                  --link-p-recover sets the link recovery probability;
                  --seam-outage restricts link faults to the polar-seam
                  planes of a walker-star
  --recovery R    drop | reoffload[:<max_retries>] — what happens to a
                  task whose chain is hit by a fault (default drop, the
                  paper's behaviour; reoffload re-decides the surviving
                  tail over healthy satellites, retry budget default 2)
  --fault-trace F scripted outage windows, one \"<t0> <t1> sat:<i>\" or
                  \"<t0> <t1> link:<a>-<b>\" per line (forced on top of
                  the Bernoulli fault processes)
  --link-timeout S    stall before a severed in-flight ISL transfer
                  retries (default 1); --recovery-deadline caps how late
                  after arrival a task may still re-offload (default 10)
  --seed X        RNG seed      --repeats R    seeds averaged per point
  --threads T     sweep cells fanned over T workers (0 = all cores, the
                  default; 1 = sequential — rows are byte-identical;
                  repeats > 1 fan out per (cell, repeat) pair)
  --shards K      event-engine pending-event shards (1 = classic single
                  heap, the default; 0 = one shard per orbital plane;
                  any K — runs are byte-identical at every setting)
  --decide-threads K  GA generation-evaluation lanes (1 = sequential, the
                  default; 0 = one per core; any K — runs are
                  byte-identical at every setting)
  --decision-cache  epoch-keyed GA placement memo for stale views under
                  periodic dissemination (off by default; NOT
                  byte-identical — hits skip the GA entirely)
  --quick         smaller slot budget          --json FILE   export rows
  --retain-outcomes  buffer per-task outcomes (metrics stream by default)
  --telemetry     runtime counters: adds a `telemetry` block to the report
                  JSON (queue/utilization samples, GA kernel stats, ...)
  --trace F[:M]   record task-lifecycle spans to a Chrome-trace/Perfetto
                  JSON file (ring buffer of M events, default 1000000);
                  implies the counters of --telemetry
  --counter-period S  sim-seconds between telemetry counter samples
                  (default 1)
  --progress      per-cell sweep progress lines on stderr (stdout clean)
  --force         experiment: overwrite existing results/*.json files
  --requests K    serve: number of requests    --workers W   exec workers";

fn load_cfg(args: &Args) -> Result<SimConfig, String> {
    SimConfig::load(args.get("config"), args)
}

fn sweep_opts(args: &Args, cfg: &SimConfig) -> exp::SweepOpts {
    let mut o = if args.has_flag("quick") {
        exp::SweepOpts::quick()
    } else {
        exp::SweepOpts::default()
    };
    o.seed = cfg.seed;
    o.slots = args.get_or("slots", if args.has_flag("quick") { o.slots } else { cfg.slots });
    o.decision_fraction = cfg.decision_fraction;
    o.repeats = args.get_or("repeats", 1usize);
    o.threads = args.get_or("threads", 0usize);
    o.shards = cfg.shards;
    o.decide_threads = cfg.decide_threads;
    o.decision_cache = cfg.decision_cache;
    // --engine / --scenario / --dissemination / --topology flow into
    // sweeps and experiments too
    o.engine = cfg.engine;
    o.scenario = cfg.scenario;
    o.dissemination = cfg.dissemination;
    o.topology = cfg.topology.clone();
    o.progress = args.has_flag("progress");
    o
}

fn maybe_write_json(args: &Args, rows: &[exp::Row]) -> Result<(), String> {
    if let Some(path) = args.get("json") {
        std::fs::write(path, exp::rows_to_json(rows).to_string())
            .map_err(|e| format!("writing {path}: {e}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn simulate(args: &Args) -> Result<(), String> {
    let cfg = load_cfg(args)?;
    let kind = SchemeKind::parse(args.get("scheme").unwrap_or("scc"))?;
    println!("{}", cfg.table());
    println!();
    // cfg.engine picks the slotted loop or the event kernel; cfg.scenario
    // picks the event engine's traffic profile (--engine / --scenario)
    let report = satkit::engine::run(&cfg, kind);
    println!("{}", report.row(kind.name()));
    println!("{}", report.to_json().to_string());
    Ok(())
}

fn sweep(args: &Args) -> Result<(), String> {
    let cfg = load_cfg(args)?;
    let opts = sweep_opts(args, &cfg);
    let lambdas: Vec<f64> = match args.get("lambdas") {
        Some(s) => s
            .split(',')
            .map(|t| t.parse::<f64>().map_err(|e| format!("--lambdas: {e}")))
            .collect::<Result<_, _>>()?,
        None => exp::default_lambdas(),
    };
    let rows = exp::lambda_sweep(cfg.model, &lambdas, &opts);
    println!(
        "{}",
        exp::render_panels(
            &format!("lambda sweep ({})", cfg.model.name()),
            &rows,
            "lambda"
        )
    );
    maybe_write_json(args, &rows)
}

fn experiment(args: &Args) -> Result<(), String> {
    let cfg = load_cfg(args)?;
    let opts = sweep_opts(args, &cfg);
    let id = args
        .positionals
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    std::fs::create_dir_all("results").map_err(|e| e.to_string())?;
    // Refuse to clobber an existing results/*.json without --force: sweep
    // outputs are expensive to regenerate, and the guard runs BEFORE the
    // sweep so a refused run costs nothing.
    let force = args.has_flag("force");
    let guard = |path: &str| -> Result<(), String> {
        if !force && std::path::Path::new(path).exists() {
            return Err(format!(
                "refusing to overwrite {path}; pass --force to replace it"
            ));
        }
        Ok(())
    };
    let run_fig =
        |name: &str, make_rows: &dyn Fn() -> Vec<exp::Row>, xn: &str| -> Result<(), String> {
            let path = format!("results/{name}.json");
            guard(&path)?;
            let rows = make_rows();
            println!("{}", exp::render_panels_with_charts(name, &rows, xn));
            std::fs::write(&path, exp::rows_to_json(&rows).to_string())
                .map_err(|e| e.to_string())?;
            println!("wrote {path}\n");
            Ok(())
        };
    match id {
        "fig2" => run_fig("fig2", &|| exp::fig2(&opts), "lambda")?,
        "fig3" => run_fig("fig3", &|| exp::fig3(&opts), "lambda")?,
        "eventsim" => {
            // the λ-sweep on the event-driven engine under cfg.scenario
            // (default model matches fig2's ResNet101; --model overrides);
            // --quick shrinks both the λ grid and the horizon so the CI
            // smoke run finishes in seconds
            let model = if args.get("model").is_some() {
                cfg.model
            } else {
                DnnModel::Resnet101
            };
            let lams = exp::eventsim_lambdas(args.has_flag("quick"));
            run_fig(
                &format!("eventsim-{}-{}", cfg.scenario.name(), model.name()),
                &|| exp::eventsim_sweep(model, &lams, cfg.scenario, &opts),
                "lambda",
            )?
        }
        "staleness" => {
            // completion rate & p95 delay vs the dissemination interval
            // T_d per scheme at high traffic — the §V-B stale-state
            // herding study. Runs on the event engine (which honours
            // sub-slot T_d) unless --engine explicitly says otherwise;
            // --lambda overrides the operating point; --quick trims the
            // T_d grid and horizon.
            let quick = args.has_flag("quick");
            let lambda = args
                .get_parsed::<f64>("lambda")?
                .unwrap_or(exp::STALENESS_LAMBDA);
            let mut opts = opts;
            if args.get("engine").is_none() {
                opts.engine = satkit::config::EngineKind::Event;
            }
            guard("results/staleness.json")?;
            let periods = exp::staleness_periods(quick);
            let rows = exp::staleness_sweep(cfg.model, lambda, &periods, &opts);
            println!(
                "{}",
                exp::render_staleness(
                    &format!(
                        "staleness sweep ({}, {} engine, lambda={lambda})",
                        cfg.model.name(),
                        opts.engine.name()
                    ),
                    &rows
                )
            );
            let json = exp::staleness_json(cfg.model, lambda, opts.engine, quick, &rows);
            let bench_path =
                satkit::bench::out_path("SATKIT_STALENESS_JSON", "BENCH_staleness.json");
            satkit::bench::write_json(&bench_path, &json).map_err(|e| e.to_string())?;
            println!("wrote {bench_path}");
            satkit::bench::write_json("results/staleness.json", &json)
                .map_err(|e| e.to_string())?;
            println!("wrote results/staleness.json\n");
        }
        "decidecache" => {
            // epoch-keyed GA decision cache (--decision-cache) on vs off
            // per periodic T_d: completion/p95 deltas (expected inside
            // the repeat noise band) plus hit rate and decides/s. SCC
            // only, event engine unless --engine explicitly says
            // otherwise; --lambda overrides the operating point; --quick
            // trims the T_d grid and horizon.
            let quick = args.has_flag("quick");
            let lambda = args
                .get_parsed::<f64>("lambda")?
                .unwrap_or(exp::DECIDECACHE_LAMBDA);
            let mut opts = opts;
            if args.get("engine").is_none() {
                opts.engine = satkit::config::EngineKind::Event;
            }
            guard("results/decidecache.json")?;
            let periods = exp::decidecache_periods(quick);
            let rows = exp::decidecache_sweep(cfg.model, lambda, &periods, &opts);
            println!(
                "{}",
                exp::render_decidecache(
                    &format!(
                        "decision-cache sweep ({}, {} engine, SCC, lambda={lambda})",
                        cfg.model.name(),
                        opts.engine.name()
                    ),
                    &rows
                )
            );
            let json = exp::decidecache_json(cfg.model, lambda, opts.engine, quick, &rows);
            let bench_path =
                satkit::bench::out_path("SATKIT_DECIDECACHE_JSON", "BENCH_decidecache.json");
            satkit::bench::write_json(&bench_path, &json).map_err(|e| e.to_string())?;
            println!("wrote {bench_path}");
            satkit::bench::write_json("results/decidecache.json", &json)
                .map_err(|e| e.to_string())?;
            println!("wrote results/decidecache.json\n");
        }
        "topology" => {
            // completion rate & p95 delay per scheme per constellation
            // topology (torus vs walker-delta vs walker-star at equal
            // satellite count). Runs on the event engine unless --engine
            // explicitly says otherwise; --lambda overrides the operating
            // point; --quick trims the horizon.
            let quick = args.has_flag("quick");
            let lambda = args
                .get_parsed::<f64>("lambda")?
                .unwrap_or(exp::TOPOLOGY_LAMBDA);
            let mut opts = opts;
            if args.get("engine").is_none() {
                opts.engine = satkit::config::EngineKind::Event;
            }
            // per-cell topologies replace any --topology override
            opts.topology = None;
            guard("results/topology.json")?;
            let kinds = exp::topology_grid(cfg.n);
            let rows = exp::topology_sweep(cfg.model, lambda, &kinds, &opts);
            println!(
                "{}",
                exp::render_topology(
                    &format!(
                        "topology sweep ({}, {} engine, lambda={lambda})",
                        cfg.model.name(),
                        opts.engine.name()
                    ),
                    &rows
                )
            );
            let json = exp::topology_json(cfg.model, lambda, opts.engine, quick, &rows);
            let bench_path =
                satkit::bench::out_path("SATKIT_TOPOLOGY_JSON", "BENCH_topology.json");
            satkit::bench::write_json(&bench_path, &json).map_err(|e| e.to_string())?;
            println!("wrote {bench_path}");
            satkit::bench::write_json("results/topology.json", &json)
                .map_err(|e| e.to_string())?;
            println!("wrote results/topology.json\n");
        }
        "llm" => {
            // round-level delay metrics per scheme per autoregressive
            // (LLM-style decode) workload variant — the adaptive
            // task-kind study. Runs on the event engine unless --engine
            // explicitly says otherwise; --lambda overrides the
            // operating point; --quick trims the round grid and horizon.
            let quick = args.has_flag("quick");
            let lambda = args
                .get_parsed::<f64>("lambda")?
                .unwrap_or(exp::LLM_LAMBDA);
            let mut opts = opts;
            if args.get("engine").is_none() {
                opts.engine = satkit::config::EngineKind::Event;
            }
            guard("results/llm.json")?;
            let rounds = exp::llm_rounds(quick);
            let kinds = exp::llm_kind_grid(&rounds);
            let rows = exp::llm_sweep(cfg.model, lambda, &kinds, &opts);
            println!(
                "{}",
                exp::render_llm(
                    &format!(
                        "llm workload sweep ({}, {} engine, lambda={lambda})",
                        cfg.model.name(),
                        opts.engine.name()
                    ),
                    &rows
                )
            );
            let json = exp::llm_json(cfg.model, lambda, opts.engine, quick, &rows);
            let bench_path = satkit::bench::out_path("SATKIT_LLM_JSON", "BENCH_llm.json");
            satkit::bench::write_json(&bench_path, &json).map_err(|e| e.to_string())?;
            println!("wrote {bench_path}");
            satkit::bench::write_json("results/llm.json", &json)
                .map_err(|e| e.to_string())?;
            println!("wrote results/llm.json\n");
        }
        "resilience" => {
            // completion rate & p95 delay vs satellite fault rate,
            // recovery off (drop) vs on (reoffload:2) per scheme — the
            // failure-recovery study. Runs on the event engine (whose
            // mid-chain faults make recovery bite) unless --engine
            // explicitly says otherwise; --lambda overrides the
            // operating point; --quick trims the rate grid and horizon.
            let quick = args.has_flag("quick");
            let lambda = args
                .get_parsed::<f64>("lambda")?
                .unwrap_or(exp::RESILIENCE_LAMBDA);
            let mut opts = opts;
            if args.get("engine").is_none() {
                opts.engine = satkit::config::EngineKind::Event;
            }
            guard("results/resilience.json")?;
            let rates = exp::resilience_rates(quick);
            let rows = exp::resilience_sweep(cfg.model, lambda, &rates, &opts);
            println!(
                "{}",
                exp::render_resilience(
                    &format!(
                        "resilience sweep ({}, {} engine, lambda={lambda})",
                        cfg.model.name(),
                        opts.engine.name()
                    ),
                    &rows
                )
            );
            let json = exp::resilience_json(cfg.model, lambda, opts.engine, quick, &rows);
            let bench_path =
                satkit::bench::out_path("SATKIT_RESILIENCE_JSON", "BENCH_resilience.json");
            satkit::bench::write_json(&bench_path, &json).map_err(|e| e.to_string())?;
            println!("wrote {bench_path}");
            satkit::bench::write_json("results/resilience.json", &json)
                .map_err(|e| e.to_string())?;
            println!("wrote results/resilience.json\n");
        }
        "scale" => run_fig("scale", &|| exp::scale(&exp::default_ns(), &opts), "N")?,
        "ablation-split" => {
            let rows = exp::ablation_split(cfg.model, &exp::default_lambdas(), &opts);
            println!("== ablation: Alg.1 balanced vs naive equal-layer split ({}) ==", cfg.model.name());
            println!("{:>8} {:>16} {:>16} {:>14} {:>14}", "lambda", "bal complete", "naive complete", "bal delay", "naive delay");
            for (l, b, n) in &rows {
                println!(
                    "{l:>8.0} {:>15.2}% {:>15.2}% {:>12.1}ms {:>12.1}ms",
                    100.0 * b.completion_rate(),
                    100.0 * n.completion_rate(),
                    b.avg_delay_ms,
                    n.avg_delay_ms
                );
            }
        }
        "ablation-ga" => {
            let iters = [1usize, 2, 5, 10, 20, 40];
            let rows = exp::ablation_ga(&iters, &opts);
            println!("== ablation: GA iteration budget (VGG19, lambda=40) ==");
            println!("{:>8} {:>14} {:>14} {:>16}", "N_iter", "complete", "delay", "variance");
            for (it, r) in &rows {
                println!(
                    "{it:>8} {:>13.2}% {:>12.1}ms {:>16.3e}",
                    100.0 * r.completion_rate(),
                    r.avg_delay_ms,
                    r.workload_variance
                );
            }
        }
        "all" => {
            run_fig("fig2", &|| exp::fig2(&opts), "lambda")?;
            run_fig("fig3", &|| exp::fig3(&opts), "lambda")?;
            run_fig("scale", &|| exp::scale(&exp::default_ns(), &opts), "N")?;
        }
        other => return Err(format!("unknown experiment '{other}'")),
    }
    Ok(())
}

fn serve(args: &Args) -> anyhow::Result<()> {
    let cfg = load_cfg(args).map_err(anyhow::Error::msg)?;
    let kind = SchemeKind::parse(args.get("scheme").unwrap_or("scc"))
        .map_err(anyhow::Error::msg)?;
    let n_req: usize = args.get_or("requests", 24);
    let workers: usize = args.get_or(
        "workers",
        std::thread::available_parallelism().map(|p| p.get().min(4)).unwrap_or(2),
    );
    let dir = default_artifact_dir();
    println!(
        "starting coordinator: {} sats, scheme={}, {} exec workers, artifacts={}",
        cfg.effective_topology().n_sats(),
        kind.name(),
        workers,
        dir.display()
    );
    let mut coord = Coordinator::new(&cfg, &dir, workers, kind)?;
    println!("artifacts loaded: {:?}", coord.artifact_names());

    let mut rng = satkit::util::rng::Pcg64::new(cfg.seed, 0x53E5);
    let origins = satkit::tasks::decision_satellites(
        cfg.effective_topology().n_sats(),
        cfg.decision_fraction,
        cfg.seed,
    );
    let reqs: Vec<InferenceRequest> = (0..n_req)
        .map(|i| InferenceRequest {
            id: i as u64,
            origin: *rng.choose(&origins),
            model: cfg.model,
        })
        .collect();

    let t0 = std::time::Instant::now();
    let mut walls = Vec::new();
    let mut modeled = Vec::new();
    let mut dropped = 0usize;
    for (i, r) in reqs.iter().enumerate() {
        let resp = coord.serve(r)?;
        if resp.dropped_at.is_some() {
            dropped += 1;
        } else {
            walls.push(resp.wall_ms);
            modeled.push(resp.modeled_ms);
        }
        if (i + 1) % 8 == 0 {
            coord.tick();
        }
    }
    let total_s = t0.elapsed().as_secs_f64();
    println!(
        "served {}/{} requests in {:.2}s  ({:.1} req/s)",
        n_req - dropped,
        n_req,
        total_s,
        n_req as f64 / total_s
    );
    println!(
        "real exec latency  p50={:.1}ms p95={:.1}ms mean={:.1}ms",
        stats::percentile(&walls, 50.0),
        stats::percentile(&walls, 95.0),
        stats::mean(&walls)
    );
    println!(
        "modeled delay      p50={:.1}ms p95={:.1}ms mean={:.1}ms",
        stats::percentile(&modeled, 50.0),
        stats::percentile(&modeled, 95.0),
        stats::mean(&modeled)
    );
    println!(
        "segments executed on PJRT: {}",
        coord.stats.segments_executed.load(std::sync::atomic::Ordering::Relaxed)
    );
    Ok(())
}

fn validate_artifacts() -> anyhow::Result<()> {
    let dir = default_artifact_dir();
    let mut engine = Engine::cpu()?;
    let names = engine.load_dir(&dir)?;
    println!("platform: {}", engine.platform());
    for name in &names {
        let art = engine.get(name)?;
        let inputs: Vec<Vec<f32>> = art
            .meta
            .inputs
            .iter()
            .map(|spec| (0..spec.num_elements()).map(|i| (i % 13) as f32 * 0.1).collect())
            .collect();
        let out = art.run_f32(&inputs)?;
        let sums: Vec<f64> = out
            .iter()
            .map(|o| o.iter().map(|x| *x as f64).sum())
            .collect();
        println!(
            "{name:<16} inputs={:?} outputs={:?} checksum={sums:?}",
            art.meta.inputs.iter().map(|s| s.shape.clone()).collect::<Vec<_>>(),
            art.meta.outputs.iter().map(|s| s.shape.clone()).collect::<Vec<_>>(),
        );
    }
    println!("all {} artifacts OK", names.len());
    Ok(())
}
