//! Constellation topology substrate (§III-A, §V-A).
//!
//! The paper's network is an N×N Walker-style grid: N orbits, N satellites
//! per orbit, evenly spaced. "The neighbors of each satellite are the
//! adjacent four satellites that can directly communicate" — i.e. a 2-D
//! torus (both the in-orbit ring and the inter-plane ring wrap).
//! Distances are Manhattan hop counts on that torus (Eq. 7, 11c).
//!
//! Real LEO systems are Walker constellations with a polar seam and
//! phasing offsets, so the torus is only one [`Constellation`] among
//! three:
//!
//! * [`Constellation::Torus`] — the paper default: closed-form Manhattan
//!   hop arithmetic on the N×N double ring ([`Torus`], re-homed here).
//! * `walker-delta:<p>x<s>[:f]` — P planes × S satellites per plane;
//!   inter-plane links wrap (plane P−1 ↔ plane 0) with a phasing slot
//!   offset F applied across the wrap.
//! * `walker-star:<p>x<s>` — the counter-rotating seam: **no** inter-plane
//!   links between plane P−1 and plane 0, so hop distances are no longer
//!   closed-form Manhattan arithmetic.
//!
//! Walker hop distances come from an all-pairs BFS LUT computed once at
//! construction ([`Walker`]); every consumer — the offloading schemes, the
//! [`crate::offload::DecisionSpaceIndex`] fast path, gossip hop-lag,
//! eventsim routing and handover — goes through [`Constellation`], so the
//! geometry is swappable from config (`--topology`, [`TopologyKind`]).

use std::collections::VecDeque;

/// Satellite identifier: a flat index into the constellation
/// (`plane * sats_per_plane + slot`).
pub type SatId = usize;

/// An N×N toroidal constellation grid.
#[derive(Clone, Debug)]
pub struct Torus {
    n: usize,
}

impl Torus {
    /// Create an N-orbit × N-satellites-per-orbit grid. Panics if `n < 2`.
    pub fn new(n: usize) -> Torus {
        assert!(n >= 2, "constellation needs n >= 2 (got {n})");
        Torus { n }
    }

    /// Grid edge length N.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Total satellites N².
    pub fn len(&self) -> usize {
        self.n * self.n
    }

    /// `len`/`is_empty` contract companion: construction enforces
    /// `n >= 2`, so a live `Torus` is never empty.
    pub fn is_empty(&self) -> bool {
        false
    }

    /// (orbit, index-in-orbit) of a satellite.
    #[inline]
    pub fn coords(&self, s: SatId) -> (usize, usize) {
        debug_assert!(s < self.len());
        (s / self.n, s % self.n)
    }

    /// Flat id from (orbit, index-in-orbit), with wraparound.
    #[inline]
    pub fn id(&self, orbit: isize, idx: isize) -> SatId {
        let n = self.n as isize;
        let o = orbit.rem_euclid(n) as usize;
        let i = idx.rem_euclid(n) as usize;
        o * self.n + i
    }

    /// Ring distance along one axis of the torus.
    #[inline]
    fn ring_dist(&self, a: usize, b: usize) -> usize {
        let d = a.abs_diff(b);
        d.min(self.n - d)
    }

    /// Manhattan hop distance `MH(i, j)` on the torus (Eq. 7).
    #[inline]
    pub fn manhattan(&self, a: SatId, b: SatId) -> usize {
        let (ao, ai) = self.coords(a);
        let (bo, bi) = self.coords(b);
        self.ring_dist(ao, bo) + self.ring_dist(ai, bi)
    }

    /// The four ISL neighbours (up/down in orbit, left/right across planes).
    pub fn neighbors(&self, s: SatId) -> [SatId; 4] {
        let (o, i) = self.coords(s);
        let (o, i) = (o as isize, i as isize);
        [
            self.id(o - 1, i),
            self.id(o + 1, i),
            self.id(o, i - 1),
            self.id(o, i + 1),
        ]
    }

    /// Decision space `A_x` (constraint 11c): all satellites within
    /// Manhattan distance `d_max` of `x`, **including** `x` itself
    /// (a decision satellite may keep a segment local).
    pub fn decision_space(&self, x: SatId, d_max: usize) -> Vec<SatId> {
        let mut out = Vec::new();
        let (xo, xi) = self.coords(x);
        let (xo, xi) = (xo as isize, xi as isize);
        let d = d_max as isize;
        for dor in -d..=d {
            let rem = d - dor.abs();
            for din in -rem..=rem {
                let id = self.id(xo + dor, xi + din);
                if !out.contains(&id) {
                    out.push(id);
                }
            }
        }
        out.sort_unstable();
        out
    }

    /// Number of distinct satellites within distance `d_max` on an infinite
    /// grid: `2d² + 2d + 1` (the torus may have fewer when N is small).
    pub fn ball_size_upper(d_max: usize) -> usize {
        2 * d_max * d_max + 2 * d_max + 1
    }

    /// Fill `out` with the row-major `ids.len() × ids.len()` Manhattan-hop
    /// LUT for an arbitrary satellite subset: `out[i·len + j] =
    /// MH(ids[i], ids[j])`. The offloading kernel precomputes this once per
    /// decision so the Eq. 12 hot loop never re-derives torus coordinates
    /// (hops fit `u16`: the torus diameter is `N ≤ 65535`).
    pub fn hops_lut(&self, ids: &[SatId], out: &mut Vec<u16>) {
        out.clear();
        out.reserve(ids.len() * ids.len());
        // derive each id's (orbit, index) once — the pairwise loop below is
        // O(|A_x|²) and runs once per offloading decision. A stack buffer
        // covers every realistic decision space (|A_x| = 2d²+2d+1 ≤ 61 for
        // d_max ≤ 5); larger subsets fall back to the heap.
        let mut stack = [(0usize, 0usize); 64];
        let heap: Vec<(usize, usize)>;
        let coords: &[(usize, usize)] = if ids.len() <= stack.len() {
            for (slot, &s) in stack.iter_mut().zip(ids) {
                *slot = self.coords(s);
            }
            &stack[..ids.len()]
        } else {
            heap = ids.iter().map(|&s| self.coords(s)).collect();
            &heap
        };
        for &(ao, ai) in coords {
            for &(bo, bi) in coords {
                out.push((self.ring_dist(ao, bo) + self.ring_dist(ai, bi)) as u16);
            }
        }
    }

    /// One shortest path from `a` to `b` (orbit axis first, then in-orbit),
    /// as the sequence of intermediate hops — used by the coordinator to
    /// route intermediate activations over ISLs.
    pub fn shortest_path(&self, a: SatId, b: SatId) -> Vec<SatId> {
        let mut path = Vec::with_capacity(self.manhattan(a, b));
        let (mut o, mut i) = self.coords(a);
        let (bo, bi) = self.coords(b);
        let n = self.n;
        let step_towards = |from: usize, to: usize| -> isize {
            if from == to {
                return 0;
            }
            let fwd = (to + n - from) % n; // steps going +1
            let bwd = (from + n - to) % n; // steps going -1
            if fwd <= bwd {
                1
            } else {
                -1
            }
        };
        while o != bo {
            o = (o as isize + step_towards(o, bo)).rem_euclid(n as isize) as usize;
            path.push(o * n + i);
        }
        while i != bi {
            i = (i as isize + step_towards(i, bi)).rem_euclid(n as isize) as usize;
            path.push(o * n + i);
        }
        path
    }
}

/// Inter-plane link pattern of a Walker constellation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WalkerKind {
    /// Walker-Delta: the inter-plane ring wraps (plane P−1 ↔ plane 0),
    /// with the phasing slot offset F applied across the wrap.
    Delta,
    /// Walker-Star: counter-rotating seam — no inter-plane links between
    /// plane P−1 and plane 0.
    Star,
}

/// A Walker constellation: `planes` orbital planes × `sats_per_plane`
/// evenly spaced satellites, in-plane rings always closed, inter-plane
/// links per [`WalkerKind`]. Hop distances are a precomputed all-pairs
/// BFS LUT (the seam breaks the closed-form Manhattan arithmetic), built
/// once at construction and cached for the lifetime of the topology.
#[derive(Clone, Debug)]
pub struct Walker {
    kind: WalkerKind,
    planes: usize,
    sats_per_plane: usize,
    /// F — slot offset applied when an inter-plane link crosses the
    /// plane wrap (Delta only; 0 for Star).
    phasing: usize,
    /// Row-major all-pairs shortest-path hop LUT: `lut[a·n + b]`.
    lut: Vec<u16>,
}

impl Walker {
    /// Build a Walker-Delta constellation. Panics unless `planes >= 2`,
    /// `sats_per_plane >= 2`, and `phasing < sats_per_plane`.
    pub fn delta(planes: usize, sats_per_plane: usize, phasing: usize) -> Walker {
        Walker::build(WalkerKind::Delta, planes, sats_per_plane, phasing)
    }

    /// Build a Walker-Star constellation (seam between plane P−1 and 0).
    pub fn star(planes: usize, sats_per_plane: usize) -> Walker {
        Walker::build(WalkerKind::Star, planes, sats_per_plane, 0)
    }

    fn build(kind: WalkerKind, planes: usize, sats_per_plane: usize, phasing: usize) -> Walker {
        assert!(
            planes >= 2 && sats_per_plane >= 2,
            "walker needs >= 2 planes and >= 2 sats per plane (got {planes}x{sats_per_plane})"
        );
        assert!(
            phasing < sats_per_plane,
            "phasing {phasing} must be < sats_per_plane {sats_per_plane}"
        );
        let mut w = Walker {
            kind,
            planes,
            sats_per_plane,
            phasing,
            lut: Vec::new(),
        };
        w.lut = w.apsp();
        w
    }

    /// The inter-plane link pattern.
    pub fn kind(&self) -> WalkerKind {
        self.kind
    }

    /// Number of orbital planes P.
    pub fn planes(&self) -> usize {
        self.planes
    }

    /// Satellites per plane S.
    pub fn sats_per_plane(&self) -> usize {
        self.sats_per_plane
    }

    /// Phasing slot offset F (0 for Star).
    pub fn phasing(&self) -> usize {
        self.phasing
    }

    /// Total satellites P·S.
    pub fn len(&self) -> usize {
        self.planes * self.sats_per_plane
    }

    /// Construction enforces `planes, sats_per_plane >= 2`: never empty.
    pub fn is_empty(&self) -> bool {
        false
    }

    /// (plane, slot-in-plane) of a satellite.
    #[inline]
    pub fn coords(&self, s: SatId) -> (usize, usize) {
        debug_assert!(s < self.len());
        (s / self.sats_per_plane, s % self.sats_per_plane)
    }

    #[inline]
    fn id(&self, plane: usize, slot: usize) -> SatId {
        plane * self.sats_per_plane + slot
    }

    /// The inter-plane neighbour of `(p, i)` in direction `dir` (±1), or
    /// `None` at the Walker-Star seam. Crossing the Delta plane wrap
    /// applies the phasing offset (+F going up past P−1, −F going down
    /// past 0), keeping the link relation symmetric.
    fn plane_neighbor(&self, p: usize, i: usize, dir: isize) -> Option<SatId> {
        let planes = self.planes as isize;
        let tp = p as isize + dir;
        if (0..planes).contains(&tp) {
            return Some(self.id(tp as usize, i));
        }
        match self.kind {
            WalkerKind::Star => None,
            WalkerKind::Delta => {
                let wp = tp.rem_euclid(planes) as usize;
                let s = self.sats_per_plane as isize;
                let di = if dir > 0 {
                    self.phasing as isize
                } else {
                    -(self.phasing as isize)
                };
                let wi = (i as isize + di).rem_euclid(s) as usize;
                Some(self.id(wp, wi))
            }
        }
    }

    /// ISL neighbours of `s`, in the torus ordering (plane −1, plane +1,
    /// slot −1, slot +1); seam satellites of a Star have degree 3.
    pub fn neighbors(&self, s: SatId) -> Vec<SatId> {
        let (p, i) = self.coords(s);
        let mut out = Vec::with_capacity(4);
        if let Some(nb) = self.plane_neighbor(p, i, -1) {
            out.push(nb);
        }
        if let Some(nb) = self.plane_neighbor(p, i, 1) {
            out.push(nb);
        }
        let sp = self.sats_per_plane;
        out.push(self.id(p, (i + sp - 1) % sp));
        out.push(self.id(p, (i + 1) % sp));
        out
    }

    /// ISL hop distance from the precomputed BFS LUT.
    #[inline]
    pub fn hops(&self, a: SatId, b: SatId) -> usize {
        self.lut[a * self.len() + b] as usize
    }

    /// All-pairs shortest-path hop counts via one BFS per satellite. Runs
    /// once per constellation construction; O(n²) memory as `u16`.
    fn apsp(&self) -> Vec<u16> {
        let n = self.len();
        let mut lut = vec![0u16; n * n];
        let mut dist = vec![u32::MAX; n];
        let mut queue = VecDeque::new();
        for src in 0..n {
            dist.fill(u32::MAX);
            dist[src] = 0;
            queue.clear();
            queue.push_back(src);
            while let Some(u) = queue.pop_front() {
                let du = dist[u];
                for nb in self.neighbors(u) {
                    if dist[nb] == u32::MAX {
                        dist[nb] = du + 1;
                        queue.push_back(nb);
                    }
                }
            }
            for (t, &d) in dist.iter().enumerate() {
                assert!(d != u32::MAX, "walker topology disconnected at {src}->{t}");
                assert!(d <= u16::MAX as u32, "walker diameter exceeds u16");
                lut[src * n + t] = d as u16;
            }
        }
        lut
    }
}

/// A pluggable constellation topology: satellite count, plane coords, ISL
/// neighbours, hop distances, and the batched hop LUT the decision kernel
/// indexes. [`Constellation::Torus`] delegates to the paper's closed-form
/// [`Torus`] arithmetic (so the default path is bit-for-bit the legacy
/// one, enforced by `tests/prop_topology.rs`); [`Constellation::Walker`]
/// answers from the per-topology BFS LUT.
#[derive(Clone, Debug)]
pub enum Constellation {
    /// The paper's N×N torus (closed-form Manhattan hops).
    Torus(Torus),
    /// Walker-Delta / Walker-Star with a precomputed BFS hop LUT.
    Walker(Walker),
}

impl Constellation {
    /// The paper-default N×N torus.
    pub fn torus(n: usize) -> Constellation {
        Constellation::Torus(Torus::new(n))
    }

    /// A Walker-Delta constellation (wrapping inter-plane ring, phasing F).
    pub fn walker_delta(planes: usize, sats_per_plane: usize, phasing: usize) -> Constellation {
        Constellation::Walker(Walker::delta(planes, sats_per_plane, phasing))
    }

    /// A Walker-Star constellation (polar seam, no cross-seam links).
    pub fn walker_star(planes: usize, sats_per_plane: usize) -> Constellation {
        Constellation::Walker(Walker::star(planes, sats_per_plane))
    }

    /// Total satellites.
    pub fn len(&self) -> usize {
        match self {
            Constellation::Torus(t) => t.len(),
            Constellation::Walker(w) => w.len(),
        }
    }

    /// Construction enforces a non-degenerate grid: never empty.
    pub fn is_empty(&self) -> bool {
        false
    }

    /// (plane, slot-in-plane) of a satellite.
    #[inline]
    pub fn coords(&self, s: SatId) -> (usize, usize) {
        match self {
            Constellation::Torus(t) => t.coords(s),
            Constellation::Walker(w) => w.coords(s),
        }
    }

    /// Number of orbital planes — N on the torus, P on a Walker. The
    /// event engine's `shards = 0` (auto) mode shards its pending-event
    /// queue one-per-plane using this.
    #[inline]
    pub fn planes(&self) -> usize {
        match self {
            Constellation::Torus(t) => t.n(),
            Constellation::Walker(w) => w.planes(),
        }
    }

    /// ISL hop distance between two satellites — Manhattan `MH(i, j)` on
    /// the torus (Eq. 7), BFS shortest-path hops on a Walker.
    #[inline]
    pub fn hops(&self, a: SatId, b: SatId) -> usize {
        match self {
            Constellation::Torus(t) => t.manhattan(a, b),
            Constellation::Walker(w) => w.hops(a, b),
        }
    }

    /// ISL neighbours of `s` (4 on the torus and Walker-Delta interior;
    /// 3 at a Walker-Star seam plane).
    pub fn neighbors(&self, s: SatId) -> Vec<SatId> {
        match self {
            Constellation::Torus(t) => t.neighbors(s).to_vec(),
            Constellation::Walker(w) => w.neighbors(s),
        }
    }

    /// Fixed-arity neighbour view for the DQN's 5-action grid walk: the
    /// (up to) 4 ISL neighbours, padded with `s` itself where a link is
    /// missing (a padded slot behaves exactly like the "stay" action).
    /// Identical to [`Torus::neighbors`] on the torus.
    pub fn neighbors4(&self, s: SatId) -> [SatId; 4] {
        match self {
            Constellation::Torus(t) => t.neighbors(s),
            Constellation::Walker(w) => {
                let mut out = [s; 4];
                for (slot, nb) in w.neighbors(s).into_iter().enumerate() {
                    out[slot] = nb;
                }
                out
            }
        }
    }

    /// The undirected ISL edge set, as `(min, max)` pairs sorted
    /// ascending — the link universe the resilience layer's
    /// `LinkFaultInjector` draws outages over.
    pub fn edges(&self) -> Vec<(SatId, SatId)> {
        let mut out = Vec::new();
        for s in 0..self.len() {
            for nb in self.neighbors(s) {
                out.push((s.min(nb), s.max(nb)));
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Decision space `A_x` (constraint 11c): all satellites within hop
    /// distance `d_max` of `x`, including `x`, sorted ascending.
    pub fn decision_space(&self, x: SatId, d_max: usize) -> Vec<SatId> {
        match self {
            Constellation::Torus(t) => t.decision_space(x, d_max),
            Constellation::Walker(w) => {
                (0..w.len()).filter(|&s| w.hops(x, s) <= d_max).collect()
            }
        }
    }

    /// Fill `out` with the row-major `ids.len() × ids.len()` hop LUT for
    /// an arbitrary satellite subset (see [`Torus::hops_lut`]); the
    /// Walker path copies straight out of the cached APSP table, so both
    /// stay allocation-free per decision beyond the reused `out` buffer.
    pub fn hops_lut(&self, ids: &[SatId], out: &mut Vec<u16>) {
        match self {
            Constellation::Torus(t) => t.hops_lut(ids, out),
            Constellation::Walker(w) => {
                out.clear();
                out.reserve(ids.len() * ids.len());
                let n = w.len();
                for &a in ids {
                    let row = &w.lut[a * n..(a + 1) * n];
                    for &b in ids {
                        out.push(row[b]);
                    }
                }
            }
        }
    }

    /// One shortest path from `a` to `b` as the sequence of intermediate
    /// hops (torus: orbit axis first; Walker: greedy LUT descent, lowest
    /// neighbour id first — deterministic).
    pub fn shortest_path(&self, a: SatId, b: SatId) -> Vec<SatId> {
        match self {
            Constellation::Torus(t) => t.shortest_path(a, b),
            Constellation::Walker(w) => {
                let mut path = Vec::with_capacity(w.hops(a, b));
                let mut cur = a;
                while cur != b {
                    let d = w.hops(cur, b);
                    let next = w
                        .neighbors(cur)
                        .into_iter()
                        .filter(|&nb| w.hops(nb, b) + 1 == d)
                        .min()
                        .expect("hop LUT inconsistent with adjacency");
                    path.push(next);
                    cur = next;
                }
                path
            }
        }
    }

    /// The satellite `steps` slots further along `s`'s own orbital plane
    /// (negative steps go backwards; wraps within the plane). This is the
    /// handover motion: the gateway link advances along the actual orbit,
    /// never across planes. On the torus this is the in-orbit ring step
    /// the legacy handover used.
    pub fn advance_in_plane(&self, s: SatId, steps: isize) -> SatId {
        match self {
            Constellation::Torus(t) => {
                let (o, i) = t.coords(s);
                t.id(o as isize, i as isize + steps)
            }
            Constellation::Walker(w) => {
                let (p, i) = w.coords(s);
                let sp = w.sats_per_plane as isize;
                let idx = (i as isize + steps).rem_euclid(sp) as usize;
                w.id(p, idx)
            }
        }
    }
}

/// Declarative topology selector (config/CLI surface): which
/// [`Constellation`] a run builds. Parsed from
/// `torus:<n> | walker-delta:<p>x<s>[:f] | walker-star:<p>x<s>`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TopologyKind {
    /// The paper's N×N torus.
    Torus {
        /// Grid edge N.
        n: usize,
    },
    /// Walker-Delta: wrapping inter-plane ring with phasing offset F.
    WalkerDelta {
        planes: usize,
        sats_per_plane: usize,
        phasing: usize,
    },
    /// Walker-Star: polar seam, no cross-seam inter-plane links.
    WalkerStar {
        planes: usize,
        sats_per_plane: usize,
    },
}

impl TopologyKind {
    /// Parse `torus:<n> | walker-delta:<p>x<s>[:f] | walker-star:<p>x<s>`
    /// (the `--topology` CLI / TOML syntax), validating ranges.
    pub fn parse(s: &str) -> Result<TopologyKind, String> {
        let low = s.to_ascii_lowercase();
        let (head, arg) = match low.split_once(':') {
            Some((h, a)) => (h, a),
            None => {
                return Err(format!(
                    "topology '{low}' needs a size \
                     (torus:<n>|walker-delta:<p>x<s>[:f]|walker-star:<p>x<s>)"
                ))
            }
        };
        let parse_usize = |a: &str, what: &str| -> Result<usize, String> {
            a.parse::<usize>().map_err(|e| format!("topology {what} '{a}': {e}"))
        };
        let parse_pxs = |a: &str| -> Result<(usize, usize), String> {
            let (p, sp) = a
                .split_once('x')
                .ok_or_else(|| format!("expected <planes>x<sats>, got '{a}'"))?;
            Ok((parse_usize(p, "planes")?, parse_usize(sp, "sats-per-plane")?))
        };
        let kind = match head {
            "torus" | "grid" => TopologyKind::Torus {
                n: parse_usize(arg, "size")?,
            },
            "walker-delta" | "delta" => {
                let (geom, f) = match arg.split_once(':') {
                    Some((g, f)) => (g, parse_usize(f, "phasing")?),
                    None => (arg, 0),
                };
                let (planes, sats_per_plane) = parse_pxs(geom)?;
                TopologyKind::WalkerDelta {
                    planes,
                    sats_per_plane,
                    phasing: f,
                }
            }
            "walker-star" | "star" => {
                let (planes, sats_per_plane) = parse_pxs(arg)?;
                TopologyKind::WalkerStar {
                    planes,
                    sats_per_plane,
                }
            }
            other => {
                return Err(format!(
                    "unknown topology '{other}' \
                     (torus:<n>|walker-delta:<p>x<s>[:f]|walker-star:<p>x<s>)"
                ))
            }
        };
        kind.validate()?;
        Ok(kind)
    }

    /// Canonical label, accepted back by [`TopologyKind::parse`].
    pub fn label(&self) -> String {
        match self {
            TopologyKind::Torus { n } => format!("torus:{n}"),
            TopologyKind::WalkerDelta { planes, sats_per_plane, phasing } => {
                format!("walker-delta:{planes}x{sats_per_plane}:{phasing}")
            }
            TopologyKind::WalkerStar { planes, sats_per_plane } => {
                format!("walker-star:{planes}x{sats_per_plane}")
            }
        }
    }

    /// Total satellites without building the topology.
    pub fn n_sats(&self) -> usize {
        match self {
            TopologyKind::Torus { n } => n * n,
            TopologyKind::WalkerDelta { planes, sats_per_plane, .. } => planes * sats_per_plane,
            TopologyKind::WalkerStar { planes, sats_per_plane } => planes * sats_per_plane,
        }
    }

    /// Range-check the geometry parameters.
    pub fn validate(&self) -> Result<(), String> {
        let (planes, sats_per_plane, phasing) = match self {
            TopologyKind::Torus { n } => {
                if *n < 2 {
                    return Err(format!("torus size {n} must be >= 2"));
                }
                return Ok(());
            }
            TopologyKind::WalkerDelta { planes, sats_per_plane, phasing } => {
                (*planes, *sats_per_plane, *phasing)
            }
            TopologyKind::WalkerStar { planes, sats_per_plane } => (*planes, *sats_per_plane, 0),
        };
        if planes < 2 || sats_per_plane < 2 {
            return Err(format!(
                "walker needs >= 2 planes and >= 2 sats per plane \
                 (got {planes}x{sats_per_plane})"
            ));
        }
        if phasing >= sats_per_plane {
            return Err(format!(
                "phasing {phasing} must be < sats per plane {sats_per_plane}"
            ));
        }
        Ok(())
    }

    /// Build the constellation this selector describes (Walker kinds pay
    /// the one-time BFS APSP here).
    pub fn build(&self) -> Constellation {
        match self {
            TopologyKind::Torus { n } => Constellation::torus(*n),
            TopologyKind::WalkerDelta { planes, sats_per_plane, phasing } => {
                Constellation::walker_delta(*planes, *sats_per_plane, *phasing)
            }
            TopologyKind::WalkerStar { planes, sats_per_plane } => {
                Constellation::walker_star(*planes, *sats_per_plane)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coords_roundtrip() {
        let t = Torus::new(7);
        for s in 0..t.len() {
            let (o, i) = t.coords(s);
            assert_eq!(t.id(o as isize, i as isize), s);
        }
    }

    #[test]
    fn manhattan_symmetric_and_triangle() {
        let t = Torus::new(6);
        for a in 0..t.len() {
            for b in 0..t.len() {
                assert_eq!(t.manhattan(a, b), t.manhattan(b, a));
                assert_eq!(t.manhattan(a, b) == 0, a == b);
                for c in [0, 7, 20] {
                    assert!(t.manhattan(a, b) <= t.manhattan(a, c) + t.manhattan(c, b));
                }
            }
        }
    }

    #[test]
    fn torus_wraps() {
        let t = Torus::new(10);
        // (0,0) and (9,0) are adjacent across the seam
        assert_eq!(t.manhattan(t.id(0, 0), t.id(9, 0)), 1);
        assert_eq!(t.manhattan(t.id(0, 0), t.id(5, 5)), 10);
        assert_eq!(t.manhattan(t.id(0, 1), t.id(0, 9)), 2);
    }

    #[test]
    fn four_distinct_neighbors_at_distance_one() {
        let t = Torus::new(5);
        for s in 0..t.len() {
            let nb = t.neighbors(s);
            for x in nb {
                assert_eq!(t.manhattan(s, x), 1);
            }
            let mut u = nb.to_vec();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), 4, "sat {s} has dup neighbors {nb:?}");
        }
    }

    #[test]
    fn decision_space_ball() {
        let t = Torus::new(10);
        let ds = t.decision_space(0, 2);
        assert_eq!(ds.len(), Torus::ball_size_upper(2)); // 13 on a big torus
        assert!(ds.contains(&0));
        for &s in &ds {
            assert!(t.manhattan(0, s) <= 2);
        }
        // everything not in the ball is farther than 2
        for s in 0..t.len() {
            if !ds.contains(&s) {
                assert!(t.manhattan(0, s) > 2);
            }
        }
    }

    #[test]
    fn decision_space_small_torus_dedups() {
        let t = Torus::new(4);
        let ds = t.decision_space(5, 3);
        // ball of radius 3 covers nearly the whole 16-sat torus, without dups
        let mut u = ds.clone();
        u.dedup();
        assert_eq!(u, ds);
        assert!(ds.len() <= t.len());
    }

    #[test]
    fn edges_sorted_unique_and_sized() {
        // Torus: 4-regular, so |E| = 4N²/2 = 2N².
        let t = Constellation::torus(4);
        let e = t.edges();
        assert_eq!(e.len(), 2 * 16);
        let mut sorted = e.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted, e);
        for &(a, b) in &e {
            assert!(a < b);
            assert!(t.neighbors(a).contains(&b));
        }
        // Walker-Star 4x4: in-plane ring 4·4 edges + 3 inter-plane seams · 4.
        let w = Constellation::walker_star(4, 4);
        assert_eq!(w.edges().len(), 16 + 12);
    }

    #[test]
    fn shortest_path_length_matches_manhattan() {
        let t = Torus::new(8);
        for (a, b) in [(0, 0), (0, 63), (3, 42), (10, 17), (7, 56)] {
            let p = t.shortest_path(a, b);
            assert_eq!(p.len(), t.manhattan(a, b), "path {a}->{b}: {p:?}");
            // consecutive hops are ISL neighbours
            let mut prev = a;
            for &h in &p {
                assert_eq!(t.manhattan(prev, h), 1);
                prev = h;
            }
            if a != b {
                assert_eq!(prev, b);
            }
        }
    }

    #[test]
    fn hops_lut_matches_manhattan() {
        let t = Torus::new(7);
        for (x, d) in [(0usize, 1usize), (24, 2), (48, 3)] {
            let ids = t.decision_space(x, d);
            let mut lut = Vec::new();
            t.hops_lut(&ids, &mut lut);
            assert_eq!(lut.len(), ids.len() * ids.len());
            for (i, &a) in ids.iter().enumerate() {
                for (j, &b) in ids.iter().enumerate() {
                    assert_eq!(
                        lut[i * ids.len() + j] as usize,
                        t.manhattan(a, b),
                        "LUT mismatch at ({a},{b})"
                    );
                }
            }
        }
        // reuse clears previous contents
        let ids2 = t.decision_space(3, 1);
        let mut lut = vec![99u16; 4];
        t.hops_lut(&ids2, &mut lut);
        assert_eq!(lut.len(), ids2.len() * ids2.len());

        // > 64 ids exercises the heap coords path
        let big: Vec<SatId> = (0..t.len()).collect();
        t.hops_lut(&big, &mut lut);
        assert_eq!(lut.len(), big.len() * big.len());
        for (i, &a) in big.iter().enumerate() {
            for (j, &b) in big.iter().enumerate() {
                assert_eq!(lut[i * big.len() + j] as usize, t.manhattan(a, b));
            }
        }
    }

    #[test]
    #[should_panic(expected = "n >= 2")]
    fn rejects_tiny_grid() {
        Torus::new(1);
    }

    #[test]
    fn is_empty_agrees_with_len() {
        for n in [2usize, 3, 10] {
            let t = Torus::new(n);
            assert_eq!(t.is_empty(), t.len() == 0);
            assert!(!t.is_empty());
        }
    }

    #[test]
    fn constellation_torus_delegates_exactly() {
        let t = Torus::new(6);
        let c = Constellation::torus(6);
        assert_eq!(c.len(), t.len());
        assert!(!c.is_empty());
        for a in 0..t.len() {
            assert_eq!(c.coords(a), t.coords(a));
            assert_eq!(c.neighbors4(a), t.neighbors(a));
            assert_eq!(c.neighbors(a), t.neighbors(a).to_vec());
            for b in 0..t.len() {
                assert_eq!(c.hops(a, b), t.manhattan(a, b));
            }
        }
        for (x, d) in [(0usize, 1usize), (17, 2), (35, 3)] {
            assert_eq!(c.decision_space(x, d), t.decision_space(x, d));
            let ids = c.decision_space(x, d);
            let (mut lc, mut lt) = (Vec::new(), Vec::new());
            c.hops_lut(&ids, &mut lc);
            t.hops_lut(&ids, &mut lt);
            assert_eq!(lc, lt);
        }
        assert_eq!(c.shortest_path(1, 22), t.shortest_path(1, 22));
    }

    #[test]
    fn walker_delta_zero_phasing_is_the_torus() {
        for n in [3usize, 4, 6] {
            let t = Torus::new(n);
            let w = Constellation::walker_delta(n, n, 0);
            for a in 0..t.len() {
                for b in 0..t.len() {
                    assert_eq!(w.hops(a, b), t.manhattan(a, b), "n={n} {a}->{b}");
                }
            }
        }
    }

    #[test]
    fn walker_star_seam_breaks_the_plane_ring() {
        let p = 5;
        let s = 4;
        let star = Constellation::walker_star(p, s);
        let delta = Constellation::walker_delta(p, s, 0);
        // adjacent across the wrap on delta, P-1 plane hops apart on star
        assert_eq!(delta.hops(0, (p - 1) * s), 1);
        assert_eq!(star.hops(0, (p - 1) * s), p - 1);
        // seam planes have degree 3, interior planes degree 4
        assert_eq!(star.neighbors(0).len(), 3);
        assert_eq!(star.neighbors((p - 1) * s).len(), 3);
        assert_eq!(star.neighbors(s).len(), 4);
        // neighbors4 pads the missing seam link with the satellite itself
        let nb4 = star.neighbors4(0);
        assert_eq!(nb4.iter().filter(|&&x| x == 0).count(), 1);
    }

    #[test]
    fn walker_delta_phasing_shifts_the_wrap_link() {
        let w = Constellation::walker_delta(4, 6, 2);
        // plane 3 slot 0 wraps up to plane 0 slot 0+F=2
        let top = 3 * 6;
        assert!(w.neighbors(top).contains(&2));
        // and the link is symmetric
        assert!(w.neighbors(2).contains(&top));
        for a in 0..w.len() {
            for &nb in &w.neighbors(a) {
                assert_eq!(w.hops(a, nb), 1);
                assert!(w.neighbors(nb).contains(&a), "asymmetric link {a}<->{nb}");
            }
        }
    }

    #[test]
    fn walker_decision_space_and_lut_agree_with_hops() {
        let w = Constellation::walker_star(4, 5);
        let ds = w.decision_space(7, 2);
        assert!(ds.contains(&7));
        assert!(ds.windows(2).all(|p| p[0] < p[1]), "sorted: {ds:?}");
        for s in 0..w.len() {
            assert_eq!(ds.contains(&s), w.hops(7, s) <= 2);
        }
        let mut lut = Vec::new();
        w.hops_lut(&ds, &mut lut);
        assert_eq!(lut.len(), ds.len() * ds.len());
        for (i, &a) in ds.iter().enumerate() {
            for (j, &b) in ds.iter().enumerate() {
                assert_eq!(lut[i * ds.len() + j] as usize, w.hops(a, b));
            }
        }
    }

    #[test]
    fn walker_shortest_path_realizes_hops() {
        for c in [
            Constellation::walker_delta(4, 5, 1),
            Constellation::walker_star(4, 5),
        ] {
            for (a, b) in [(0usize, 19usize), (3, 12), (7, 7), (15, 2)] {
                let p = c.shortest_path(a, b);
                assert_eq!(p.len(), c.hops(a, b), "{a}->{b}: {p:?}");
                let mut prev = a;
                for &h in &p {
                    assert_eq!(c.hops(prev, h), 1);
                    prev = h;
                }
                if a != b {
                    assert_eq!(prev, b);
                }
            }
        }
    }

    #[test]
    fn advance_in_plane_wraps_and_stays_in_plane() {
        let c = Constellation::walker_star(3, 4);
        let s0 = 6; // plane 1, slot 2
        assert_eq!(c.advance_in_plane(s0, 0), s0);
        assert_eq!(c.advance_in_plane(s0, 1), 4 + 3);
        assert_eq!(c.advance_in_plane(s0, 2), 4); // wraps to slot 0
        assert_eq!(c.advance_in_plane(s0, -3), 4 + 3);
        assert_eq!(c.advance_in_plane(s0, 4), s0);
        // torus delegation matches the legacy id() ring step
        let t = Torus::new(5);
        let ct = Constellation::torus(5);
        for s in 0..t.len() {
            for steps in [-7isize, -1, 0, 1, 3, 12] {
                let (o, i) = t.coords(s);
                assert_eq!(
                    ct.advance_in_plane(s, steps),
                    t.id(o as isize, i as isize + steps)
                );
            }
        }
    }

    #[test]
    fn topology_kind_parse_label_roundtrip() {
        for s in ["torus:10", "walker-delta:6x8:2", "walker-star:5x7"] {
            let k = TopologyKind::parse(s).unwrap();
            assert_eq!(TopologyKind::parse(&k.label()).unwrap(), k);
            assert_eq!(k.n_sats(), k.build().len());
        }
        assert_eq!(
            TopologyKind::parse("walker-delta:6x8").unwrap(),
            TopologyKind::WalkerDelta {
                planes: 6,
                sats_per_plane: 8,
                phasing: 0
            }
        );
        assert_eq!(TopologyKind::parse("torus:4").unwrap().n_sats(), 16);
        assert!(TopologyKind::parse("torus").is_err());
        assert!(TopologyKind::parse("torus:1").is_err());
        assert!(TopologyKind::parse("walker-delta:1x8").is_err());
        assert!(TopologyKind::parse("walker-delta:6x8:9").is_err());
        assert!(TopologyKind::parse("walker-star:6").is_err());
        assert!(TopologyKind::parse("hexgrid:3").is_err());
    }

    #[test]
    #[should_panic(expected = "phasing")]
    fn walker_rejects_phasing_out_of_range() {
        Walker::delta(4, 4, 4);
    }
}
