//! Constellation topology substrate (§III-A, §V-A).
//!
//! The paper's network is an N×N Walker-style grid: N orbits, N satellites
//! per orbit, evenly spaced. "The neighbors of each satellite are the
//! adjacent four satellites that can directly communicate" — i.e. a 2-D
//! torus (both the in-orbit ring and the inter-plane ring wrap).
//! Distances are Manhattan hop counts on that torus (Eq. 7, 11c).

/// Satellite identifier: a flat index into the N×N grid.
pub type SatId = usize;

/// An N×N toroidal constellation grid.
#[derive(Clone, Debug)]
pub struct Torus {
    n: usize,
}

impl Torus {
    /// Create an N-orbit × N-satellites-per-orbit grid. Panics if `n < 2`.
    pub fn new(n: usize) -> Torus {
        assert!(n >= 2, "constellation needs n >= 2 (got {n})");
        Torus { n }
    }

    /// Grid edge length N.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Total satellites N².
    pub fn len(&self) -> usize {
        self.n * self.n
    }

    /// `len`/`is_empty` contract: true iff the grid holds no satellites.
    /// (Construction enforces `n >= 2`, so a live `Torus` is never empty.)
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// (orbit, index-in-orbit) of a satellite.
    #[inline]
    pub fn coords(&self, s: SatId) -> (usize, usize) {
        debug_assert!(s < self.len());
        (s / self.n, s % self.n)
    }

    /// Flat id from (orbit, index-in-orbit), with wraparound.
    #[inline]
    pub fn id(&self, orbit: isize, idx: isize) -> SatId {
        let n = self.n as isize;
        let o = orbit.rem_euclid(n) as usize;
        let i = idx.rem_euclid(n) as usize;
        o * self.n + i
    }

    /// Ring distance along one axis of the torus.
    #[inline]
    fn ring_dist(&self, a: usize, b: usize) -> usize {
        let d = a.abs_diff(b);
        d.min(self.n - d)
    }

    /// Manhattan hop distance `MH(i, j)` on the torus (Eq. 7).
    #[inline]
    pub fn manhattan(&self, a: SatId, b: SatId) -> usize {
        let (ao, ai) = self.coords(a);
        let (bo, bi) = self.coords(b);
        self.ring_dist(ao, bo) + self.ring_dist(ai, bi)
    }

    /// The four ISL neighbours (up/down in orbit, left/right across planes).
    pub fn neighbors(&self, s: SatId) -> [SatId; 4] {
        let (o, i) = self.coords(s);
        let (o, i) = (o as isize, i as isize);
        [
            self.id(o - 1, i),
            self.id(o + 1, i),
            self.id(o, i - 1),
            self.id(o, i + 1),
        ]
    }

    /// Decision space `A_x` (constraint 11c): all satellites within
    /// Manhattan distance `d_max` of `x`, **including** `x` itself
    /// (a decision satellite may keep a segment local).
    pub fn decision_space(&self, x: SatId, d_max: usize) -> Vec<SatId> {
        let mut out = Vec::new();
        let (xo, xi) = self.coords(x);
        let (xo, xi) = (xo as isize, xi as isize);
        let d = d_max as isize;
        for dor in -d..=d {
            let rem = d - dor.abs();
            for din in -rem..=rem {
                let id = self.id(xo + dor, xi + din);
                if !out.contains(&id) {
                    out.push(id);
                }
            }
        }
        out.sort_unstable();
        out
    }

    /// Number of distinct satellites within distance `d_max` on an infinite
    /// grid: `2d² + 2d + 1` (the torus may have fewer when N is small).
    pub fn ball_size_upper(d_max: usize) -> usize {
        2 * d_max * d_max + 2 * d_max + 1
    }

    /// Fill `out` with the row-major `ids.len() × ids.len()` Manhattan-hop
    /// LUT for an arbitrary satellite subset: `out[i·len + j] =
    /// MH(ids[i], ids[j])`. The offloading kernel precomputes this once per
    /// decision so the Eq. 12 hot loop never re-derives torus coordinates
    /// (hops fit `u16`: the torus diameter is `N ≤ 65535`).
    pub fn hops_lut(&self, ids: &[SatId], out: &mut Vec<u16>) {
        out.clear();
        out.reserve(ids.len() * ids.len());
        // derive each id's (orbit, index) once — the pairwise loop below is
        // O(|A_x|²) and runs once per offloading decision. A stack buffer
        // covers every realistic decision space (|A_x| = 2d²+2d+1 ≤ 61 for
        // d_max ≤ 5); larger subsets fall back to the heap.
        let mut stack = [(0usize, 0usize); 64];
        let heap: Vec<(usize, usize)>;
        let coords: &[(usize, usize)] = if ids.len() <= stack.len() {
            for (slot, &s) in stack.iter_mut().zip(ids) {
                *slot = self.coords(s);
            }
            &stack[..ids.len()]
        } else {
            heap = ids.iter().map(|&s| self.coords(s)).collect();
            &heap
        };
        for &(ao, ai) in coords {
            for &(bo, bi) in coords {
                out.push((self.ring_dist(ao, bo) + self.ring_dist(ai, bi)) as u16);
            }
        }
    }

    /// One shortest path from `a` to `b` (orbit axis first, then in-orbit),
    /// as the sequence of intermediate hops — used by the coordinator to
    /// route intermediate activations over ISLs.
    pub fn shortest_path(&self, a: SatId, b: SatId) -> Vec<SatId> {
        let mut path = Vec::with_capacity(self.manhattan(a, b));
        let (mut o, mut i) = self.coords(a);
        let (bo, bi) = self.coords(b);
        let n = self.n;
        let step_towards = |from: usize, to: usize| -> isize {
            if from == to {
                return 0;
            }
            let fwd = (to + n - from) % n; // steps going +1
            let bwd = (from + n - to) % n; // steps going -1
            if fwd <= bwd {
                1
            } else {
                -1
            }
        };
        while o != bo {
            o = (o as isize + step_towards(o, bo)).rem_euclid(n as isize) as usize;
            path.push(o * n + i);
        }
        while i != bi {
            i = (i as isize + step_towards(i, bi)).rem_euclid(n as isize) as usize;
            path.push(o * n + i);
        }
        path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coords_roundtrip() {
        let t = Torus::new(7);
        for s in 0..t.len() {
            let (o, i) = t.coords(s);
            assert_eq!(t.id(o as isize, i as isize), s);
        }
    }

    #[test]
    fn manhattan_symmetric_and_triangle() {
        let t = Torus::new(6);
        for a in 0..t.len() {
            for b in 0..t.len() {
                assert_eq!(t.manhattan(a, b), t.manhattan(b, a));
                assert_eq!(t.manhattan(a, b) == 0, a == b);
                for c in [0, 7, 20] {
                    assert!(t.manhattan(a, b) <= t.manhattan(a, c) + t.manhattan(c, b));
                }
            }
        }
    }

    #[test]
    fn torus_wraps() {
        let t = Torus::new(10);
        // (0,0) and (9,0) are adjacent across the seam
        assert_eq!(t.manhattan(t.id(0, 0), t.id(9, 0)), 1);
        assert_eq!(t.manhattan(t.id(0, 0), t.id(5, 5)), 10);
        assert_eq!(t.manhattan(t.id(0, 1), t.id(0, 9)), 2);
    }

    #[test]
    fn four_distinct_neighbors_at_distance_one() {
        let t = Torus::new(5);
        for s in 0..t.len() {
            let nb = t.neighbors(s);
            for x in nb {
                assert_eq!(t.manhattan(s, x), 1);
            }
            let mut u = nb.to_vec();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), 4, "sat {s} has dup neighbors {nb:?}");
        }
    }

    #[test]
    fn decision_space_ball() {
        let t = Torus::new(10);
        let ds = t.decision_space(0, 2);
        assert_eq!(ds.len(), Torus::ball_size_upper(2)); // 13 on a big torus
        assert!(ds.contains(&0));
        for &s in &ds {
            assert!(t.manhattan(0, s) <= 2);
        }
        // everything not in the ball is farther than 2
        for s in 0..t.len() {
            if !ds.contains(&s) {
                assert!(t.manhattan(0, s) > 2);
            }
        }
    }

    #[test]
    fn decision_space_small_torus_dedups() {
        let t = Torus::new(4);
        let ds = t.decision_space(5, 3);
        // ball of radius 3 covers nearly the whole 16-sat torus, without dups
        let mut u = ds.clone();
        u.dedup();
        assert_eq!(u, ds);
        assert!(ds.len() <= t.len());
    }

    #[test]
    fn shortest_path_length_matches_manhattan() {
        let t = Torus::new(8);
        for (a, b) in [(0, 0), (0, 63), (3, 42), (10, 17), (7, 56)] {
            let p = t.shortest_path(a, b);
            assert_eq!(p.len(), t.manhattan(a, b), "path {a}->{b}: {p:?}");
            // consecutive hops are ISL neighbours
            let mut prev = a;
            for &h in &p {
                assert_eq!(t.manhattan(prev, h), 1);
                prev = h;
            }
            if a != b {
                assert_eq!(prev, b);
            }
        }
    }

    #[test]
    fn hops_lut_matches_manhattan() {
        let t = Torus::new(7);
        for (x, d) in [(0usize, 1usize), (24, 2), (48, 3)] {
            let ids = t.decision_space(x, d);
            let mut lut = Vec::new();
            t.hops_lut(&ids, &mut lut);
            assert_eq!(lut.len(), ids.len() * ids.len());
            for (i, &a) in ids.iter().enumerate() {
                for (j, &b) in ids.iter().enumerate() {
                    assert_eq!(
                        lut[i * ids.len() + j] as usize,
                        t.manhattan(a, b),
                        "LUT mismatch at ({a},{b})"
                    );
                }
            }
        }
        // reuse clears previous contents
        let ids2 = t.decision_space(3, 1);
        let mut lut = vec![99u16; 4];
        t.hops_lut(&ids2, &mut lut);
        assert_eq!(lut.len(), ids2.len() * ids2.len());

        // > 64 ids exercises the heap coords path
        let big: Vec<SatId> = (0..t.len()).collect();
        t.hops_lut(&big, &mut lut);
        assert_eq!(lut.len(), big.len() * big.len());
        for (i, &a) in big.iter().enumerate() {
            for (j, &b) in big.iter().enumerate() {
                assert_eq!(lut[i * big.len() + j] as usize, t.manhattan(a, b));
            }
        }
    }

    #[test]
    #[should_panic(expected = "n >= 2")]
    fn rejects_tiny_grid() {
        Torus::new(1);
    }

    #[test]
    fn is_empty_agrees_with_len() {
        for n in [2usize, 3, 10] {
            let t = Torus::new(n);
            assert_eq!(t.is_empty(), t.len() == 0);
            assert!(!t.is_empty());
        }
    }
}
