//! Communication models (§III-B): gateway↔satellite Shannon rate with
//! shadowed-Rician fading (Eq. 1) and the inter-satellite Gaussian-channel
//! rate (Eq. 2). Also derives the per-hop transfer coefficient the delay
//! model (Eq. 7) multiplies by workload × Manhattan hops.

use crate::config::CommConfig;
use crate::util::rng::Pcg64;

const BOLTZMANN: f64 = 1.380_649e-23;

#[inline]
fn db_to_lin(db: f64) -> f64 {
    10f64.powf(db / 10.0)
}

/// Channel state for the gateway↔satellite uplink: samples the composite
/// gain ξ_{g,i}(t) = large-scale fading × shadowed-Rician small-scale term.
#[derive(Clone, Debug)]
pub struct GatewayChannel {
    cfg: CommConfig,
    /// Free-space path loss at the current elevation [dB] (large-scale).
    pub path_loss_db: f64,
}

impl GatewayChannel {
    /// LEO uplink at ~550 km / Ku-band ⇒ ≈ 169 dB free-space loss; callers
    /// can override per-elevation.
    pub fn new(cfg: CommConfig) -> GatewayChannel {
        GatewayChannel {
            cfg,
            path_loss_db: 169.0,
        }
    }

    /// Sample the composite channel gain ξ (linear). Shadowed-Rician: a
    /// Rician LOS term whose mean power is log-normally shadowed.
    pub fn sample_gain(&self, rng: &mut Pcg64) -> f64 {
        let k = db_to_lin(self.cfg.rician_k_db);
        // Rician fading power: |sqrt(K/(K+1)) + sqrt(1/(K+1))·CN(0,1)|²
        let sigma = (1.0 / (2.0 * (k + 1.0))).sqrt();
        let los = (k / (k + 1.0)).sqrt();
        let re = los + sigma * rng.normal();
        let im = sigma * rng.normal();
        let small_scale = re * re + im * im;
        // log-normal shadowing of the large-scale term
        let shadow_db = rng.normal_with(0.0, self.cfg.shadow_sigma_db);
        db_to_lin(-(self.path_loss_db + shadow_db) + self.cfg.antenna_gain_dbi) * small_scale
    }

    /// Eq. 1 — average uplink rate v_{g,i}(t) [bit/s]:
    /// `B0·log2(1 + P_g·ξ/M_G)`.
    pub fn rate_bps(&self, gain: f64) -> f64 {
        let p_g = db_to_lin(self.cfg.gw_tx_power_dbw);
        let noise = db_to_lin(self.cfg.gw_noise_dbw);
        self.cfg.gw_bandwidth_hz * (1.0 + p_g * gain / noise).log2()
    }

    /// Time [s] to upload `bytes` at the sampled rate.
    pub fn upload_secs(&self, bytes: f64, rng: &mut Pcg64) -> f64 {
        let r = self.rate_bps(self.sample_gain(rng)).max(1.0);
        bytes * 8.0 / r
    }
}

/// Inter-satellite link model (Eq. 2).
#[derive(Clone, Debug)]
pub struct IslLink {
    cfg: CommConfig,
}

impl IslLink {
    pub fn new(cfg: CommConfig) -> IslLink {
        IslLink { cfg }
    }

    /// Eq. 2 — max achievable ISL data rate r(i,j) [bit/s]:
    /// `B·log2(1 + P_t·G_i(j)·G_j(i)·L_i(j)·L_j(i) / (k·T·B))`.
    pub fn rate_bps(&self) -> f64 {
        let p_t = db_to_lin(self.cfg.sat_tx_power_dbw);
        let gains = db_to_lin(self.cfg.antenna_gain_dbi);
        let pointing = self.cfg.pointing_coeff * self.cfg.pointing_coeff;
        // Intra-plane ISL path loss at ~2,000 km / 26 GHz ≈ 186 dB.
        let path = db_to_lin(-186.0);
        let noise = BOLTZMANN * self.cfg.noise_temp_k * self.cfg.isl_bandwidth_hz;
        let snr = p_t * gains * pointing * path / noise;
        self.cfg.isl_bandwidth_hz * (1.0 + snr).log2()
    }

    /// Seconds to push `bytes` across ONE hop.
    pub fn hop_secs(&self, bytes: f64) -> f64 {
        bytes * 8.0 / self.rate_bps().max(1.0)
    }

    /// The Eq. 7 transfer coefficient κ [s per (MFLOP·hop)].
    ///
    /// Eq. 7 charges transmission as `MH(s_k, s_{k+1}) · q_k`: the shipped
    /// tensor is proxied by the segment workload. κ converts that product
    /// to seconds using the model's mean activation-bytes-per-MFLOP ratio
    /// and the ISL rate, so delays stay in physical units.
    pub fn kappa_secs_per_mflop_hop(&self, bytes_per_mflop: f64) -> f64 {
        self.hop_secs(bytes_per_mflop)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CommConfig;

    #[test]
    fn isl_rate_in_plausible_band() {
        let link = IslLink::new(CommConfig::default());
        let r = link.rate_bps();
        // 20 MHz channel: between 1 Mb/s and 20 MHz * ~10 b/s/Hz
        assert!(r > 1e6 && r < 2.5e8, "rate = {r}");
    }

    #[test]
    fn hop_time_scales_linearly() {
        let link = IslLink::new(CommConfig::default());
        let t1 = link.hop_secs(1e6);
        let t2 = link.hop_secs(2e6);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn gateway_rate_positive_and_bounded() {
        let ch = GatewayChannel::new(CommConfig::default());
        let mut rng = Pcg64::seed_from_u64(1);
        for _ in 0..100 {
            let g = ch.sample_gain(&mut rng);
            assert!(g > 0.0);
            let r = ch.rate_bps(g);
            assert!(r >= 0.0 && r < 10e6 * 40.0, "r={r}");
        }
    }

    #[test]
    fn shadowing_makes_gain_stochastic() {
        let ch = GatewayChannel::new(CommConfig::default());
        let mut rng = Pcg64::seed_from_u64(2);
        let a = ch.sample_gain(&mut rng);
        let b = ch.sample_gain(&mut rng);
        assert_ne!(a, b);
    }

    #[test]
    fn higher_bandwidth_higher_rate() {
        let mut hi = CommConfig::default();
        hi.isl_bandwidth_hz *= 2.0;
        let r_lo = IslLink::new(CommConfig::default()).rate_bps();
        let r_hi = IslLink::new(hi).rate_bps();
        assert!(r_hi > r_lo);
    }

    #[test]
    fn upload_secs_reasonable() {
        let ch = GatewayChannel::new(CommConfig::default());
        let mut rng = Pcg64::seed_from_u64(3);
        // 224x224x3 f32 image = 602,112 bytes over a ~10-40 Mb/s link
        let t = ch.upload_secs(602_112.0, &mut rng);
        assert!(t > 1e-3 && t < 30.0, "t={t}");
    }
}
