//! Task generation substrate (§III-A): UEs in remote areas generate DNN
//! inference tasks; each area's gateway aggregates them and uplinks to the
//! decision-making satellite overhead. Arrivals per decision satellite per
//! slot are Poisson(λ) (Table I: λ ∈ [4, 70]).

use crate::dnn::DnnModel;
use crate::topology::SatId;
use crate::util::rng::Pcg64;

/// One DNN inference task (a "task block" after the decision satellite
/// groups arrivals into processing units).
#[derive(Clone, Debug)]
pub struct Task {
    /// Unique id (monotone per generator).
    pub id: u64,
    /// Decision-making satellite that received the task from its gateway.
    pub origin: SatId,
    /// Which DNN the task runs.
    pub model: DnnModel,
    /// Workload multiplier: UE inputs vary (crop sizes / batch of frames),
    /// scaling every layer's workload uniformly. 1.0 = canonical 224².
    pub scale: f64,
    /// Slot in which the task arrived (slotted engine's clock).
    pub arrival_slot: usize,
    /// Continuous arrival timestamp [s]. The slotted engine quantizes this
    /// to the slot start; the event-driven engine uses the exact instant.
    pub arrival_time_s: f64,
}

impl Task {
    /// Per-layer workload vector for this task [MFLOP], scaled.
    pub fn layer_workloads(&self) -> Vec<f64> {
        self.model
            .profile()
            .workloads()
            .into_iter()
            .map(|w| w * self.scale)
            .collect()
    }

    /// Total workload [MFLOP].
    pub fn total_mflops(&self) -> f64 {
        self.model.profile().total_mflops() * self.scale
    }
}

/// Poisson task generator for a set of decision satellites.
#[derive(Debug)]
pub struct TaskGenerator {
    rng: Pcg64,
    next_id: u64,
    /// λ — mean tasks per decision satellite per slot.
    pub lambda: f64,
    pub model: DnnModel,
    /// Half-width of the uniform workload-scale jitter around 1.0
    /// (0.0 ⇒ all tasks identical, as in the paper's fixed-model setup).
    pub scale_jitter: f64,
}

impl TaskGenerator {
    pub fn new(seed: u64, lambda: f64, model: DnnModel) -> TaskGenerator {
        TaskGenerator {
            rng: Pcg64::new(seed, 0x7A5C),
            next_id: 0,
            lambda,
            model,
            scale_jitter: 0.0,
        }
    }

    /// With workload jitter (exercises adaptive splitting on varied tasks).
    pub fn with_jitter(mut self, jitter: f64) -> TaskGenerator {
        assert!((0.0..1.0).contains(&jitter));
        self.scale_jitter = jitter;
        self
    }

    /// Draw this slot's arrivals for one decision satellite.
    pub fn arrivals(&mut self, origin: SatId, slot: usize) -> Vec<Task> {
        let k = self.rng.poisson(self.lambda);
        (0..k).map(|_| self.one(origin, slot)).collect()
    }

    /// Generate a single task at a slot boundary (slotted engine).
    pub fn one(&mut self, origin: SatId, slot: usize) -> Task {
        self.at_time(origin, slot as f64)
    }

    /// Generate a single task at a continuous timestamp (event engine).
    pub fn at_time(&mut self, origin: SatId, t: f64) -> Task {
        debug_assert!(t >= 0.0);
        let id = self.next_id;
        self.next_id += 1;
        let scale = if self.scale_jitter > 0.0 {
            self.rng
                .f64_in(1.0 - self.scale_jitter, 1.0 + self.scale_jitter)
        } else {
            1.0
        };
        Task {
            id,
            origin,
            model: self.model,
            scale,
            arrival_slot: t as usize,
            arrival_time_s: t,
        }
    }

    /// Total tasks generated so far.
    pub fn generated(&self) -> u64 {
        self.next_id
    }
}

/// Choose which satellites act as decision-making satellites: `frac` of the
/// constellation, spread deterministically (evenly strided) so coverage
/// areas are geographically dispersed as in Fig. 1.
pub fn decision_satellites(n_sats: usize, frac: f64, seed: u64) -> Vec<SatId> {
    let count = ((n_sats as f64 * frac).round() as usize).clamp(1, n_sats);
    let mut rng = Pcg64::new(seed, 0xDEC1);
    // stride placement + random phase: deterministic, dispersed
    let stride = n_sats as f64 / count as f64;
    let phase = rng.f64() * stride;
    let mut out: Vec<SatId> = (0..count)
        .map(|i| ((phase + i as f64 * stride) as usize) % n_sats)
        .collect();
    out.sort_unstable();
    out.dedup();
    // collisions from rounding: fill with unused ids
    let mut i = 0;
    while out.len() < count {
        if !out.contains(&i) {
            out.push(i);
        }
        i += 1;
    }
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_arrival_mean() {
        let mut g = TaskGenerator::new(1, 25.0, DnnModel::Vgg19);
        let slots = 400;
        let total: usize = (0..slots).map(|s| g.arrivals(0, s).len()).sum();
        let mean = total as f64 / slots as f64;
        assert!((mean - 25.0).abs() < 1.0, "mean={mean}");
    }

    #[test]
    fn ids_unique_and_monotone() {
        let mut g = TaskGenerator::new(2, 10.0, DnnModel::Resnet101);
        let tasks: Vec<Task> = (0..20).flat_map(|s| g.arrivals(3, s)).collect();
        for w in tasks.windows(2) {
            assert!(w[0].id < w[1].id);
        }
        assert_eq!(g.generated(), tasks.len() as u64);
    }

    #[test]
    fn no_jitter_means_identical_scale() {
        let mut g = TaskGenerator::new(3, 5.0, DnnModel::Vgg19);
        for t in g.arrivals(0, 0) {
            assert_eq!(t.scale, 1.0);
        }
    }

    #[test]
    fn jitter_within_bounds() {
        let mut g = TaskGenerator::new(4, 20.0, DnnModel::Vgg19).with_jitter(0.3);
        for s in 0..10 {
            for t in g.arrivals(0, s) {
                assert!((0.7..=1.3).contains(&t.scale), "scale={}", t.scale);
            }
        }
    }

    #[test]
    fn task_workloads_scaled() {
        let t = Task {
            id: 0,
            origin: 0,
            model: DnnModel::Vgg19,
            scale: 2.0,
            arrival_slot: 0,
            arrival_time_s: 0.0,
        };
        let total: f64 = t.layer_workloads().iter().sum();
        assert!((total - t.total_mflops()).abs() < 1e-6);
        assert!((t.total_mflops() / DnnModel::Vgg19.profile().total_mflops() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn continuous_arrival_quantizes_to_slot() {
        let mut g = TaskGenerator::new(5, 1.0, DnnModel::Vgg19);
        let t = g.at_time(2, 3.75);
        assert_eq!(t.arrival_slot, 3);
        assert!((t.arrival_time_s - 3.75).abs() < 1e-12);
        // the slotted path lands exactly on the slot boundary
        let u = g.one(2, 7);
        assert_eq!(u.arrival_slot, 7);
        assert_eq!(u.arrival_time_s, 7.0);
    }

    #[test]
    fn decision_sats_deterministic_and_sized() {
        let a = decision_satellites(100, 0.2, 7);
        let b = decision_satellites(100, 0.2, 7);
        assert_eq!(a, b);
        assert_eq!(a.len(), 20);
        for &s in &a {
            assert!(s < 100);
        }
        // different seed, different phase
        let c = decision_satellites(100, 0.2, 8);
        assert_eq!(c.len(), 20);
    }

    #[test]
    fn decision_sats_at_least_one() {
        assert_eq!(decision_satellites(9, 0.0, 1).len(), 1);
        assert_eq!(decision_satellites(9, 1.0, 1).len(), 9);
    }
}
