//! Task generation substrate (§III-A): UEs in remote areas generate DNN
//! inference tasks; each area's gateway aggregates them and uplinks to the
//! decision-making satellite overhead. Arrivals per decision satellite per
//! slot are Poisson(λ) (Table I: λ ∈ [4, 70]).

use crate::config::LlmConfig;
use crate::dnn::DnnModel;
use crate::topology::SatId;
use crate::util::rng::Pcg64;

/// Which workload class a run generates: the paper's one-shot split-DNN
/// inference, or an LLM-style autoregressive task that keeps producing
/// decode rounds after its prefill (segment-chain) phase completes.
///
/// `OneShot` is the default and is bit-for-bit the pre-task-kind
/// behaviour on both engines (enforced by `tests/prop_taskkind.rs`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TaskKind {
    /// One split inference per task (the paper's model; the default).
    OneShot,
    /// Multi-round decode with sticky KV-cache state (token streaming
    /// over the placed segment chain).
    Autoregressive {
        /// Decode rounds run after the segment chain (the prefill).
        rounds: u32,
        /// Workload of one full-model decode round [MFLOP].
        decode_flops: f64,
        /// KV-cache size [bytes]: re-serving a live task from a different
        /// satellite ships this over the ISL path (Eq. 7 reuse).
        state_bytes: f64,
        /// Small-model-first escalation: rounds run on the serving
        /// satellite's small model until the accumulated round delay
        /// exceeds this threshold [s], then the remaining rounds (and the
        /// KV cache) migrate to the GA-chosen placement. `None` decodes
        /// every round on the chain's last satellite with the full model.
        escalate: Option<f64>,
    },
}

impl TaskKind {
    /// Parse `oneshot` or
    /// `autoregressive[:<rounds>[:<decode_flops>[:<state_bytes>[:<escalate_s>]]]]`
    /// (aliases `ar`, `llm`), filling unstated parameters from `defaults`
    /// (the `[llm]` TOML block) — the same default-injection pattern as
    /// [`crate::state::DisseminationKind::parse_with`].
    pub fn parse_with(s: &str, defaults: &LlmConfig) -> Result<TaskKind, String> {
        let low = s.to_ascii_lowercase();
        let mut parts = low.splitn(5, ':');
        let head = parts.next().unwrap_or("");
        match head {
            "oneshot" | "one-shot" | "single" => {
                if low.contains(':') {
                    Err(format!("task kind 'oneshot' takes no arguments, got '{low}'"))
                } else {
                    Ok(TaskKind::OneShot)
                }
            }
            "autoregressive" | "ar" | "llm" => {
                let mut rounds = defaults.rounds;
                let mut decode_flops = defaults.decode_flops;
                let mut state_bytes = defaults.state_bytes;
                let mut escalate = defaults.escalate;
                if let Some(r) = parts.next() {
                    rounds = r
                        .parse::<u32>()
                        .map_err(|e| format!("task-kind rounds '{r}': {e}"))?;
                }
                if let Some(f) = parts.next() {
                    decode_flops = f
                        .parse::<f64>()
                        .map_err(|e| format!("task-kind decode_flops '{f}': {e}"))?;
                }
                if let Some(b) = parts.next() {
                    state_bytes = b
                        .parse::<f64>()
                        .map_err(|e| format!("task-kind state_bytes '{b}': {e}"))?;
                }
                if let Some(t) = parts.next() {
                    escalate = Some(
                        t.parse::<f64>()
                            .map_err(|e| format!("task-kind escalate '{t}': {e}"))?,
                    );
                }
                let kind = TaskKind::Autoregressive {
                    rounds,
                    decode_flops,
                    state_bytes,
                    escalate,
                };
                kind.validate()?;
                Ok(kind)
            }
            other => Err(format!(
                "unknown task kind '{other}' \
                 (oneshot|autoregressive[:<rounds>[:<mflops>[:<bytes>[:<escalate_s>]]]])"
            )),
        }
    }

    /// [`TaskKind::parse_with`] against the stock `[llm]` defaults.
    pub fn parse(s: &str) -> Result<TaskKind, String> {
        TaskKind::parse_with(s, &LlmConfig::default())
    }

    /// Canonical selector string; `parse_with` on this (under defaults
    /// whose `escalate` is `None`, e.g. [`LlmConfig::default`]) returns
    /// `self` exactly — Rust's float `Display` is shortest-roundtrip, so
    /// the numeric fields survive the trip bit-for-bit
    /// (`tests/prop_config_parse.rs`).
    pub fn label(&self) -> String {
        match self {
            TaskKind::OneShot => "oneshot".into(),
            TaskKind::Autoregressive {
                rounds,
                decode_flops,
                state_bytes,
                escalate,
            } => match escalate {
                Some(e) => {
                    format!("autoregressive:{rounds}:{decode_flops}:{state_bytes}:{e}")
                }
                None => format!("autoregressive:{rounds}:{decode_flops}:{state_bytes}"),
            },
        }
    }

    /// Validate parameter ranges (mirrors [`crate::config::SimConfig::validate`]).
    pub fn validate(&self) -> Result<(), String> {
        match self {
            TaskKind::OneShot => Ok(()),
            TaskKind::Autoregressive {
                rounds,
                decode_flops,
                state_bytes,
                escalate,
            } => {
                if *rounds == 0 {
                    return Err("task-kind rounds must be >= 1".into());
                }
                if !decode_flops.is_finite() || *decode_flops <= 0.0 {
                    return Err(format!(
                        "task-kind decode_flops={decode_flops} must be finite and > 0"
                    ));
                }
                if !state_bytes.is_finite() || *state_bytes < 0.0 {
                    return Err(format!(
                        "task-kind state_bytes={state_bytes} must be finite and >= 0"
                    ));
                }
                if let Some(e) = escalate {
                    if !e.is_finite() || *e < 0.0 {
                        return Err(format!(
                            "task-kind escalate={e} must be finite and >= 0"
                        ));
                    }
                }
                Ok(())
            }
        }
    }
}

/// One DNN inference task (a "task block" after the decision satellite
/// groups arrivals into processing units).
#[derive(Clone, Debug)]
pub struct Task {
    /// Unique id (monotone per generator).
    pub id: u64,
    /// Decision-making satellite that received the task from its gateway.
    pub origin: SatId,
    /// Which DNN the task runs.
    pub model: DnnModel,
    /// Workload multiplier: UE inputs vary (crop sizes / batch of frames),
    /// scaling every layer's workload uniformly. 1.0 = canonical 224².
    pub scale: f64,
    /// Slot in which the task arrived (slotted engine's clock).
    pub arrival_slot: usize,
    /// Continuous arrival timestamp [s]. The slotted engine quantizes this
    /// to the slot start; the event-driven engine uses the exact instant.
    pub arrival_time_s: f64,
}

impl Task {
    /// Per-layer workload vector for this task [MFLOP], scaled.
    pub fn layer_workloads(&self) -> Vec<f64> {
        self.model
            .profile()
            .workloads()
            .into_iter()
            .map(|w| w * self.scale)
            .collect()
    }

    /// Total workload [MFLOP].
    pub fn total_mflops(&self) -> f64 {
        self.model.profile().total_mflops() * self.scale
    }
}

/// Poisson task generator for a set of decision satellites.
#[derive(Debug)]
pub struct TaskGenerator {
    rng: Pcg64,
    next_id: u64,
    /// λ — mean tasks per decision satellite per slot.
    pub lambda: f64,
    pub model: DnnModel,
    /// Half-width of the uniform workload-scale jitter around 1.0
    /// (0.0 ⇒ all tasks identical, as in the paper's fixed-model setup).
    pub scale_jitter: f64,
}

impl TaskGenerator {
    pub fn new(seed: u64, lambda: f64, model: DnnModel) -> TaskGenerator {
        TaskGenerator {
            rng: Pcg64::new(seed, 0x7A5C),
            next_id: 0,
            lambda,
            model,
            scale_jitter: 0.0,
        }
    }

    /// With workload jitter (exercises adaptive splitting on varied tasks).
    pub fn with_jitter(mut self, jitter: f64) -> TaskGenerator {
        assert!((0.0..1.0).contains(&jitter));
        self.scale_jitter = jitter;
        self
    }

    /// Draw this slot's arrivals for one decision satellite.
    pub fn arrivals(&mut self, origin: SatId, slot: usize) -> Vec<Task> {
        let k = self.rng.poisson(self.lambda);
        (0..k).map(|_| self.one(origin, slot)).collect()
    }

    /// Generate a single task at a slot boundary (slotted engine).
    pub fn one(&mut self, origin: SatId, slot: usize) -> Task {
        self.at_time(origin, slot as f64)
    }

    /// Generate a single task at a continuous timestamp (event engine).
    pub fn at_time(&mut self, origin: SatId, t: f64) -> Task {
        debug_assert!(t >= 0.0);
        let id = self.next_id;
        self.next_id += 1;
        let scale = if self.scale_jitter > 0.0 {
            self.rng
                .f64_in(1.0 - self.scale_jitter, 1.0 + self.scale_jitter)
        } else {
            1.0
        };
        Task {
            id,
            origin,
            model: self.model,
            scale,
            arrival_slot: t as usize,
            arrival_time_s: t,
        }
    }

    /// Total tasks generated so far.
    pub fn generated(&self) -> u64 {
        self.next_id
    }
}

/// Choose which satellites act as decision-making satellites: `frac` of the
/// constellation, spread deterministically (evenly strided) so coverage
/// areas are geographically dispersed as in Fig. 1.
pub fn decision_satellites(n_sats: usize, frac: f64, seed: u64) -> Vec<SatId> {
    let count = ((n_sats as f64 * frac).round() as usize).clamp(1, n_sats);
    let mut rng = Pcg64::new(seed, 0xDEC1);
    // stride placement + random phase: deterministic, dispersed
    let stride = n_sats as f64 / count as f64;
    let phase = rng.f64() * stride;
    let mut out: Vec<SatId> = (0..count)
        .map(|i| ((phase + i as f64 * stride) as usize) % n_sats)
        .collect();
    out.sort_unstable();
    out.dedup();
    // collisions from rounding: fill with unused ids
    let mut i = 0;
    while out.len() < count {
        if !out.contains(&i) {
            out.push(i);
        }
        i += 1;
    }
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_arrival_mean() {
        let mut g = TaskGenerator::new(1, 25.0, DnnModel::Vgg19);
        let slots = 400;
        let total: usize = (0..slots).map(|s| g.arrivals(0, s).len()).sum();
        let mean = total as f64 / slots as f64;
        assert!((mean - 25.0).abs() < 1.0, "mean={mean}");
    }

    #[test]
    fn ids_unique_and_monotone() {
        let mut g = TaskGenerator::new(2, 10.0, DnnModel::Resnet101);
        let tasks: Vec<Task> = (0..20).flat_map(|s| g.arrivals(3, s)).collect();
        for w in tasks.windows(2) {
            assert!(w[0].id < w[1].id);
        }
        assert_eq!(g.generated(), tasks.len() as u64);
    }

    #[test]
    fn no_jitter_means_identical_scale() {
        let mut g = TaskGenerator::new(3, 5.0, DnnModel::Vgg19);
        for t in g.arrivals(0, 0) {
            assert_eq!(t.scale, 1.0);
        }
    }

    #[test]
    fn jitter_within_bounds() {
        let mut g = TaskGenerator::new(4, 20.0, DnnModel::Vgg19).with_jitter(0.3);
        for s in 0..10 {
            for t in g.arrivals(0, s) {
                assert!((0.7..=1.3).contains(&t.scale), "scale={}", t.scale);
            }
        }
    }

    #[test]
    fn task_workloads_scaled() {
        let t = Task {
            id: 0,
            origin: 0,
            model: DnnModel::Vgg19,
            scale: 2.0,
            arrival_slot: 0,
            arrival_time_s: 0.0,
        };
        let total: f64 = t.layer_workloads().iter().sum();
        assert!((total - t.total_mflops()).abs() < 1e-6);
        assert!((t.total_mflops() / DnnModel::Vgg19.profile().total_mflops() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn continuous_arrival_quantizes_to_slot() {
        let mut g = TaskGenerator::new(5, 1.0, DnnModel::Vgg19);
        let t = g.at_time(2, 3.75);
        assert_eq!(t.arrival_slot, 3);
        assert!((t.arrival_time_s - 3.75).abs() < 1e-12);
        // the slotted path lands exactly on the slot boundary
        let u = g.one(2, 7);
        assert_eq!(u.arrival_slot, 7);
        assert_eq!(u.arrival_time_s, 7.0);
    }

    #[test]
    fn decision_sats_deterministic_and_sized() {
        let a = decision_satellites(100, 0.2, 7);
        let b = decision_satellites(100, 0.2, 7);
        assert_eq!(a, b);
        assert_eq!(a.len(), 20);
        for &s in &a {
            assert!(s < 100);
        }
        // different seed, different phase
        let c = decision_satellites(100, 0.2, 8);
        assert_eq!(c.len(), 20);
    }

    #[test]
    fn decision_sats_at_least_one() {
        assert_eq!(decision_satellites(9, 0.0, 1).len(), 1);
        assert_eq!(decision_satellites(9, 1.0, 1).len(), 9);
    }

    #[test]
    fn task_kind_parses_and_labels() {
        assert_eq!(TaskKind::parse("oneshot").unwrap(), TaskKind::OneShot);
        assert_eq!(TaskKind::parse("ONE-SHOT").unwrap(), TaskKind::OneShot);
        let d = LlmConfig::default();
        // bare autoregressive fills every field from the [llm] defaults
        assert_eq!(
            TaskKind::parse("autoregressive").unwrap(),
            TaskKind::Autoregressive {
                rounds: d.rounds,
                decode_flops: d.decode_flops,
                state_bytes: d.state_bytes,
                escalate: d.escalate,
            }
        );
        assert_eq!(TaskKind::parse("llm").unwrap(), TaskKind::parse("ar").unwrap());
        let k = TaskKind::parse("autoregressive:4:150.5:1024:0.25").unwrap();
        assert_eq!(
            k,
            TaskKind::Autoregressive {
                rounds: 4,
                decode_flops: 150.5,
                state_bytes: 1024.0,
                escalate: Some(0.25),
            }
        );
        assert_eq!(TaskKind::parse(&k.label()).unwrap(), k);
        assert_eq!(TaskKind::OneShot.label(), "oneshot");
    }

    #[test]
    fn task_kind_rejects_malformed() {
        for bad in [
            "",
            "warp",
            "oneshot:3",
            "autoregressive:zero",
            "autoregressive:3:abc",
            "autoregressive:3:100:xyz",
            "autoregressive:3:100:0:nope",
            "autoregressive:0",       // rounds must be >= 1
            "autoregressive:3:-5",    // decode_flops must be > 0
            "autoregressive:3:100:-1", // state_bytes must be >= 0
        ] {
            assert!(TaskKind::parse(bad).is_err(), "accepted {bad:?}");
        }
    }
}
