//! Resource-state dissemination: what a decision-making satellite can
//! *observe* when it offloads (§I "local observations", §IV Eq. 12).
//!
//! The paper's offloading decisions are made on **disseminated** resource
//! state, not on ground truth: satellite loads propagate over ISLs, so by
//! the time a decision satellite evaluates Eq. 12 the loads it sees may be
//! stale. That staleness is exactly what makes the §V-B herding /
//! load-imbalance effect visible — several decision satellites pick the
//! same "fittest" target before anyone learns its load moved. This module
//! makes the observability model first-class and shared by both engines:
//!
//! * [`StateView`] — the read-only snapshot every
//!   [`crate::offload::OffloadScheme::decide_into`] consumes instead of
//!   live satellite state. Static parameters (`C_x`, `M_w`) are always
//!   exact; the *loaded workload* is either live or an observed copy.
//! * [`DisseminationKind`] — how observations age:
//!   - `instant`: decisions see ground truth (the event engine's legacy
//!     behaviour, and an idealized upper bound);
//!   - `periodic:<T_d>`: a network-wide state broadcast every `T_d`
//!     seconds; between broadcasts an origin sees the last broadcast plus
//!     only its **own** placements (the slotted engine's classic
//!     slot-start snapshot is the `T_d = slot` special case);
//!   - `gossip[:<tick>]`: hop-delayed flooding — an origin's view of a
//!     peer `p` lags by `MH(x, p)` gossip ticks, each tick standing for
//!     one ISL store-and-forward interval.
//! * [`ViewTracker`] — the engine-side machinery: per-area view buffers,
//!   the broadcast/tick schedule, and the origin's self-knowledge
//!   (placements it issued are applied to its own view immediately,
//!   gated by the same Eq. 4 admission rule it believes holds).
//!
//! Both engines drive one tracker. The event engine fires a
//! [`crate::eventsim::Event::StateBroadcast`] event per interval and
//! captures state **eagerly** at the broadcast instant. The slotted engine
//! keeps its legacy semantics by capturing **lazily** at the start of each
//! origin's per-slot batch (dissemination is modeled as completing by the
//! time the origin processes its arrivals); with `T_d = 1` slot this
//! coincides exactly with the pre-existing local-view snapshot, which is
//! enforced bit-for-bit by `tests/prop_staleness.rs`.

use std::collections::VecDeque;

use crate::satellite::Satellite;
use crate::topology::{Constellation, SatId};

/// Default gossip store-and-forward interval [s] — the per-hop state
/// propagation latency when `gossip` is selected without an argument and
/// no config is in scope (25 ms, the typical LEO ISL store-and-forward
/// figure). Config-aware callers derive the tick from the
/// `--isl-latency-ms` knob via [`DisseminationKind::parse_with`] instead.
pub const DEFAULT_GOSSIP_TICK_S: f64 = 0.025;

/// How resource state propagates from satellites to decision makers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DisseminationKind {
    /// Decisions observe ground-truth loads (no propagation delay).
    Instant,
    /// Network-wide broadcast every `period_s` seconds; views refresh per
    /// broadcast window and otherwise age together.
    Periodic {
        /// T_d — broadcast period [s].
        period_s: f64,
    },
    /// Hop-delayed gossip: an origin's view of peer `p` lags by
    /// `MH(origin, p)` ticks of `tick_s` seconds each.
    Gossip {
        /// Per-hop store-and-forward interval [s].
        tick_s: f64,
    },
}

impl DisseminationKind {
    /// Parse `instant | periodic[:<secs>] | gossip[:<secs>]` (the
    /// `--dissemination` CLI / TOML syntax). `periodic` without an
    /// argument means one slot (1 s); `gossip` without an argument uses
    /// [`DEFAULT_GOSSIP_TICK_S`]. Config-aware callers should prefer
    /// [`DisseminationKind::parse_with`], which derives the bare-gossip
    /// tick from the per-hop ISL latency knob.
    pub fn parse(s: &str) -> Result<DisseminationKind, String> {
        DisseminationKind::parse_with(s, DEFAULT_GOSSIP_TICK_S)
    }

    /// [`DisseminationKind::parse`] with the tick a bare `gossip` gets
    /// (the config layer passes `isl_latency_ms / 1000`, so the gossip
    /// cadence tracks the modeled ISL store-and-forward latency instead
    /// of a hard-coded constant). Explicit `gossip:<secs>` always wins.
    pub fn parse_with(
        s: &str,
        gossip_tick_default_s: f64,
    ) -> Result<DisseminationKind, String> {
        let low = s.to_ascii_lowercase();
        let (head, arg) = match low.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (low.as_str(), None),
        };
        let parse_secs = |a: &str| -> Result<f64, String> {
            a.parse::<f64>()
                .map_err(|e| format!("dissemination interval '{a}': {e}"))
        };
        match head {
            "instant" | "fresh" => match arg {
                None => Ok(DisseminationKind::Instant),
                Some(a) => Err(format!("instant takes no argument (got ':{a}')")),
            },
            "periodic" | "broadcast" => Ok(DisseminationKind::Periodic {
                period_s: match arg {
                    Some(a) => parse_secs(a)?,
                    None => 1.0,
                },
            }),
            "gossip" | "hop" => Ok(DisseminationKind::Gossip {
                tick_s: match arg {
                    Some(a) => parse_secs(a)?,
                    None => gossip_tick_default_s,
                },
            }),
            other => Err(format!(
                "unknown dissemination '{other}' (instant|periodic:<s>|gossip[:<s>])"
            )),
        }
    }

    /// Canonical label, accepted back by [`DisseminationKind::parse`].
    pub fn label(&self) -> String {
        match self {
            DisseminationKind::Instant => "instant".into(),
            DisseminationKind::Periodic { period_s } => format!("periodic:{period_s}"),
            DisseminationKind::Gossip { tick_s } => format!("gossip:{tick_s}"),
        }
    }

    /// The staleness scale [s]: 0 for instant, the broadcast period for
    /// periodic, the per-hop tick for gossip (the x-axis of the
    /// `experiment staleness` sweep).
    pub fn t_d_s(&self) -> f64 {
        match self {
            DisseminationKind::Instant => 0.0,
            DisseminationKind::Periodic { period_s } => *period_s,
            DisseminationKind::Gossip { tick_s } => *tick_s,
        }
    }

    /// The model as a slot-clocked engine can realize it: dissemination
    /// can happen at most once per 1 s slot, so sub-slot intervals clamp
    /// up to one slot (`periodic:0.25` runs as `periodic:1`) and a gossip
    /// tick is always one slot per hop. Longer periodic windows,
    /// including non-integer ones, pass through unchanged (the window
    /// boundary test `floor(t / T_d)` works at slot granularity).
    pub fn quantized_to_slots(&self) -> DisseminationKind {
        match *self {
            DisseminationKind::Instant => DisseminationKind::Instant,
            DisseminationKind::Periodic { period_s } => DisseminationKind::Periodic {
                period_s: period_s.max(1.0),
            },
            DisseminationKind::Gossip { .. } => DisseminationKind::Gossip { tick_s: 1.0 },
        }
    }

    /// Range-check the model parameters.
    pub fn validate(&self) -> Result<(), String> {
        let secs = self.t_d_s();
        if !secs.is_finite() || secs < 0.0 {
            return Err(format!("dissemination interval {secs} must be finite and >= 0"));
        }
        match self {
            DisseminationKind::Instant => Ok(()),
            _ if secs > 0.0 => Ok(()),
            _ => Err("dissemination interval must be > 0 (use 'instant' for no lag)".into()),
        }
    }
}

/// The resource state an offloading scheme is allowed to observe.
///
/// Static per-satellite parameters (`C_x` capacity, `M_w` admission
/// ceiling) are always read exactly; the **loaded workload** `q` is either
/// live (instant dissemination) or an observed per-area copy maintained by
/// a [`ViewTracker`]. Derived quantities ([`StateView::residual`],
/// [`StateView::utilization`]) use the same expressions as
/// [`Satellite::residual`] / [`Satellite::utilization`] so instant views
/// are bit-for-bit identical to reading the satellites directly.
#[derive(Clone, Copy)]
pub struct StateView<'a> {
    sats: &'a [Satellite],
    observed: Option<&'a [f64]>,
    /// Dissemination epoch this view was captured in (see
    /// [`ViewTracker::epoch`]); 0 for live and hand-built views.
    epoch: u64,
}

impl<'a> StateView<'a> {
    /// A view with zero staleness: reads live satellite state.
    pub fn live(sats: &'a [Satellite]) -> StateView<'a> {
        StateView {
            sats,
            observed: None,
            epoch: 0,
        }
    }

    /// A view whose loaded workloads come from `loaded` (one entry per
    /// satellite) while static parameters stay exact.
    pub fn observed(sats: &'a [Satellite], loaded: &'a [f64]) -> StateView<'a> {
        debug_assert_eq!(sats.len(), loaded.len());
        StateView {
            sats,
            observed: Some(loaded),
            epoch: 0,
        }
    }

    /// Tag this view with the dissemination epoch it was captured in
    /// (builder form, used by [`ViewTracker::view`]). The epoch carries no
    /// state itself — it is the invalidation key the opt-in decision
    /// cache (`--decision-cache`) hangs on.
    pub fn at_epoch(mut self, epoch: u64) -> StateView<'a> {
        self.epoch = epoch;
        self
    }

    /// Monotone dissemination epoch of this view: 0 for live views,
    /// otherwise the owning tracker's counter at capture time.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Number of satellites in view.
    pub fn len(&self) -> usize {
        self.sats.len()
    }

    /// True when the constellation is empty.
    pub fn is_empty(&self) -> bool {
        self.sats.is_empty()
    }

    /// True when loads are observed copies rather than ground truth.
    pub fn is_stale(&self) -> bool {
        self.observed.is_some()
    }

    /// Observed loaded workload `q` of satellite `id` [MFLOP].
    #[inline]
    pub fn loaded(&self, id: SatId) -> f64 {
        match self.observed {
            Some(o) => o[id],
            None => self.sats[id].loaded(),
        }
    }

    /// `C_x` — computation capability [MFLOP/slot] (always exact).
    #[inline]
    pub fn capacity(&self, id: SatId) -> f64 {
        self.sats[id].capacity_mflops
    }

    /// `M_w` — maximum loaded workload before Eq. 4 rejects (always exact).
    #[inline]
    pub fn max_workload(&self, id: SatId) -> f64 {
        self.sats[id].max_workload_mflops
    }

    /// Observed residual admissible workload `M_w − q` (RRP's ranking key).
    #[inline]
    pub fn residual(&self, id: SatId) -> f64 {
        (self.sats[id].max_workload_mflops - self.loaded(id)).max(0.0)
    }

    /// Observed admission-window utilization `q / M_w` in [0, 1].
    #[inline]
    pub fn utilization(&self, id: SatId) -> f64 {
        (self.loaded(id) / self.sats[id].max_workload_mflops).clamp(0.0, 1.0)
    }
}

/// One gossip snapshot: capture time plus per-satellite loaded workloads.
type Snapshot = (f64, Vec<f64>);

/// Engine-side dissemination machinery: per-area observed-state buffers
/// driven by the broadcast/tick schedule of a [`DisseminationKind`].
///
/// * **Instant** — no buffers; [`ViewTracker::view`] returns a live view.
/// * **Periodic** — one `loaded` buffer per decision area. The event
///   engine refreshes every buffer eagerly at each `StateBroadcast` event
///   ([`ViewTracker::broadcast_now`]); the slotted engine refreshes lazily
///   at the first batch of each broadcast window
///   ([`ViewTracker::advance_to`] + [`ViewTracker::sync_batch`]), which
///   makes `T_d = 1` slot coincide exactly with its legacy local-view
///   snapshot.
/// * **Gossip** — a ring of timestamped whole-constellation snapshots; an
///   area's view of peer `p` is the snapshot `MH(origin, p)` ticks old,
///   with the area's own recent placements replayed on top.
///
/// Self-knowledge: [`ViewTracker::record_local`] applies an origin's own
/// placement to its view immediately — gated by Eq. 4 against the *view*
/// (the origin's belief), matching the legacy slotted `local_view` exactly.
pub struct ViewTracker {
    kind: DisseminationKind,
    /// Per-area observed `loaded` vectors (empty for Instant).
    views: Vec<Vec<f64>>,
    /// Broadcast generation each area's view last synced to (Periodic).
    synced: Vec<u64>,
    /// Broadcast windows opened so far (Periodic).
    generation: u64,
    /// Snapshot ring, newest first: `ring[h]` is `h` ticks old (Gossip).
    ring: VecDeque<Snapshot>,
    /// Ring depth: `d_max + 1` (view lag is capped at `d_max` hops, the
    /// farthest candidate constraint 11c admits).
    depth: usize,
    /// Per-area log of own placements `(t, sat, q)` newer than the oldest
    /// retained snapshot, replayed on top of lagged snapshots (Gossip).
    logs: Vec<Vec<(f64, SatId, f64)>>,
    /// Eager dissemination captures performed ([`ViewTracker::broadcast_now`]);
    /// telemetry only — see [`ViewTracker::broadcasts`].
    broadcasts: u64,
    /// Monotone view-epoch counter (see [`ViewTracker::epoch`]): bumped
    /// whenever observed views may change for a reason other than an
    /// origin's own placements — broadcasts, newly opened periodic
    /// windows, and engine-reported shocks ([`ViewTracker::bump_epoch`]).
    epoch: u64,
}

impl ViewTracker {
    /// Build a tracker for `n_areas` decision areas over `n_sats`
    /// satellites; `d_max` bounds the gossip lag (constraint 11c).
    pub fn new(
        kind: DisseminationKind,
        n_sats: usize,
        n_areas: usize,
        d_max: usize,
    ) -> ViewTracker {
        let buffered = !matches!(kind, DisseminationKind::Instant);
        let gossip = matches!(kind, DisseminationKind::Gossip { .. });
        let mut ring = VecDeque::new();
        if gossip {
            // the constellation starts idle: one all-zero snapshot at t=0
            ring.push_front((0.0, vec![0.0; n_sats]));
        }
        ViewTracker {
            kind,
            views: if buffered {
                vec![vec![0.0; n_sats]; n_areas]
            } else {
                Vec::new()
            },
            synced: vec![0; if buffered { n_areas } else { 0 }],
            generation: 0,
            ring,
            depth: d_max + 1,
            logs: vec![Vec::new(); if gossip { n_areas } else { 0 }],
            broadcasts: 0,
            epoch: 0,
        }
    }

    /// The model this tracker implements.
    pub fn kind(&self) -> DisseminationKind {
        self.kind
    }

    /// True when views are live (no buffers to maintain).
    pub fn is_instant(&self) -> bool {
        matches!(self.kind, DisseminationKind::Instant)
    }

    /// True for the hop-delayed gossip model.
    pub fn is_gossip(&self) -> bool {
        matches!(self.kind, DisseminationKind::Gossip { .. })
    }

    /// Interval between dissemination events [s]; `None` for instant
    /// (nothing to schedule).
    pub fn broadcast_interval(&self) -> Option<f64> {
        match self.kind {
            DisseminationKind::Instant => None,
            DisseminationKind::Periodic { period_s } => Some(period_s),
            DisseminationKind::Gossip { tick_s } => Some(tick_s),
        }
    }

    /// Eager capture at a dissemination instant (the event engine's
    /// `StateBroadcast` handler; the slotted engine calls this at slot
    /// start for gossip). `serving[area]` is each area's current decision
    /// satellite — the gossip lag reference point.
    pub fn broadcast_now(
        &mut self,
        t: f64,
        sats: &[Satellite],
        topo: &Constellation,
        serving: &[SatId],
    ) {
        match self.kind {
            DisseminationKind::Instant => {}
            DisseminationKind::Periodic { .. } => {
                self.broadcasts += 1;
                self.generation += 1;
                self.epoch += 1;
                for (area, view) in self.views.iter_mut().enumerate() {
                    for (v, s) in view.iter_mut().zip(sats) {
                        *v = s.loaded();
                    }
                    self.synced[area] = self.generation;
                }
            }
            DisseminationKind::Gossip { .. } => {
                self.broadcasts += 1;
                self.epoch += 1;
                // push the new snapshot, recycling the evicted buffer
                let mut snap = if self.ring.len() >= self.depth {
                    self.ring.pop_back().map(|(_, v)| v).unwrap_or_default()
                } else {
                    Vec::new()
                };
                snap.clear();
                snap.extend(sats.iter().map(|s| s.loaded()));
                self.ring.push_front((t, snap));
                let oldest_t = self.ring.back().map(|(ts, _)| *ts).unwrap_or(t);
                let newest = self.ring.len() - 1;
                for (area, log) in self.logs.iter_mut().enumerate() {
                    // entries strictly before the oldest snapshot are
                    // inside every retained snapshot already
                    log.retain(|&(tp, _, _)| tp >= oldest_t);
                    let origin = serving[area];
                    let view = &mut self.views[area];
                    for (p, v) in view.iter_mut().enumerate() {
                        let h = topo.hops(origin, p).min(newest);
                        *v = self.ring[h].1[p];
                    }
                    // replay own placements the visible snapshot cannot
                    // contain yet: a snapshot at time ts captures state
                    // from strictly before ts (the slotted engine stamps
                    // slot-start snapshots and same-slot placements with
                    // the same integer second), so tp >= ts replays
                    for &(tp, p, q) in log.iter() {
                        let h = topo.hops(origin, p).min(newest);
                        if tp >= self.ring[h].0 {
                            view[p] += q;
                        }
                    }
                }
            }
        }
    }

    /// Lazy clock advance for the slotted engine: opens the broadcast
    /// window containing time `t` (Periodic only); actual state capture is
    /// deferred to each area's next [`ViewTracker::sync_batch`].
    pub fn advance_to(&mut self, t: f64) {
        if let DisseminationKind::Periodic { period_s } = self.kind {
            let gen = (t / period_s).floor() as u64 + 1;
            if gen > self.generation {
                self.generation = gen;
                self.epoch += 1;
            }
        }
    }

    /// Lazy capture at the start of an area's decision batch (slotted
    /// engine): if a new broadcast window opened since this area last
    /// synced, its view re-captures live state — the legacy slot-start
    /// snapshot when `T_d = 1` slot.
    pub fn sync_batch(&mut self, area: usize, sats: &[Satellite]) {
        if matches!(self.kind, DisseminationKind::Periodic { .. })
            && self.synced[area] < self.generation
        {
            for (v, s) in self.views[area].iter_mut().zip(sats) {
                *v = s.loaded();
            }
            self.synced[area] = self.generation;
        }
    }

    /// Record a placement the origin of `area` just issued: its own view
    /// updates immediately (it made the decision), gated by the Eq. 4
    /// admission rule evaluated against the *view* — the origin's belief,
    /// exactly like the legacy slotted `local_view.try_load`. No-op for
    /// instant (live state already reflects real admissions).
    pub fn record_local(&mut self, area: usize, sat: SatId, q: f64, t: f64, sats: &[Satellite]) {
        if self.is_instant() || q <= 0.0 {
            return;
        }
        let view = &mut self.views[area];
        if view[sat] + q < sats[sat].max_workload_mflops {
            view[sat] += q;
            if !self.logs.is_empty() {
                self.logs[area].push((t, sat, q));
            }
        }
    }

    /// Dissemination rounds driven so far, for telemetry: eager
    /// [`ViewTracker::broadcast_now`] captures (the event engine, and the
    /// slotted engine under gossip) or lazily opened periodic windows via
    /// [`ViewTracker::advance_to`] (the slotted engine), whichever the
    /// engine actually exercised. Zero for instant dissemination.
    pub fn broadcasts(&self) -> u64 {
        self.broadcasts.max(self.generation)
    }

    /// Monotone view-epoch counter: increments at every dissemination
    /// capture / newly opened periodic window and at every engine-reported
    /// shock ([`ViewTracker::bump_epoch`] on faults and handovers).
    /// Between two epochs, an area's observed view changes only through
    /// its origin's own placements ([`ViewTracker::record_local`]) — the
    /// invariant the opt-in `--decision-cache` relies on to replay
    /// placements within an epoch.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Engine hook for view shocks outside the dissemination schedule —
    /// fault batches (capacities vanished) and coverage handovers (the
    /// serving satellite changed). Cached decisions must not survive
    /// either, so engines bump the epoch even though the observed buffers
    /// themselves refresh only at the next capture.
    pub fn bump_epoch(&mut self) {
        self.epoch += 1;
    }

    /// The state view `area`'s origin decides on right now.
    pub fn view<'a>(&'a self, area: usize, sats: &'a [Satellite]) -> StateView<'a> {
        match self.kind {
            DisseminationKind::Instant => StateView::live(sats),
            _ => StateView::observed(sats, &self.views[area]).at_epoch(self.epoch),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sats(n: usize) -> Vec<Satellite> {
        (0..n).map(|i| Satellite::new(i, 3000.0, 15000.0)).collect()
    }

    #[test]
    fn parse_roundtrip_and_errors() {
        for s in ["instant", "periodic:0.5", "periodic:2", "gossip:0.25"] {
            let k = DisseminationKind::parse(s).unwrap();
            assert_eq!(DisseminationKind::parse(&k.label()).unwrap(), k);
        }
        assert_eq!(
            DisseminationKind::parse("periodic").unwrap(),
            DisseminationKind::Periodic { period_s: 1.0 }
        );
        assert_eq!(
            DisseminationKind::parse("gossip").unwrap(),
            DisseminationKind::Gossip {
                tick_s: DEFAULT_GOSSIP_TICK_S
            }
        );
        assert!(DisseminationKind::parse("telepathy").is_err());
        assert!(DisseminationKind::parse("periodic:x").is_err());
        assert!(DisseminationKind::parse("instant:1").is_err());
        assert!(DisseminationKind::Periodic { period_s: 0.0 }.validate().is_err());
        assert!(DisseminationKind::Gossip { tick_s: f64::NAN }.validate().is_err());
        assert!(DisseminationKind::Instant.validate().is_ok());
    }

    #[test]
    fn live_view_matches_satellite_reads_bitwise() {
        let mut s = sats(4);
        s[2].try_load(1234.5);
        let v = StateView::live(&s);
        assert_eq!(v.len(), 4);
        assert!(!v.is_stale());
        for (i, sat) in s.iter().enumerate() {
            assert_eq!(v.loaded(i).to_bits(), sat.loaded().to_bits());
            assert_eq!(v.residual(i).to_bits(), sat.residual().to_bits());
            assert_eq!(v.utilization(i).to_bits(), sat.utilization().to_bits());
            assert_eq!(v.capacity(i), sat.capacity_mflops);
            assert_eq!(v.max_workload(i), sat.max_workload_mflops);
        }
    }

    #[test]
    fn observed_view_overrides_loads_only() {
        let mut s = sats(3);
        s[0].try_load(9000.0);
        let obs = vec![100.0, 200.0, 300.0];
        let v = StateView::observed(&s, &obs);
        assert!(v.is_stale());
        assert_eq!(v.loaded(0), 100.0); // stale, not the live 9000
        assert_eq!(v.residual(2), 15000.0 - 300.0);
        assert_eq!(v.capacity(0), 3000.0); // static params stay exact
    }

    #[test]
    fn periodic_views_freeze_between_broadcasts() {
        let topo = Constellation::torus(3);
        let mut live = sats(9);
        let mut tr = ViewTracker::new(
            DisseminationKind::Periodic { period_s: 2.0 },
            9,
            1,
            2,
        );
        let serving = [0usize];
        live[4].try_load(5000.0);
        tr.broadcast_now(2.0, &live, &topo, &serving);
        assert_eq!(tr.view(0, &live).loaded(4), 5000.0);
        // live moves on; the view must not
        live[4].try_load(3000.0);
        assert_eq!(tr.view(0, &live).loaded(4), 5000.0);
        tr.broadcast_now(4.0, &live, &topo, &serving);
        assert_eq!(tr.view(0, &live).loaded(4), 8000.0);
    }

    #[test]
    fn record_local_respects_believed_admission() {
        let topo = Constellation::torus(3);
        let live = sats(9);
        let mut tr = ViewTracker::new(
            DisseminationKind::Periodic { period_s: 1.0 },
            9,
            1,
            2,
        );
        tr.broadcast_now(0.0, &live, &topo, &[0]);
        tr.record_local(0, 3, 14_000.0, 0.0, &live);
        assert_eq!(tr.view(0, &live).loaded(3), 14_000.0);
        // 14_000 + 2_000 >= 15_000: the origin believes this placement
        // would be rejected, so its view must not grow
        tr.record_local(0, 3, 2_000.0, 0.0, &live);
        assert_eq!(tr.view(0, &live).loaded(3), 14_000.0);
        tr.record_local(0, 3, 900.0, 0.0, &live);
        assert_eq!(tr.view(0, &live).loaded(3), 14_900.0);
    }

    #[test]
    fn gossip_views_lag_by_hop_count() {
        let topo = Constellation::torus(4);
        let mut live = sats(16);
        let origin = 0usize;
        let nb = topo.neighbors(origin)[0];
        let mut tr = ViewTracker::new(
            DisseminationKind::Gossip { tick_s: 1.0 },
            16,
            1,
            2,
        );
        // tick 1: neighbor loaded 4000
        live[nb].try_load(4000.0);
        live[origin].try_load(1000.0);
        tr.broadcast_now(1.0, &live, &topo, &[origin]);
        // tick 2: neighbor loads 2000 more
        live[nb].try_load(2000.0);
        tr.broadcast_now(2.0, &live, &topo, &[origin]);
        let v = tr.view(0, &live);
        // self: freshest snapshot (lag 0)
        assert_eq!(v.loaded(origin), 1000.0);
        // neighbor at MH=1: one tick old — sees 4000, not 6000
        assert_eq!(v.loaded(nb), 4000.0);
        // after another tick the 6000 becomes visible at lag 1
        tr.broadcast_now(3.0, &live, &topo, &[origin]);
        assert_eq!(tr.view(0, &live).loaded(nb), 6000.0);
    }

    #[test]
    fn gossip_replays_own_placements_on_stale_peers() {
        let topo = Constellation::torus(4);
        let live = sats(16);
        let origin = 0usize;
        let nb = topo.neighbors(origin)[0];
        let mut tr = ViewTracker::new(
            DisseminationKind::Gossip { tick_s: 1.0 },
            16,
            1,
            2,
        );
        tr.broadcast_now(1.0, &live, &topo, &[origin]);
        // the origin places 3000 on its neighbor between ticks: its own
        // view must reflect it immediately...
        tr.record_local(0, nb, 3000.0, 1.5, &live);
        assert_eq!(tr.view(0, &live).loaded(nb), 3000.0);
        // ...and keep reflecting it across the next tick, where the
        // visible (1-tick-old) snapshot predates the placement. The live
        // state never saw the load (this test never calls try_load), which
        // stands in for the snapshot lag.
        tr.broadcast_now(2.0, &live, &topo, &[origin]);
        assert_eq!(tr.view(0, &live).loaded(nb), 3000.0);
    }

    #[test]
    fn broadcast_counter_tracks_rounds() {
        let topo = Constellation::torus(3);
        let live = sats(9);
        let mut tr = ViewTracker::new(
            DisseminationKind::Periodic { period_s: 2.0 },
            9,
            1,
            2,
        );
        assert_eq!(tr.broadcasts(), 0);
        tr.broadcast_now(2.0, &live, &topo, &[0]);
        tr.broadcast_now(4.0, &live, &topo, &[0]);
        assert_eq!(tr.broadcasts(), 2);
        // the slotted engine's lazy periodic path opens windows without
        // ever calling broadcast_now; those count too
        let mut lazy = ViewTracker::new(
            DisseminationKind::Periodic { period_s: 1.0 },
            9,
            1,
            2,
        );
        lazy.advance_to(3.0);
        assert_eq!(lazy.broadcasts(), 4); // windows at t = 0, 1, 2, 3
        // instant dissemination never broadcasts
        let mut inst = ViewTracker::new(DisseminationKind::Instant, 9, 1, 2);
        inst.broadcast_now(1.0, &live, &topo, &[0]);
        assert_eq!(inst.broadcasts(), 0);
    }

    #[test]
    fn epoch_counts_broadcasts_windows_and_shocks() {
        let topo = Constellation::torus(3);
        let live = sats(9);
        // eager periodic: every broadcast is an epoch
        let mut tr = ViewTracker::new(
            DisseminationKind::Periodic { period_s: 2.0 },
            9,
            1,
            2,
        );
        assert_eq!(tr.epoch(), 0);
        assert_eq!(tr.view(0, &live).epoch(), 0);
        tr.broadcast_now(2.0, &live, &topo, &[0]);
        tr.broadcast_now(4.0, &live, &topo, &[0]);
        assert_eq!(tr.epoch(), 2);
        assert_eq!(tr.view(0, &live).epoch(), 2);
        // engine-reported shocks (fault / handover) bump without a capture
        tr.bump_epoch();
        assert_eq!(tr.epoch(), 3);
        // lazy periodic: an epoch per newly opened window, and repeated
        // advances inside one window change nothing
        let mut lazy = ViewTracker::new(
            DisseminationKind::Periodic { period_s: 1.0 },
            9,
            1,
            2,
        );
        lazy.advance_to(0.0);
        assert_eq!(lazy.epoch(), 1);
        lazy.advance_to(0.5);
        assert_eq!(lazy.epoch(), 1);
        lazy.advance_to(3.0);
        assert_eq!(lazy.epoch(), 2);
        // gossip ticks are epochs too
        let mut gsp = ViewTracker::new(
            DisseminationKind::Gossip { tick_s: 1.0 },
            9,
            1,
            2,
        );
        gsp.broadcast_now(1.0, &live, &topo, &[0]);
        assert_eq!(gsp.epoch(), 1);
        // live views always report epoch 0
        let inst = ViewTracker::new(DisseminationKind::Instant, 9, 1, 2);
        assert_eq!(inst.view(0, &live).epoch(), 0);
    }

    #[test]
    fn instant_tracker_is_transparent() {
        let topo = Constellation::torus(3);
        let mut live = sats(9);
        let mut tr = ViewTracker::new(DisseminationKind::Instant, 9, 2, 2);
        assert!(tr.is_instant());
        assert_eq!(tr.broadcast_interval(), None);
        tr.broadcast_now(1.0, &live, &topo, &[0, 4]);
        tr.record_local(0, 3, 500.0, 1.0, &live);
        live[3].try_load(700.0);
        // the view is the live state, untouched by tracker calls
        assert_eq!(tr.view(0, &live).loaded(3), 700.0);
        assert!(!tr.view(0, &live).is_stale());
    }
}
