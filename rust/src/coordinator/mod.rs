//! L3 coordinator service: the "decision-making satellite" as a running
//! process. It owns a pool of PJRT execution workers (each with its own
//! on-board engine — the `xla` crate's client types are thread-confined),
//! and the request loop: arriving DNN tasks are split (Alg. 1), assigned
//! a processing sequence (Alg. 2 / a baseline), and each segment's *real*
//! slice inference executes on an execution worker — activations handed
//! off through channels (the ISL stand-in), delays accounted per Eq. 5–8.
//!
//! The offline image has no tokio, so concurrency is std::thread worker
//! pools over mpsc channels ([`pool`] for generic jobs,
//! [`crate::runtime::ExecPool`] for PJRT executions).

pub mod pool;

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::SimConfig;
use crate::dnn::DnnModel;
use crate::offload::{make_scheme, OffloadContext, OffloadScheme, SchemeKind};
use crate::runtime::ExecPool;
use crate::satellite::{Admission, Satellite};
use crate::splitting::balanced_split;
use crate::topology::Constellation;
use crate::util::rng::Pcg64;

/// A served inference request (one DNN task from a gateway).
#[derive(Clone, Debug)]
pub struct InferenceRequest {
    pub id: u64,
    pub origin: usize,
    pub model: DnnModel,
}

/// Completed-request record.
#[derive(Clone, Debug)]
pub struct InferenceResponse {
    pub id: u64,
    /// Satellites that executed each segment.
    pub sequence: Vec<usize>,
    /// Wall-clock service time (real PJRT execution included) [ms].
    pub wall_ms: f64,
    /// Model-predicted delay (Eq. 5 + Eq. 7) [ms].
    pub modeled_ms: f64,
    /// Dropped at segment k (Eq. 4), if any.
    pub dropped_at: Option<usize>,
    /// Checksum of the final activation (proves real compute ran).
    pub output_checksum: f64,
}

/// Coordinator statistics.
#[derive(Debug, Default)]
pub struct CoordStats {
    pub served: AtomicU64,
    pub dropped: AtomicU64,
    pub segments_executed: AtomicU64,
}

/// The collaborative-satellite-computing coordinator.
pub struct Coordinator {
    cfg: SimConfig,
    topo: Constellation,
    satellites: Arc<Mutex<Vec<Satellite>>>,
    exec: ExecPool,
    scheme: Box<dyn OffloadScheme>,
    pub stats: Arc<CoordStats>,
    kappa: f64,
}

impl Coordinator {
    /// Build a coordinator over the configured constellation
    /// (`cfg.effective_topology()` — the `cfg.n × cfg.n` torus by
    /// default) with artifacts loaded from `artifact_dir` by `workers`
    /// PJRT execution workers.
    pub fn new(
        cfg: &SimConfig,
        artifact_dir: &Path,
        workers: usize,
        scheme_kind: SchemeKind,
    ) -> Result<Coordinator> {
        let exec = ExecPool::new(artifact_dir, workers.max(1))
            .with_context(|| format!("loading artifacts from {}", artifact_dir.display()))?;
        let topo = cfg.build_topology();
        let satellites = (0..topo.len())
            .map(|i| {
                Satellite::new(
                    i,
                    cfg.satellite.capacity_mflops,
                    cfg.satellite.max_workload_mflops,
                )
            })
            .collect();
        let profile = cfg.model.profile();
        let bytes_per_mflop = profile.layers.iter().map(|l| l.output_bytes).sum::<f64>()
            / profile.total_mflops().max(1e-9);
        let isl = crate::comm::IslLink::new(cfg.comm.clone());
        Ok(Coordinator {
            cfg: cfg.clone(),
            topo,
            satellites: Arc::new(Mutex::new(satellites)),
            exec,
            scheme: make_scheme(scheme_kind, cfg.seed),
            stats: Arc::new(CoordStats::default()),
            kappa: isl.kappa_secs_per_mflop_hop(bytes_per_mflop),
        })
    }

    /// Artifact that stands in for one segment's slice compute.
    fn slice_artifact(model: DnnModel) -> &'static str {
        match model {
            DnnModel::Vgg19 => "vgg_slice",
            DnnModel::Resnet101 => "resnet_slice",
        }
    }

    /// Names of loaded artifacts (diagnostics).
    pub fn artifact_names(&self) -> &[String] {
        self.exec.artifact_names()
    }

    /// Serve one request: split, decide, admit, then execute the surviving
    /// segments' slice inference on the PJRT workers, chaining activations.
    pub fn serve(&mut self, req: &InferenceRequest) -> Result<InferenceResponse> {
        let l = self.cfg.effective_l();
        let d_max = self.cfg.effective_d_max();
        let profile = req.model.profile();
        let segments =
            balanced_split(&profile.workloads(), l, self.cfg.ga.epsilon).segment_workloads();
        let candidates = self.topo.decision_space(req.origin, d_max);

        // decide under the current shared satellite state
        let chrom = {
            let sats = self.satellites.lock().unwrap();
            let ctx = OffloadContext {
                topo: &self.topo,
                view: crate::state::StateView::live(&sats),
                origin: req.origin,
                candidates: &candidates,
                segments: &segments,
                kappa: self.kappa,
                ga: &self.cfg.ga,
                migration: None,
                outages: None,
            };
            self.scheme.decide(&ctx)
        };

        // admission + modeled delay (Eq. 4, 5, 7)
        let mut modeled_s = 0.0;
        let mut dropped_at = None;
        {
            let mut sats = self.satellites.lock().unwrap();
            for (k, (&c, &q)) in chrom.iter().zip(&segments).enumerate() {
                if q == 0.0 {
                    continue;
                }
                match sats[c].try_load(q) {
                    Admission::Accepted => {
                        modeled_s += sats[c].service_secs_with_queue(q);
                        if k + 1 < chrom.len() {
                            modeled_s +=
                                self.topo.hops(c, chrom[k + 1]) as f64 * q * self.kappa;
                        }
                    }
                    Admission::Rejected => {
                        dropped_at = Some(k);
                        break;
                    }
                }
            }
        }

        // real compute: run each surviving segment's slice artifact,
        // sequentially chained (activation of k feeds k+1).
        let t0 = Instant::now();
        let mut checksum = 0.0f64;
        if dropped_at.is_none() {
            let art = Self::slice_artifact(req.model);
            let n_elem: usize = {
                // all exec workers share artifact set; look up input size
                // via a probe execution-free path: sizes are fixed per model
                match req.model {
                    DnnModel::Vgg19 => 1 * 56 * 56 * 64,
                    DnnModel::Resnet101 => 1 * 56 * 56 * 256,
                }
            };
            let n_exec = chrom.iter().zip(&segments).filter(|(_, &q)| q > 0.0).count();
            let mut rng = Pcg64::new(self.cfg.seed ^ req.id, 0xAC7);
            let mut act: Vec<f32> = (0..n_elem).map(|_| rng.f64() as f32).collect();
            for _ in 0..n_exec {
                let out = self
                    .exec
                    .run(art, vec![std::mem::take(&mut act)])
                    .context("segment execution")?;
                let flat = &out[0];
                checksum = flat.iter().map(|x| *x as f64).sum::<f64>();
                // shape-adapt the activation for the next fixed-shape slice
                // (the stand-in for the real per-cut shapes the AOT graph
                // would carry in a per-slice artifact set)
                act = (0..n_elem).map(|i| flat[i % flat.len()]).collect();
                self.stats.segments_executed.fetch_add(1, Ordering::Relaxed);
            }
        }
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

        if dropped_at.is_some() {
            self.stats.dropped.fetch_add(1, Ordering::Relaxed);
        } else {
            self.stats.served.fetch_add(1, Ordering::Relaxed);
        }
        Ok(InferenceResponse {
            id: req.id,
            sequence: chrom,
            wall_ms,
            modeled_ms: modeled_s * 1e3,
            dropped_at,
            output_checksum: checksum,
        })
    }

    /// Serve a batch of requests, ticking satellite service between none.
    pub fn serve_batch(&mut self, requests: &[InferenceRequest]) -> Result<Vec<InferenceResponse>> {
        requests.iter().map(|r| self.serve(r)).collect()
    }

    /// Advance the satellites by one service slot (drain backlog).
    pub fn tick(&self) {
        let mut sats = self.satellites.lock().unwrap();
        for s in sats.iter_mut() {
            s.service_slot();
        }
    }

    /// Snapshot of per-satellite utilization (monitoring endpoint).
    pub fn utilization(&self) -> Vec<f64> {
        self.satellites
            .lock()
            .unwrap()
            .iter()
            .map(|s| s.utilization())
            .collect()
    }
}
