//! Thread-pool substrate (no tokio on the offline image): fixed worker
//! threads over an mpsc job channel, with typed result hand-back.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool. Jobs are closures; [`WorkerPool::run`] blocks
/// for one result, [`WorkerPool::spawn`] is fire-and-forget with a
/// receiver handle.
pub struct WorkerPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new(size: usize) -> WorkerPool {
        assert!(size > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("satkit-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed
                        }
                    })
                    .expect("spawning worker")
            })
            .collect();
        WorkerPool {
            tx: Some(tx),
            workers,
        }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job and get a receiver for its result.
    pub fn spawn<T, F>(&self, f: F) -> Receiver<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (rtx, rrx) = channel();
        let job: Job = Box::new(move || {
            let _ = rtx.send(f());
        });
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(job)
            .expect("worker pool closed");
        rrx
    }

    /// Submit and block for the result.
    pub fn run<T, F>(&self, f: F) -> Result<T, std::sync::mpsc::RecvError>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        self.spawn(f).recv()
    }

    /// Map a function over items in parallel, preserving order.
    pub fn map<T, U, F>(&self, items: Vec<T>, f: F) -> Vec<U>
    where
        T: Send + 'static,
        U: Send + 'static,
        F: Fn(T) -> U + Send + Sync + Clone + 'static,
    {
        let handles: Vec<Receiver<U>> = items
            .into_iter()
            .map(|item| {
                let f = f.clone();
                self.spawn(move || f(item))
            })
            .collect();
        handles.into_iter().map(|h| h.recv().unwrap()).collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_jobs_and_returns_results() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.run(|| 2 + 2).unwrap(), 4);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let pool = WorkerPool::new(8);
        let out = pool.map((0..100).collect::<Vec<i32>>(), |x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<i32>>());
    }

    #[test]
    fn all_workers_participate_eventually() {
        let pool = WorkerPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let rxs: Vec<_> = (0..64)
            .map(|_| {
                let c = Arc::clone(&counter);
                pool.spawn(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                })
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = WorkerPool::new(2);
        let _ = pool.run(|| ());
        drop(pool); // must not hang
    }
}
