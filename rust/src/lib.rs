//! # satkit — collaborative satellite computing via adaptive DNN task
//! splitting and offloading
//!
//! A reproduction of *"Collaborative Satellite Computing through Adaptive
//! DNN Task Splitting and Offloading"* (ISCC 2024) as a production-shaped
//! three-layer stack:
//!
//! * **L3 (this crate)** — the coordination contribution: the
//!   workload-balanced task splitting scheme ([`splitting`], Alg. 1), the
//!   GA-based self-adaptive offloading scheme ([`offload::ga`], Alg. 2),
//!   the paper's baselines (Random / RRP / DQN), the constellation
//!   simulator ([`sim`]) implementing the system model of Eq. 1–9, and a
//!   thread-pool coordinator ([`coordinator`]) that executes real DNN
//!   slice inference through PJRT.
//!
//! ## Two simulation engines
//!
//! The system model runs on either of two clocks behind the shared
//! [`engine::Engine`] abstraction (select with `SimConfig::engine` or
//! `--engine` on the CLI):
//!
//! * [`sim::Simulation`] — the paper's **fixed-slot** loop (§V): arrivals,
//!   admission, and backlog draining advance once per slot.
//! * [`eventsim::EventSim`] — a **continuous-time discrete-event** kernel:
//!   a per-plane sharded bank of binary heaps
//!   ([`eventsim::queue::ShardedEventQueue`], `SimConfig::shards`) with
//!   deterministic FIFO tie-breaking drives `TaskArrival` /
//!   `SegmentStart` / `SegmentDone` / `IslTransfer` / `Handover` /
//!   `Fault` / `StateBroadcast` events through per-satellite
//!   work-conserving queues, so delay fidelity is no longer capped by
//!   slot quantization and cost scales with events rather than
//!   wall-clock slots. One sequence counter spans the bank and pops take
//!   the global `(time, seq)` minimum, so runs are byte-identical at
//!   every shard count (`tests/prop_sharded.rs`).
//!
//! The event engine draws arrivals from pluggable
//! [`eventsim::scenario::TrafficScenario`] profiles — homogeneous Poisson
//! (the paper baseline, on which the two engines agree), diurnal
//! sinusoidal, bursty MMPP, and a moving ground-track hotspot.
//!
//! The million-task hot path is structural: live tasks sit in the
//! [`eventsim::arena::Slab`] slot arena (events carry ABA-checked
//! `(slot, id)` pairs; fault scans go through a per-satellite reverse
//! index), the GA evaluates whole generations through the
//! structure-of-arrays [`offload::DecisionSpaceIndex::deficit_batch`]
//! kernel (bit-for-bit the scalar Eq. 12; with `--features simd` it
//! dispatches to explicit AVX2/NEON lanes that stay bit-identical —
//! [`offload::simd_active`] reports what actually runs), generation
//! evaluation fans chromosome chunks across the persistent
//! [`offload::pool::EvalPool`] worker pool (`--decide-threads`,
//! byte-identical at every lane count — `tests/prop_pool.rs`; an
//! opt-in epoch-keyed decision cache, `--decision-cache`, memoizes
//! whole placements between state broadcasts), and
//! [`experiments::run_cells_repeated`] fans independent
//! (cell × repeat) work items across cores with byte-identical row
//! output. `benches/eventsim_scale.rs` tracks the resulting tasks/s in
//! `BENCH_eventsim.json`.
//!
//! ## Pluggable constellation topology
//!
//! The geometry under both engines is a [`topology::Constellation`]
//! (select with `SimConfig::topology` / `--topology`): the paper's N×N
//! torus (the default — bit-for-bit the legacy closed-form Manhattan
//! path), a Walker-Delta (`walker-delta:<p>x<s>[:f]`, wrapping
//! inter-plane ring with phasing offset F), or a Walker-Star
//! (`walker-star:<p>x<s>`, polar seam with no cross-seam ISLs). Walker
//! hop distances come from a per-topology BFS LUT computed once at
//! construction; every consumer — schemes, the indexed decision kernel,
//! gossip hop-lag, eventsim routing, handover — goes through the
//! abstraction. The `experiment topology` sweep compares completion
//! rate and tail delay per scheme across the three geometries.
//!
//! ## Resource-state dissemination
//!
//! Offloading decisions consume a disseminated [`state::StateView`], not
//! ground truth: [`state::DisseminationKind`] selects how observations age
//! (`instant`, `periodic:<T_d>` broadcast, or hop-delayed `gossip`), and
//! both engines drive the same [`state::ViewTracker`]. The slotted
//! engine's classic slot-start snapshot is the `periodic:1` special case;
//! the event engine refreshes views on
//! [`eventsim::Event::StateBroadcast`] events. The `experiment staleness`
//! sweep measures how completion rate and tail delay degrade with `T_d` —
//! the §V-B stale-state herding effect.
//!
//! ## Resilience
//!
//! Faults no longer have to be fatal: the [`resilience`] layer adds a
//! [`resilience::RecoveryPolicy`] (`--recovery drop|reoffload[:n]`) that
//! re-runs the offloading decision for a faulted task's *remaining*
//! segment chain (charging re-uplink of intermediate activations over
//! ISL hops, bounded retries, deadline-aware give-up), a
//! [`resilience::LinkFaultInjector`] for Bernoulli / Walker-star
//! seam-only ISL outages whose dead links stall and reroute in-flight
//! transfers through an outage-masked [`resilience::OutageMap`], and
//! scripted [`resilience::FaultTrace`] windows (`--fault-trace`) for
//! reproducible chaos runs. `--recovery drop` (the default) stays
//! whole-run byte-identical with the legacy engines
//! (`tests/prop_resilience.rs`), and the `experiment resilience` sweep
//! tracks completion and tail delay vs fault rate with recovery on/off
//! (`BENCH_resilience.json`).
//!
//! ## Observability
//!
//! Both engines thread an [`obs::Obs`] telemetry instance through their
//! hot paths: a ring-buffered task-lifecycle trace recorder with a
//! Chrome-trace/Perfetto JSON exporter (`--trace <path>[:<max-events>]`),
//! a runtime counter registry serialized as the `telemetry` block of
//! [`metrics::Report::to_json`] (`--telemetry`), and per-cell sweep
//! progress on stderr (`--progress`). Every hook branches on a single
//! `enabled` flag, so disabled runs stay bit-for-bit identical
//! (property-enforced by `tests/prop_telemetry.rs`).
//!
//! * **L2 (python/compile/model.py)** — JAX slice forwards, lowered once
//!   to `artifacts/*.hlo.txt` at build time.
//! * **L1 (python/compile/kernels/)** — Pallas matmul/conv kernels inside
//!   those graphs, validated against pure-jnp oracles.
//!
//! Python never runs on the request path: [`runtime`] loads the HLO text
//! artifacts and executes them on the PJRT CPU client from Rust.
//!
//! See `rust/ARCHITECTURE.md` for the full module map cross-referenced to
//! the paper's sections and equations, including the data-flow of a task's
//! life in both engines.
//!
//! ## Quickstart
//!
//! A [`config::SimConfig`] plus a scheme selects a run; [`engine::run`]
//! dispatches to the configured clock and returns the §V-B
//! [`metrics::Report`]:
//!
//! ```
//! use satkit::config::SimConfig;
//! use satkit::offload::SchemeKind;
//!
//! let cfg = SimConfig {
//!     n: 4,          // 4×4 torus constellation
//!     slots: 6,      // tiny horizon so the doctest stays fast
//!     lambda: 6.0,
//!     seed: 7,
//!     ..SimConfig::default()
//! };
//! let report = satkit::engine::run(&cfg, SchemeKind::Scc);
//! assert!(report.total_tasks > 0);
//! assert_eq!(report.total_tasks, report.completed_tasks + report.dropped_tasks);
//! println!("completion rate = {:.3}", report.completion_rate());
//! ```

pub mod comm;
pub mod config;
pub mod coordinator;
pub mod dnn;
pub mod engine;
pub mod eventsim;
pub mod metrics;
pub mod nn;
pub mod obs;
pub mod offload;
pub mod resilience;
pub mod runtime;
pub mod satellite;
pub mod sim;
pub mod splitting;
pub mod state;
pub mod tasks;
pub mod topology;
pub mod util;
pub mod experiments;
pub mod bench;
