//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust request path.
//!
//! Interchange is HLO **text** — jax ≥ 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md). Each artifact carries a
//! `<name>.meta.json` sidecar with input/output shapes that we validate
//! before feeding buffers.
//!
//! Python never runs here: after `make artifacts` the Rust binary is
//! self-contained.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Shape + dtype of one artifact port.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Sidecar metadata for an artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

fn specs_from_json(j: &Json, key: &str) -> Result<Vec<TensorSpec>> {
    let arr = j
        .get(key)
        .and_then(|x| x.as_arr())
        .ok_or_else(|| anyhow!("meta missing '{key}'"))?;
    arr.iter()
        .map(|e| {
            let shape = e
                .get("shape")
                .and_then(|s| s.as_arr())
                .ok_or_else(|| anyhow!("port missing shape"))?
                .iter()
                .map(|d| d.as_f64().unwrap_or(0.0) as usize)
                .collect();
            let dtype = e
                .get("dtype")
                .and_then(|d| d.as_str())
                .unwrap_or("float32")
                .to_string();
            Ok(TensorSpec { shape, dtype })
        })
        .collect()
}

impl ArtifactMeta {
    pub fn parse(text: &str) -> Result<ArtifactMeta> {
        let j = Json::parse(text).map_err(|e| anyhow!("meta json: {e}"))?;
        Ok(ArtifactMeta {
            name: j
                .get("name")
                .and_then(|n| n.as_str())
                .unwrap_or("unnamed")
                .to_string(),
            inputs: specs_from_json(&j, "inputs")?,
            outputs: specs_from_json(&j, "outputs")?,
        })
    }
}

/// One compiled artifact, ready to execute.
pub struct LoadedArtifact {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedArtifact {
    /// Execute with f32 inputs; shapes are validated against the sidecar.
    /// Returns the flattened f32 contents of each output.
    pub fn run_f32(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, spec) in inputs.iter().zip(&self.meta.inputs) {
            if buf.len() != spec.num_elements() {
                bail!(
                    "{}: input needs {} elements ({:?}), got {}",
                    self.meta.name,
                    spec.num_elements(),
                    spec.shape,
                    buf.len()
                );
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(buf).reshape(&dims)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }
}

/// The PJRT engine: one CPU client + all loaded artifacts.
pub struct Engine {
    client: xla::PjRtClient,
    artifacts: HashMap<String, LoadedArtifact>,
    dir: PathBuf,
}

impl Engine {
    /// Create a CPU PJRT client (the in-orbit compute substrate stand-in).
    pub fn cpu() -> Result<Engine> {
        Ok(Engine {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
            artifacts: HashMap::new(),
            dir: PathBuf::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load one artifact pair (`<dir>/<name>.hlo.txt` + `.meta.json`).
    pub fn load(&mut self, dir: &Path, name: &str) -> Result<()> {
        let hlo = dir.join(format!("{name}.hlo.txt"));
        let meta_p = dir.join(format!("{name}.meta.json"));
        let meta_text = std::fs::read_to_string(&meta_p)
            .with_context(|| format!("reading {}", meta_p.display()))?;
        let meta = ArtifactMeta::parse(&meta_text)?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing {}", hlo.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        self.artifacts
            .insert(name.to_string(), LoadedArtifact { meta, exe });
        self.dir = dir.to_path_buf();
        Ok(())
    }

    /// Load every `*.hlo.txt` in a directory. Returns loaded names.
    pub fn load_dir(&mut self, dir: &Path) -> Result<Vec<String>> {
        let mut names = Vec::new();
        for entry in std::fs::read_dir(dir)
            .with_context(|| format!("reading {}", dir.display()))?
        {
            let p = entry?.path();
            if let Some(fname) = p.file_name().and_then(|f| f.to_str()) {
                if let Some(stem) = fname.strip_suffix(".hlo.txt") {
                    self.load(dir, stem)?;
                    names.push(stem.to_string());
                }
            }
        }
        names.sort();
        Ok(names)
    }

    pub fn get(&self, name: &str) -> Result<&LoadedArtifact> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded (dir: {})", self.dir.display()))
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.artifacts.keys().map(String::as_str).collect();
        v.sort();
        v
    }

    /// Convenience: execute by name.
    pub fn run_f32(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.get(name)?.run_f32(inputs)
    }
}

/// Default artifact directory: `$SATKIT_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("SATKIT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parses_sidecar() {
        let m = ArtifactMeta::parse(
            r#"{"name":"qnet","inputs":[{"shape":[8,32],"dtype":"float32"}],
                "outputs":[{"shape":[8,5],"dtype":"float32"}]}"#,
        )
        .unwrap();
        assert_eq!(m.name, "qnet");
        assert_eq!(m.inputs[0].shape, vec![8, 32]);
        assert_eq!(m.inputs[0].num_elements(), 256);
        assert_eq!(m.outputs[0].shape, vec![8, 5]);
    }

    #[test]
    fn meta_rejects_garbage() {
        assert!(ArtifactMeta::parse("not json").is_err());
        assert!(ArtifactMeta::parse(r#"{"name":"x"}"#).is_err());
    }

    // PJRT-backed tests live in rust/tests/integration_runtime.rs (they
    // need artifacts/ built by `make artifacts`).
}

// ---------------------------------------------------------------------------
// ExecPool: PJRT execution workers.
//
// The `xla` crate's client/executable types are thread-confined (Rc
// internals, not Send/Sync), so artifacts cannot be shared across a thread
// pool. Instead each execution worker owns a full Engine — its own PJRT
// client with all artifacts compiled — and requests are dispatched over
// channels. This mirrors the deployment model anyway: every satellite runs
// its own on-board runtime.

use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// A slice-execution request: artifact name + flattened f32 inputs.
pub struct ExecJob {
    pub artifact: String,
    pub inputs: Vec<Vec<f32>>,
    pub reply: Sender<Result<Vec<Vec<f32>>>>,
}

/// Pool of PJRT execution workers, each with a private [`Engine`].
pub struct ExecPool {
    tx: Option<Sender<ExecJob>>,
    workers: Vec<JoinHandle<()>>,
    names: Vec<String>,
}

impl ExecPool {
    /// Spawn `size` workers, each compiling every artifact in `dir`.
    /// Blocks until all workers are ready (or one fails).
    pub fn new(dir: &Path, size: usize) -> Result<ExecPool> {
        assert!(size > 0);
        let (tx, rx) = channel::<ExecJob>();
        let rx = Arc::new(Mutex::new(rx));
        let (ready_tx, ready_rx) = channel::<Result<Vec<String>>>();
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            let ready = ready_tx.clone();
            let dir = dir.to_path_buf();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("satkit-exec-{i}"))
                    .spawn(move || {
                        let engine = (|| -> Result<Engine> {
                            let mut e = Engine::cpu()?;
                            e.load_dir(&dir)?;
                            Ok(e)
                        })();
                        let engine = match engine {
                            Ok(e) => {
                                let _ = ready
                                    .send(Ok(e.names().iter().map(|s| s.to_string()).collect()));
                                e
                            }
                            Err(err) => {
                                let _ = ready.send(Err(err));
                                return;
                            }
                        };
                        loop {
                            let job = {
                                let guard = rx.lock().unwrap();
                                guard.recv()
                            };
                            match job {
                                Ok(job) => {
                                    let res = engine.run_f32(&job.artifact, &job.inputs);
                                    let _ = job.reply.send(res);
                                }
                                Err(_) => break,
                            }
                        }
                    })
                    .expect("spawning exec worker"),
            );
        }
        drop(ready_tx);
        let mut names = Vec::new();
        for _ in 0..size {
            names = ready_rx.recv().expect("worker startup")?;
        }
        Ok(ExecPool {
            tx: Some(tx),
            workers,
            names,
        })
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Names of the artifacts every worker has loaded.
    pub fn artifact_names(&self) -> &[String] {
        &self.names
    }

    /// Submit an execution; returns a receiver for the result.
    pub fn submit(
        &self,
        artifact: &str,
        inputs: Vec<Vec<f32>>,
    ) -> std::sync::mpsc::Receiver<Result<Vec<Vec<f32>>>> {
        let (reply, rx) = channel();
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(ExecJob {
                artifact: artifact.to_string(),
                inputs,
                reply,
            })
            .expect("exec pool closed");
        rx
    }

    /// Submit and block.
    pub fn run(&self, artifact: &str, inputs: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        self.submit(artifact, inputs)
            .recv()
            .map_err(|e| anyhow!("exec worker died: {e}"))?
    }
}

impl Drop for ExecPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}
