//! Workload-balanced task splitting (Algorithm 1, §IV-A).
//!
//! Given the per-layer workloads `{w_1, …, w_{N^l}}` and an expected slice
//! count `L ≤ N^l`, find the partition of consecutive layers into exactly
//! `L` blocks that minimizes the largest block workload (the min-max
//! utility of Eq. 3) via binary search on the block-size limit ("binary
//! monotonicity" + dichotomy): `Split(limit)` greedily packs layers while
//! the running block stays ≤ limit, and the resulting block count is
//! non-increasing in the limit.
//!
//! Complexity: `O(N^l · log2(V))` time with `V = Σw − max w` the search
//! interval, `O(L)` extra space — as analysed in §IV-A.

/// One block (slice) of consecutive layers.
#[derive(Clone, Debug, PartialEq)]
pub struct Block {
    /// Index of the first layer in the block (0-based, inclusive).
    pub start: usize,
    /// One past the last layer (exclusive). `start == end` ⇒ empty block.
    pub end: usize,
    /// Total workload of the block [MFLOP] — the `m_k` of Eq. 3/4.
    pub workload: f64,
}

impl Block {
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }

    pub fn num_layers(&self) -> usize {
        self.end - self.start
    }
}

/// The partitioning result: exactly `L` blocks covering all layers in
/// order (possibly with trailing empty blocks, per Alg. 1 line 24).
#[derive(Clone, Debug, PartialEq)]
pub struct SplitResult {
    pub blocks: Vec<Block>,
    /// The binary-search block-size limit that produced this partition.
    pub limit: f64,
}

impl SplitResult {
    /// Per-segment workloads `{q_1, …, q_L}` (Alg. 2's input).
    pub fn segment_workloads(&self) -> Vec<f64> {
        self.blocks.iter().map(|b| b.workload).collect()
    }

    /// max_k m_k — the minimized objective (Eq. 3).
    pub fn max_block_workload(&self) -> f64 {
        self.blocks.iter().map(|b| b.workload).fold(0.0, f64::max)
    }

    /// Balance ratio: max block / mean non-empty block (1.0 = perfect).
    pub fn balance_ratio(&self) -> f64 {
        let nonempty: Vec<f64> = self
            .blocks
            .iter()
            .filter(|b| !b.is_empty())
            .map(|b| b.workload)
            .collect();
        if nonempty.is_empty() {
            return 1.0;
        }
        let mean = nonempty.iter().sum::<f64>() / nonempty.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            self.max_block_workload() / mean
        }
    }
}

/// `Split(LimitSize)` (Alg. 1 lines 1–12): greedy first-fit pack of the
/// layer sequence into blocks of workload ≤ `limit`. Returns block
/// boundaries. `limit` must be ≥ max layer workload for this to cover all
/// layers; the driver guarantees that via the Lower bound.
pub fn split_with_limit(workloads: &[f64], limit: f64) -> Vec<Block> {
    let mut blocks = Vec::new();
    let mut start = 0usize;
    let mut acc = 0.0f64;
    for (i, &w) in workloads.iter().enumerate() {
        if acc + w <= limit {
            acc += w;
        } else {
            blocks.push(Block {
                start,
                end: i,
                workload: acc,
            });
            start = i;
            acc = w;
        }
    }
    blocks.push(Block {
        start,
        end: workloads.len(),
        workload: acc,
    });
    blocks
}

/// Algorithm 1: workload-balanced split into exactly `L` blocks.
///
/// `epsilon` is the binary-search precision (Table I: 1 MFLOP).
///
/// # Panics
/// If `workloads` is empty, `L == 0`, or `L > N^l` (constraint 11e).
pub fn balanced_split(workloads: &[f64], l: usize, epsilon: f64) -> SplitResult {
    assert!(!workloads.is_empty(), "no layers to split");
    assert!(l >= 1, "L must be >= 1");
    assert!(
        l <= workloads.len(),
        "constraint 11e violated: L={l} > N^l={}",
        workloads.len()
    );
    assert!(epsilon > 0.0);
    assert!(
        workloads.iter().all(|w| *w >= 0.0),
        "negative layer workload"
    );

    // Lower = max_k w_k (every layer must fit in one block);
    // Upper = Σ w_k (a single block holds everything).
    let mut lower = workloads.iter().cloned().fold(0.0, f64::max);
    let mut upper: f64 = workloads.iter().sum();

    while upper - lower > epsilon {
        let mid = 0.5 * (lower + upper);
        let scheme = split_with_limit(workloads, mid);
        if scheme.len() > l {
            // too many blocks: limit too small
            lower = mid;
        } else {
            upper = mid;
        }
    }

    // `upper` is feasible: |Split(upper)| <= L.
    let mut blocks = split_with_limit(workloads, upper);
    debug_assert!(blocks.len() <= l);
    // Alg. 1 line 24: pad with empty blocks until |result| == L.
    let tail = workloads.len();
    while blocks.len() < l {
        blocks.push(Block {
            start: tail,
            end: tail,
            workload: 0.0,
        });
    }
    SplitResult {
        blocks,
        limit: upper,
    }
}

/// Naive equal-layer-count split baseline (for the ablation bench): cut
/// every ⌈N^l / L⌉ layers regardless of workload.
pub fn naive_equal_layers(workloads: &[f64], l: usize) -> SplitResult {
    assert!(l >= 1 && l <= workloads.len());
    let n = workloads.len();
    let per = n.div_ceil(l);
    let mut blocks = Vec::with_capacity(l);
    for k in 0..l {
        let start = (k * per).min(n);
        let end = ((k + 1) * per).min(n);
        blocks.push(Block {
            start,
            end,
            workload: workloads[start..end].iter().sum(),
        });
    }
    let limit = blocks.iter().map(|b| b.workload).fold(0.0, f64::max);
    SplitResult { blocks, limit }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::DnnModel;

    fn assert_valid_partition(workloads: &[f64], res: &SplitResult, l: usize) {
        assert_eq!(res.blocks.len(), l, "exactly L blocks");
        // coverage in order, no gaps/overlaps
        let mut pos = 0usize;
        for b in &res.blocks {
            if !b.is_empty() {
                assert_eq!(b.start, pos, "gap/overlap at {pos}");
                pos = b.end;
            }
        }
        assert_eq!(pos, workloads.len(), "all layers covered");
        // workload sums match
        let total: f64 = workloads.iter().sum();
        let got: f64 = res.blocks.iter().map(|b| b.workload).sum();
        assert!((total - got).abs() < 1e-6 * total.max(1.0));
    }

    #[test]
    fn uniform_layers_split_evenly() {
        let w = vec![10.0; 12];
        let res = balanced_split(&w, 4, 0.01);
        assert_valid_partition(&w, &res, 4);
        assert!((res.max_block_workload() - 30.0).abs() < 1.0);
        assert!(res.balance_ratio() < 1.05);
    }

    #[test]
    fn single_giant_layer_dominates() {
        let w = vec![1.0, 1.0, 100.0, 1.0, 1.0];
        let res = balanced_split(&w, 3, 0.01);
        assert_valid_partition(&w, &res, 3);
        // the giant layer forms (close to) its own block
        assert!(res.max_block_workload() <= 102.1);
    }

    #[test]
    fn l_equals_one_single_block() {
        let w = vec![5.0, 7.0, 3.0];
        let res = balanced_split(&w, 1, 0.01);
        assert_eq!(res.blocks.len(), 1);
        assert!((res.blocks[0].workload - 15.0).abs() < 1e-9);
    }

    #[test]
    fn l_equals_n_each_layer_own_block_or_padded() {
        let w = vec![4.0, 4.0, 4.0, 4.0];
        let res = balanced_split(&w, 4, 0.01);
        assert_valid_partition(&w, &res, 4);
        assert!((res.max_block_workload() - 4.0).abs() < 0.1);
    }

    #[test]
    fn pads_empty_blocks_when_fewer_needed() {
        // heavy skew: greedy may legitimately need < L blocks, padded to L
        let w2 = vec![100.0, 0.0, 0.0];
        let res2 = balanced_split(&w2, 3, 0.01);
        assert_eq!(res2.blocks.len(), 3);
        assert_valid_partition(&w2, &res2, 3);
    }

    #[test]
    fn monotone_block_count_in_limit() {
        let w: Vec<f64> = (1..=20).map(|i| (i as f64 * 7.0) % 13.0 + 1.0).collect();
        let mut prev = usize::MAX;
        let total: f64 = w.iter().sum();
        let maxw = w.iter().cloned().fold(0.0, f64::max);
        let mut lim = maxw;
        while lim <= total {
            let count = split_with_limit(&w, lim).len();
            assert!(count <= prev, "block count must be non-increasing");
            prev = count;
            lim += (total - maxw) / 37.0;
        }
    }

    #[test]
    fn vgg19_table1_split() {
        let w = DnnModel::Vgg19.profile().workloads();
        let res = balanced_split(&w, 3, 1.0);
        assert_valid_partition(&w, &res, 3);
        let total: f64 = w.iter().sum();
        // balanced: max block well below half the model
        assert!(res.max_block_workload() < 0.55 * total);
        assert!(res.balance_ratio() < 1.6, "ratio={}", res.balance_ratio());
    }

    #[test]
    fn resnet101_table1_split() {
        let w = DnnModel::Resnet101.profile().workloads();
        let res = balanced_split(&w, 4, 1.0);
        assert_valid_partition(&w, &res, 4);
        assert!(res.balance_ratio() < 1.35, "ratio={}", res.balance_ratio());
    }

    #[test]
    fn balanced_beats_naive_on_skewed_input() {
        let w = DnnModel::Vgg19.profile().workloads();
        let bal = balanced_split(&w, 3, 1.0);
        let naive = naive_equal_layers(&w, 3);
        assert!(bal.max_block_workload() <= naive.max_block_workload());
    }

    #[test]
    fn optimality_vs_bruteforce_small() {
        // exhaustive check: binary-search result equals the true min-max
        // over all contiguous 3-partitions for a small case
        let w = vec![3.0, 9.0, 2.0, 7.0, 4.0, 6.0];
        let l = 3;
        let res = balanced_split(&w, l, 1e-6);
        let mut best = f64::INFINITY;
        let n = w.len();
        for c1 in 1..n {
            for c2 in c1 + 1..n {
                let parts = [
                    w[..c1].iter().sum::<f64>(),
                    w[c1..c2].iter().sum::<f64>(),
                    w[c2..].iter().sum::<f64>(),
                ];
                best = best.min(parts.into_iter().fold(0.0, f64::max));
            }
        }
        assert!(
            (res.max_block_workload() - best).abs() < 1e-3,
            "got {} want {}",
            res.max_block_workload(),
            best
        );
    }

    #[test]
    #[should_panic(expected = "constraint 11e")]
    fn rejects_l_above_layer_count() {
        balanced_split(&[1.0, 2.0], 3, 0.1);
    }

    #[test]
    fn zero_workload_layers_ok() {
        let w = vec![0.0, 5.0, 0.0, 5.0];
        let res = balanced_split(&w, 2, 0.01);
        assert_valid_partition(&w, &res, 2);
    }
}
