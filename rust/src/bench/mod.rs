//! Bench harness substrate (the offline image has no criterion): a small
//! wall-clock timing framework with warmup, repetitions, and
//! mean/stddev/min reporting, used by every target in `rust/benches/`.

use crate::util::stats;
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_ms: f64,
    pub stddev_ms: f64,
    pub min_ms: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10.3} ms ± {:>8.3} (min {:>10.3}, n={})",
            self.name, self.mean_ms, self.stddev_ms, self.min_ms, self.iters
        )
    }
}

/// Time `f` with `warmup` throwaway runs and `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    BenchResult {
        name: name.to_string(),
        mean_ms: stats::mean(&samples),
        stddev_ms: stats::stddev_sample(&samples),
        min_ms: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        iters: samples.len(),
    }
}

/// Standard bench-binary preamble: honour `SATKIT_BENCH_QUICK=1`.
pub fn quick_mode() -> bool {
    std::env::var("SATKIT_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 1, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(r.mean_ms >= 0.0);
        assert_eq!(r.iters, 5);
        assert!(r.min_ms <= r.mean_ms + 1e-9);
    }

    #[test]
    fn row_formats() {
        let r = BenchResult {
            name: "x".into(),
            mean_ms: 1.0,
            stddev_ms: 0.1,
            min_ms: 0.9,
            iters: 3,
        };
        assert!(r.row().contains("ms"));
    }
}
