//! Bench harness substrate (the offline image has no criterion): a small
//! wall-clock timing framework with warmup, repetitions, and
//! mean/stddev/min reporting, used by every target in `rust/benches/`,
//! plus machine-readable JSON export (`BENCH_<name>.json`) so the perf
//! trajectory of the hot paths can be tracked across PRs and smoked in CI.

use crate::util::json::Json;
use crate::util::stats;
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_ms: f64,
    pub stddev_ms: f64,
    pub min_ms: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10.3} ms ± {:>8.3} (min {:>10.3}, n={})",
            self.name, self.mean_ms, self.stddev_ms, self.min_ms, self.iters
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("mean_ms", Json::Num(self.mean_ms)),
            ("stddev_ms", Json::Num(self.stddev_ms)),
            ("min_ms", Json::Num(self.min_ms)),
            ("iters", Json::Num(self.iters as f64)),
        ])
    }
}

/// Assemble a machine-readable bench report: the suite name, whether quick
/// mode trimmed the workload (quick numbers are NOT comparable to full
/// ones), and every case's timing.
pub fn suite_json(suite: &str, quick: bool, results: &[BenchResult]) -> Json {
    Json::obj(vec![
        ("bench", Json::Str(suite.to_string())),
        ("quick", Json::Bool(quick)),
        (
            "results",
            Json::Arr(results.iter().map(|r| r.to_json()).collect()),
        ),
    ])
}

/// Write any machine-readable report (e.g. the `experiment staleness`
/// sweep's `BENCH_staleness.json`) alongside the timing suites.
pub fn write_json(path: &str, json: &Json) -> std::io::Result<()> {
    std::fs::write(path, json.to_string())
}

/// Write the suite report to `path` (conventionally `BENCH_<suite>.json`
/// in the crate root, overridable via `SATKIT_BENCH_JSON`).
pub fn write_suite_json(
    path: &str,
    suite: &str,
    quick: bool,
    results: &[BenchResult],
) -> std::io::Result<()> {
    std::fs::write(path, suite_json(suite, quick, results).to_string())
}

/// Time `f` with `warmup` throwaway runs and `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    BenchResult {
        name: name.to_string(),
        mean_ms: stats::mean(&samples),
        stddev_ms: stats::stddev_sample(&samples),
        min_ms: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        iters: samples.len(),
    }
}

/// Time like [`bench`], then normalize every statistic by `items`: for
/// kernels that process a whole batch per closure call but whose row
/// should stay comparable with single-element cases (e.g. the batched
/// deficit kernel reported per chromosome next to the scalar rows).
pub fn bench_per_item<F: FnMut()>(
    name: &str,
    items: usize,
    warmup: usize,
    iters: usize,
    f: F,
) -> BenchResult {
    let mut r = bench(name, warmup, iters, f);
    let d = items.max(1) as f64;
    r.mean_ms /= d;
    r.stddev_ms /= d;
    r.min_ms /= d;
    r
}

/// Standard bench-binary preamble: honour `SATKIT_BENCH_QUICK=1`.
pub fn quick_mode() -> bool {
    std::env::var("SATKIT_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Resolve an output path from its `SATKIT_*_JSON` env override, falling
/// back to `default`. One helper for every bench/sweep emitter (hotpath,
/// eventsim, staleness, topology, llm) so the override convention can't
/// drift per call site.
pub fn out_path(env_key: &str, default: &str) -> String {
    std::env::var(env_key).unwrap_or_else(|_| default.to_string())
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 1, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(r.mean_ms >= 0.0);
        assert_eq!(r.iters, 5);
        assert!(r.min_ms <= r.mean_ms + 1e-9);
    }

    #[test]
    fn per_item_normalizes_stats() {
        let r = bench_per_item("batchy", 10, 0, 3, || {
            std::hint::black_box((0..100_000u64).sum::<u64>());
        });
        let raw = bench("raw", 0, 3, || {
            std::hint::black_box((0..100_000u64).sum::<u64>());
        });
        // same work, but reported per item: ~10x smaller statistics
        assert!(r.mean_ms <= raw.mean_ms, "{} vs {}", r.mean_ms, raw.mean_ms);
        assert!(r.min_ms <= r.mean_ms + 1e-9);
        assert_eq!(r.iters, 3);
    }

    #[test]
    fn row_formats() {
        let r = BenchResult {
            name: "x".into(),
            mean_ms: 1.0,
            stddev_ms: 0.1,
            min_ms: 0.9,
            iters: 3,
        };
        assert!(r.row().contains("ms"));
    }

    #[test]
    fn out_path_prefers_env_override() {
        // key unique to this test: cargo runs tests in-process threads,
        // so a shared key could race with another test's env mutation
        let key = "SATKIT_TEST_OUT_PATH_JSON";
        std::env::remove_var(key);
        assert_eq!(out_path(key, "BENCH_default.json"), "BENCH_default.json");
        std::env::set_var(key, "/tmp/override.json");
        assert_eq!(out_path(key, "BENCH_default.json"), "/tmp/override.json");
        std::env::remove_var(key);
    }

    #[test]
    fn suite_json_parses_back() {
        let r = BenchResult {
            name: "SCC decide".into(),
            mean_ms: 0.5,
            stddev_ms: 0.01,
            min_ms: 0.45,
            iters: 20,
        };
        let j = suite_json("hotpath", true, &[r]).to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("hotpath"));
        assert_eq!(parsed.get("quick").unwrap(), &Json::Bool(true));
        let results = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("name").unwrap().as_str(), Some("SCC decide"));
        assert_eq!(results[0].get("mean_ms").unwrap().as_f64(), Some(0.5));
    }
}
