//! Shared abstraction over the two simulation engines.
//!
//! The slotted simulator ([`crate::sim::Simulation`], the paper's §V
//! evaluation loop) and the continuous-time discrete-event kernel
//! ([`crate::eventsim::EventSim`]) consume the same [`SimConfig`] and
//! produce the same [`Report`], so callers — the CLI, the experiment
//! harness, the benches — select one with [`EngineKind`] and stay
//! agnostic about the clock underneath.

use crate::config::{EngineKind, SimConfig};
use crate::eventsim::EventSim;
use crate::metrics::Report;
use crate::offload::SchemeKind;
use crate::sim::Simulation;

/// A ready-to-run simulation, independent of its clock model.
pub trait Engine {
    /// Engine label for tables and logs.
    fn label(&self) -> &'static str;

    /// Consume the engine and produce the §V-B report.
    fn run_boxed(self: Box<Self>) -> Report;
}

impl Engine for Simulation {
    fn label(&self) -> &'static str {
        EngineKind::Slotted.name()
    }

    fn run_boxed(self: Box<Self>) -> Report {
        (*self).run()
    }
}

impl Engine for EventSim {
    fn label(&self) -> &'static str {
        EngineKind::Event.name()
    }

    fn run_boxed(self: Box<Self>) -> Report {
        (*self).run()
    }
}

/// Instantiate the engine selected by `cfg.engine`.
pub fn build(cfg: &SimConfig, kind: SchemeKind) -> Box<dyn Engine> {
    match cfg.engine {
        EngineKind::Slotted => Box::new(Simulation::new(cfg, kind)),
        EngineKind::Event => Box::new(EventSim::new(cfg, kind)),
    }
}

/// Build and run in one step (the common CLI/experiment path).
pub fn run(cfg: &SimConfig, kind: SchemeKind) -> Report {
    build(cfg, kind).run_boxed()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ScenarioKind;

    fn cfg(engine: EngineKind) -> SimConfig {
        SimConfig {
            n: 6,
            slots: 8,
            lambda: 5.0,
            seed: 3,
            engine,
            ..SimConfig::default()
        }
    }

    #[test]
    fn dispatch_selects_both_engines() {
        for kind in EngineKind::all() {
            let e = build(&cfg(kind), SchemeKind::Random);
            assert_eq!(e.label(), kind.name());
            let r = e.run_boxed();
            assert!(r.total_tasks > 0, "{kind:?}");
        }
    }

    #[test]
    fn run_honours_scenario_field() {
        let mut c = cfg(EngineKind::Event);
        c.scenario = ScenarioKind::Diurnal;
        let r = run(&c, SchemeKind::Rrp);
        assert!(r.total_tasks > 0);
    }

    #[test]
    fn metrics_stream_by_default_and_retain_on_flag() {
        for kind in EngineKind::all() {
            // default: streaming — no per-task buffer in the report
            let streamed = run(&cfg(kind), SchemeKind::Random);
            assert!(streamed.outcomes.is_none(), "{kind:?} buffered by default");

            // flag: full outcomes retained, one per task, same headline stats
            let mut c = cfg(kind);
            c.retain_outcomes = true;
            let retained = run(&c, SchemeKind::Random);
            let outs = retained.outcomes.as_ref().expect("retained outcomes");
            assert_eq!(outs.len() as u64, retained.total_tasks);
            assert_eq!(streamed.total_tasks, retained.total_tasks, "{kind:?}");
            assert_eq!(
                streamed.avg_delay_ms.to_bits(),
                retained.avg_delay_ms.to_bits(),
                "{kind:?}: retaining must not change streamed statistics"
            );
            // retained buffer agrees with the streamed counters
            let completed = outs.iter().filter(|o| o.completed()).count() as u64;
            assert_eq!(completed, retained.completed_tasks);
        }
    }
}
